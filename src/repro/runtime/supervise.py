"""Process supervision and health watchdogs, decoupled from the LM trainer.

The pieces the training loop (``runtime/loop.py``) grew for 1000-node runs —
the rolling-median straggler watchdog and the restore-and-retry restart
policy — apply just as well to a *serving* process: a forecast server must be
spawned, probed for readiness, restarted with backoff when it dies, and given
up on when it crash-loops.  This module owns those mechanisms; the trainer
and the serving launcher both import from here.

* :class:`StragglerWatchdog` — rolling-median step/dispatch timer (moved from
  ``runtime.loop``, which re-exports it for compatibility).
* :class:`RestartPolicy` — exponential backoff + crash-loop detection over a
  sliding window.
* :class:`Supervisor` — spawn a child process, poll a readiness probe,
  restart on exit per the policy, raise :class:`SupervisorGaveUp` on a crash
  loop.  Synchronous on purpose: it supervises a *separate* process and is
  itself the thing that must stay simple enough to never crash.
* :func:`http_ready` — a stdlib-only readiness probe for ``/healthz``-style
  endpoints (no aiohttp dependency in the supervising process).
"""

from __future__ import annotations

import logging
import os
import subprocess
import sys
import time
import urllib.error
import urllib.request
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.flight import FlightRecorder
from repro.obs.trace import monotonic

log = logging.getLogger("repro.runtime")


# ---------------------------------------------------------------------------
# straggler watchdog (moved from runtime/loop.py)
# ---------------------------------------------------------------------------


@dataclass
class WatchdogStats:
    steps: int = 0
    stragglers: int = 0
    median_s: float = 0.0


class StragglerWatchdog:
    """Rolling-median step timer; flags steps slower than ``factor``×median."""

    def __init__(self, factor: float = 3.0, window: int = 32,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.stats = WatchdogStats()
        self.on_straggler = on_straggler

    def record(self, step: int, dt: float) -> bool:
        self.stats.steps += 1
        flagged = False
        # straggler flagging compares dt against the median of the PRIOR
        # samples (>= 8 of them, the warm-up), so a slow step is judged
        # against history it is not part of
        prior = self.times[-self.window :]
        self.times.append(dt)
        # ...but the published rolling median includes the sample just
        # recorded: consumers like the serving engine's retry_after_ms need
        # a real estimate from the very first dispatch, not the second
        self.stats.median_s = float(np.median(self.times[-self.window :]))
        if len(prior) >= 8:
            med = float(np.median(prior))
            if dt > self.factor * med:
                self.stats.stragglers += 1
                flagged = True
                log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        return flagged


# ---------------------------------------------------------------------------
# restart policy: backoff + crash-loop detection
# ---------------------------------------------------------------------------


@dataclass
class RestartPolicy:
    """Exponential backoff between restarts; give up on a crash loop.

    A *crash loop* is ``max_crashes`` exits within ``crash_window_s`` of each
    other — a child that keeps dying right after (or before) becoming ready
    will not be restarted forever."""

    backoff_s: float = 0.5
    backoff_factor: float = 2.0
    backoff_max_s: float = 30.0
    crash_window_s: float = 60.0
    max_crashes: int = 5
    _crash_times: List[float] = field(default_factory=list)
    _restarts: int = 0

    def next_backoff(self) -> float:
        b = min(self.backoff_s * self.backoff_factor**self._restarts, self.backoff_max_s)
        self._restarts += 1
        return b

    def reset_backoff(self) -> None:
        self._restarts = 0

    def record_crash(self, now: Optional[float] = None) -> bool:
        """Record one child exit; returns True when this tips into a crash
        loop (caller should give up instead of restarting)."""
        now = monotonic() if now is None else now
        self._crash_times.append(now)
        window = [t for t in self._crash_times if now - t <= self.crash_window_s]
        self._crash_times = window
        return len(window) >= self.max_crashes


class SupervisorGaveUp(RuntimeError):
    """The supervised child crash-looped past the restart policy."""


def http_ready(url: str, timeout_s: float = 1.0) -> bool:
    """True iff ``url`` answers 2xx within ``timeout_s`` (stdlib only)."""
    try:
        with urllib.request.urlopen(url, timeout=timeout_s) as resp:
            return 200 <= resp.status < 300
    except (urllib.error.URLError, OSError, ValueError):
        return False


# ---------------------------------------------------------------------------
# the supervisor
# ---------------------------------------------------------------------------


class Supervisor:
    """Spawn → probe readiness → restart with backoff → give up on crash loop.

    ``probe`` is any zero-argument callable returning True once the child is
    ready (:func:`http_ready` partial'd onto ``/healthz`` for the forecast
    server; tests use file- or socket-based probes).  A child that exits (or
    never probes ready within ``ready_timeout_s``) counts as one crash.

    When a flight recorder is armed (``flight=`` or ``$REPRO_FLIGHT_DIR``),
    the supervisor drops a bundle *before* every restart and on crash-loop
    give-up: the child's own recorder (same env var, inherited through
    :func:`_child_env`) captures the in-process story, and the supervisor's
    bundle captures the outside view — exit codes, restart cadence, backoff
    state — so an operator can reconstruct a crash loop from the bundles
    alone.
    """

    def __init__(
        self,
        cmd: Sequence[str],
        *,
        probe: Callable[[], bool],
        policy: Optional[RestartPolicy] = None,
        ready_timeout_s: float = 60.0,
        probe_interval_s: float = 0.1,
        on_event: Optional[Callable[[str, Dict], None]] = None,
        flight: Optional[FlightRecorder] = None,
    ):
        self.cmd = list(cmd)
        self.probe = probe
        self.policy = policy or RestartPolicy()
        self.ready_timeout_s = float(ready_timeout_s)
        self.probe_interval_s = float(probe_interval_s)
        self.on_event = on_event
        self.proc: Optional[subprocess.Popen] = None
        self._stopping = False
        self.stats: Dict[str, int] = {"spawns": 0, "crashes": 0, "restarts": 0}
        self.flight = flight if flight is not None else FlightRecorder.from_env()
        if self.flight is not None:
            self.flight.bind(
                stats=self._flight_stats,
                config={"cmd": self.cmd, "ready_timeout_s": self.ready_timeout_s},
            )

    def _event(self, kind: str, **detail) -> None:
        log.info("supervisor: %s %s", kind, detail)
        if self.on_event:
            self.on_event(kind, detail)

    def _flight_stats(self) -> Dict:
        return {
            **self.stats,
            "restarts_since_ready": self.policy._restarts,
            "crashes_in_window": len(self.policy._crash_times),
            "child_pid": self.proc.pid if self.proc is not None else None,
            "child_returncode": self.proc.poll() if self.proc is not None else None,
        }

    # -- lifecycle ----------------------------------------------------------

    def spawn(self) -> subprocess.Popen:
        self.stats["spawns"] += 1
        self.proc = subprocess.Popen(self.cmd, env=_child_env())
        self._event("spawned", pid=self.proc.pid)
        return self.proc

    def wait_ready(self) -> bool:
        """Poll the probe until ready; False if the child dies or the
        readiness timeout expires first."""
        deadline = monotonic() + self.ready_timeout_s
        while monotonic() < deadline:
            if self.proc is not None and self.proc.poll() is not None:
                return False
            if self.probe():
                self._event("ready", pid=self.proc.pid if self.proc else None)
                return True
            time.sleep(self.probe_interval_s)
        return False

    def start(self) -> None:
        """Spawn and block until ready; crash-loop rules apply from the very
        first spawn (a child that can't ever become ready gives up too)."""
        while not self._stopping:
            self.spawn()
            if self.wait_ready():
                self.policy.reset_backoff()
                return
            self._crash_and_backoff("never became ready")

    def run_forever(self) -> None:
        """Supervise until :class:`SupervisorGaveUp` or an external stop():
        wait for the child to exit, restart it, re-probe readiness (crash-loop
        accounting applies to the restarts exactly as to the first spawn)."""
        if self.proc is None:
            self.start()
        while not self._stopping:
            proc = self.proc
            if proc is None:  # stop() detached it: deliberate shutdown
                return
            code = proc.wait()
            if self._stopping or self.proc is not proc:
                return
            self._crash_and_backoff(f"exit code {code}")
            self.stats["restarts"] += 1
            self.start()

    def _crash_and_backoff(self, why: str) -> None:
        self.stats["crashes"] += 1
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()
        if self.policy.record_crash():
            self._event("gave_up", reason=why, crashes=self.stats["crashes"])
            if self.flight is not None:
                self.flight.dump("supervisor_gave_up", extra={"why": why})
            raise SupervisorGaveUp(
                f"{self.policy.max_crashes} crashes within {self.policy.crash_window_s}s ({why})"
            )
        backoff = self.policy.next_backoff()
        self._event("crashed", reason=why, backoff_s=backoff)
        # the black box goes down with the plane: record what the supervisor
        # saw BEFORE the restart, while the dead child's exit state is fresh
        if self.flight is not None:
            self.flight.dump("supervisor_restart", extra={"why": why, "backoff_s": backoff})
        time.sleep(backoff)

    def stop(self, grace_s: float = 5.0) -> None:
        """Terminate the child (SIGTERM, then SIGKILL after ``grace_s``) and
        end supervision — run_forever/start return instead of respawning.
        Terminal for this instance: build a fresh Supervisor to serve again."""
        self._stopping = True
        proc, self.proc = self.proc, None
        if proc is None or proc.poll() is not None:
            return
        proc.terminate()
        try:
            proc.wait(timeout=grace_s)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait()
        self._event("stopped", pid=proc.pid)


def _child_env() -> Dict[str, str]:
    """The environment for a supervised child: the parent's, with the root
    this process imported :mod:`repro` from prepended to ``PYTHONPATH`` —
    ``sys.path`` edits (a source checkout, the test conftest) do not survive
    into a subprocess, and without this a ``-m repro.launch.serve`` child
    dies with ModuleNotFoundError before it can ever become ready."""
    import repro as _repro

    env = dict(os.environ)
    root = str(Path(_repro.__file__).resolve().parent.parent)
    existing = [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    if root not in existing:
        env["PYTHONPATH"] = os.pathsep.join([root, *existing])
    return env


def serve_command(argv: Sequence[str]) -> List[str]:
    """The child command for a supervised forecast server: this interpreter,
    ``-m repro.launch.serve``, the caller's serve args."""
    return [sys.executable, "-m", "repro.launch.serve", *argv]
