"""Training loop with checkpoint/restart, straggler watchdog, elastic restore.

Failure model (1000+-node operation):

* **Process/node loss** — every state mutation passes through TrainState;
  checkpoints are atomic (COMMIT marker) and device-agnostic, and the data
  pipeline is a pure function of step, so crash+restart resumes bit-exact on
  whatever mesh the restarted job gets (elastic re-shard via logical rules).
* **Stragglers** — a rolling-median step-time watchdog flags slow steps and
  invokes a mitigation callback (logging / skip-host policy upstream).
  Checkpoint writes are async so slow storage never stalls the step loop.
* **Fault injection** — Trainer.run(fault_hook=...) lets tests kill steps
  deterministically and assert recovery (tests/test_runtime.py).
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, linear_warmup_cosine
from repro.runtime.supervise import StragglerWatchdog, WatchdogStats  # noqa: F401 — re-exported;
# the watchdog moved to runtime/supervise.py (shared with the serving
# supervisor), existing importers keep finding it here

log = logging.getLogger("repro.runtime")


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: Any


def make_train_step(
    model,
    *,
    base_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
    weight_decay: float = 0.1,
    remat: bool = True,
    microbatches: int = 1,
) -> Callable:
    """Pure (state, batch) → (state, metrics); jit/pjit-ready.

    ``microbatches`` > 1 enables gradient accumulation via lax.scan: the
    global batch is split on the leading axis, per-microbatch grads are
    summed in fp32, and the optimizer runs once — bounding live activation
    memory at large (batch × seq) without touching the model code.
    """

    grad_fn = jax.value_and_grad(lambda p, b: model.loss(p, b, remat=remat), has_aux=True)

    def _apply(state, grads, metrics):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = linear_warmup_cosine(state.step, base_lr, warmup_steps, total_steps)
        params, opt = adamw_update(state.params, grads, state.opt, lr,
                                   weight_decay=weight_decay)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return TrainState(step=state.step + 1, params=params, opt=opt), metrics

    def train_step(state: TrainState, batch) -> tuple[TrainState, Dict[str, jax.Array]]:
        if microbatches <= 1:
            (_, metrics), grads = grad_fn(state.params, batch)
            return _apply(state, grads, metrics)

        mb_batch = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:]),
            batch,
        )
        first = jax.tree_util.tree_map(lambda x: x[0], mb_batch)
        out_shape = jax.eval_shape(grad_fn, state.params, first)
        (_, metrics_shape), grads_shape = out_shape
        gzero = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, jnp.float32), grads_shape)
        mzero = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, jnp.float32), metrics_shape)

        def body(carry, mb):
            gacc, macc = carry
            (_, metrics), grads = grad_fn(state.params, mb)
            gacc = jax.tree_util.tree_map(lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            macc = jax.tree_util.tree_map(lambda a, m: a + m.astype(jnp.float32), macc, metrics)
            return (gacc, macc), None

        (gsum, msum), _ = jax.lax.scan(body, (gzero, mzero), mb_batch)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, gsum)
        metrics = jax.tree_util.tree_map(lambda m: m / microbatches, msum)
        return _apply(state, grads, metrics)

    return train_step


def init_train_state(model, rng) -> TrainState:
    params = model.init_params(rng)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params, opt=adamw_init(params))


class Trainer:
    """Restartable trainer: run(n_steps) survives injected faults by
    restoring the last committed checkpoint and replaying the (deterministic)
    data stream."""

    def __init__(
        self,
        model,
        dataset,
        ckpt_dir: str,
        *,
        train_step: Optional[Callable] = None,
        ckpt_every: int = 50,
        rng_seed: int = 0,
        donate: bool = True,
        watchdog: Optional[StragglerWatchdog] = None,
        shardings: Any = None,
    ):
        self.model = model
        self.dataset = dataset
        self.ckpt = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.rng_seed = rng_seed
        self.watchdog = watchdog or StragglerWatchdog()
        step_fn = train_step or make_train_step(model)
        self._step = jax.jit(step_fn, donate_argnums=(0,) if donate else ())
        self.shardings = shardings
        self.metrics_history: list[Dict[str, float]] = []

    def _init_state(self) -> TrainState:
        return init_train_state(self.model, jax.random.PRNGKey(self.rng_seed))

    def restore_or_init(self) -> TrainState:
        template = jax.eval_shape(self._init_state)
        step, state = self.ckpt.restore_or_init(template, self._init_state, self.shardings)
        if step:
            log.info("restored checkpoint at step %d", step)
        return state

    def run(
        self,
        n_steps: int,
        *,
        fault_hook: Optional[Callable[[int], None]] = None,
        max_restarts: int = 3,
    ) -> TrainState:
        restarts = 0
        while True:
            try:
                state = self.restore_or_init()
                state = self._run_from(state, n_steps, fault_hook)
                self.ckpt.wait()
                return state
            except _InjectedFault:
                restarts += 1
                if restarts > max_restarts:
                    raise
                log.warning("fault at restart #%d — restoring and continuing", restarts)
                continue

    def _run_from(self, state: TrainState, n_steps: int, fault_hook) -> TrainState:
        start = int(state.step)
        for step in range(start, n_steps):
            if fault_hook is not None:
                fault_hook(step)  # may raise _InjectedFault
            batch = {k: jnp.asarray(v) for k, v in self.dataset.batch_at(step).items()}
            t0 = time.perf_counter()
            state, metrics = self._step(state, batch)
            jax.block_until_ready(metrics["loss"])
            self.watchdog.record(step, time.perf_counter() - t0)
            self.metrics_history.append({k: float(v) for k, v in metrics.items()})
            if (step + 1) % self.ckpt_every == 0 or step + 1 == n_steps:
                self.ckpt.save_async(step + 1, state)
        return state


class _InjectedFault(RuntimeError):
    """Raised by test fault hooks to simulate a node failure."""


def injected_fault() -> RuntimeError:
    return _InjectedFault("injected fault")
