"""Gradient compression: int8 quantization with error feedback.

Used on the data-parallel all-reduce path: each leaf is quantized to int8
with a per-leaf fp32 scale before the cross-replica sum, and the
quantization error is carried into the next step (error feedback keeps
SGD/Adam convergence).  The shard_map DP step below demonstrates the full
pattern with manual collectives; the GSPMD production path keeps fp32
reduction by default (compression is opt-in, benchmarked in EXPERIMENTS.md).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def int8_compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (f32/bf16) → (int8 values, f32 scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(tree):
    return jax.tree_util.tree_map(int8_compress, tree)


def dp_allreduce_compressed(grads: Any, axis_name: str) -> Any:
    """Mean-reduce a gradient pytree across ``axis_name`` with int8 payloads.

    A shared scale (pmax of per-replica maxima — a scalar all-reduce) makes
    the int32 accumulation exact up to per-replica rounding; the int8 payload
    is 4× smaller than f32 on the wire.
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g):
        g32 = g.astype(jnp.float32)
        gmax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.maximum(gmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        # widen to int32 for overflow-free summation across replicas
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (summed.astype(jnp.float32) * scale / n).astype(g.dtype)

    return jax.tree_util.tree_map(leaf, grads)


def dp_allreduce_compressed_ef(grads: Any, errors: Any, axis_name: str) -> Tuple[Any, Any]:
    """Error-feedback variant: compresses (grad + carried error), returns
    (reduced grads, new error residuals)."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        gmax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.maximum(gmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_err = g32 - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        reduced = summed.astype(jnp.float32) * scale / n
        return reduced.astype(g.dtype), new_err

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])
