"""Fault-tolerant distributed runtime: train state/step, restartable loop,
straggler watchdog, gradient compression."""

from .loop import TrainState, Trainer, make_train_step
from .compression import int8_compress, int8_decompress

__all__ = ["TrainState", "Trainer", "make_train_step", "int8_compress", "int8_decompress"]
