"""Fault-tolerant distributed runtime: train state/step, restartable loop,
straggler watchdog, process supervision, gradient compression."""

from .loop import TrainState, Trainer, make_train_step
from .compression import int8_compress, int8_decompress
from .supervise import (
    RestartPolicy,
    StragglerWatchdog,
    Supervisor,
    SupervisorGaveUp,
    WatchdogStats,
    http_ready,
)

__all__ = [
    "RestartPolicy",
    "StragglerWatchdog",
    "Supervisor",
    "SupervisorGaveUp",
    "TrainState",
    "Trainer",
    "WatchdogStats",
    "http_ready",
    "int8_compress",
    "int8_decompress",
    "make_train_step",
]
