"""Core NN layers in pure JAX: params are nested dicts of arrays, with a
parallel ParamSpec tree carrying logical sharding axes (see parallel/sharding).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import with_logical_constraint


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: str = "float32"
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None  # default: 1/sqrt(fan_in)

    def initializer(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        scale = self.scale
        if scale is None:
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(self.dtype)


def init_param_tree(specs: Any, rng: jax.Array) -> Any:
    """Materialize a ParamSpec pytree deterministically (path-keyed folds)."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )[0]
    treedef = jax.tree_util.tree_structure(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    out = []
    for path, spec in leaves_with_paths:
        path_str = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        key = jax.random.fold_in(rng, int(np.uint32(hash(path_str) & 0xFFFFFFFF)))
        out.append(spec.initializer(key))
    return jax.tree_util.tree_unflatten(treedef, out)


def spec_tree_shapes(specs: Any) -> Any:
    """ParamSpec tree → ShapeDtypeStruct tree (for dry-run lowering)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(d: int) -> Dict[str, ParamSpec]:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_spec, rmsnorm
    if kind == "layernorm":
        return layernorm_spec, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Dense / embeddings
# ---------------------------------------------------------------------------


def dense_spec(d_in: int, d_out: int, logical: Tuple[Optional[str], Optional[str]],
               use_bias: bool = False, out_logical: Optional[str] = None) -> Dict[str, ParamSpec]:
    spec = {"kernel": ParamSpec((d_in, d_out), logical)}
    if use_bias:
        spec["bias"] = ParamSpec((d_out,), (logical[1],), init="zeros")
    return spec


def dense(params, x, compute_dtype=None):
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
    # mixed precision: fp32 master weights cast to the activation dtype
    k = params["kernel"].astype(x.dtype)
    y = x @ k
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


def embedding_spec(vocab: int, d: int) -> Dict[str, ParamSpec]:
    return {"embedding": ParamSpec((vocab, d), ("vocab", "embed"), scale=1.0)}


def embed(params, tokens, dtype):
    return params["embedding"].astype(dtype)[tokens]


def unembed(params, x):
    """Logits head (optionally tied to the embedding)."""
    return x @ params["embedding"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# Activations / MLP
# ---------------------------------------------------------------------------


def _act(kind: str, x):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def mlp_spec(d: int, d_ff: int, activation: str, use_bias: bool) -> Dict[str, Any]:
    if activation in ("swiglu", "geglu"):
        return {
            "wi": dense_spec(d, d_ff, ("embed", "mlp"), use_bias),
            "wg": dense_spec(d, d_ff, ("embed", "mlp"), use_bias),
            "wo": dense_spec(d_ff, d, ("mlp", "embed"), use_bias),
        }
    return {
        "wi": dense_spec(d, d_ff, ("embed", "mlp"), use_bias),
        "wo": dense_spec(d_ff, d, ("mlp", "embed"), use_bias),
    }


def mlp(params, x, activation: str):
    if activation in ("swiglu", "geglu"):
        act = "silu" if activation == "swiglu" else "gelu"
        h = _act(act, dense(params["wg"], x)) * dense(params["wi"], x)
    else:
        h = _act("gelu" if activation == "gelu" else "silu", dense(params["wi"], x))
    h = with_logical_constraint(h, ("batch",) + (None,) * (h.ndim - 2) + ("mlp",))
    return dense(params["wo"], h)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((seq, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
