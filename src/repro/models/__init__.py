"""LM architecture zoo (assigned pool): pure-JAX models with logical-axis
sharding annotations, scan-over-layers stacks, and KV/state caches."""

from .model import LM, build_model

__all__ = ["LM", "build_model"]
