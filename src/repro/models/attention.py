"""Attention: GQA + RoPE, causal / sliding-window / cross, three impls.

* ``naive``   — materializes (S, S) scores; reference for tests.
* ``chunked`` — lax.scan over KV chunks with online softmax (flash-style in
  pure JAX): O(S·C) live memory, compiles on any backend — the default for
  the 32k/500k dry-run shapes.
* ``pallas``  — the hand TPU kernel in repro.kernels.flash_attention (MXU
  tiled, same math), selected on TPU or via config; validated against
  ``naive`` in interpret mode.

Shapes: q (B, S, H, Dh); k/v (B, Skv, Kh, Dh) with H = G·Kh (GQA).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import with_logical_constraint

from .layers import ParamSpec, rope, softcap

NEG_INF = -1e30


def attention_spec(d: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   use_bias: bool = False) -> Dict[str, Any]:
    return {
        "wq": {"kernel": ParamSpec((d, n_heads, head_dim), ("embed", "heads", "head_dim"))},
        "wk": {"kernel": ParamSpec((d, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"))},
        "wv": {"kernel": ParamSpec((d, n_kv_heads, head_dim), ("embed", "kv_heads", "head_dim"))},
        "wo": {"kernel": ParamSpec((n_heads, head_dim, d), ("heads", "head_dim", "embed"))},
        **({"bq": ParamSpec((n_heads, head_dim), ("heads", "head_dim"), init="zeros"),
            "bk": ParamSpec((n_kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros"),
            "bv": ParamSpec((n_kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")}
           if use_bias else {}),
    }


def qkv_project(params, x) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]["kernel"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"]["kernel"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"]["kernel"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return q, k, v


def out_project(params, o) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"]["kernel"].astype(o.dtype))


def _expand_gqa(q: jax.Array, kh: int) -> jax.Array:
    """(B, S, H, Dh) → (B, S, Kh, G, Dh)."""
    b, s, h, dh = q.shape
    return q.reshape(b, s, kh, h // kh, dh)


# ---------------------------------------------------------------------------
# naive reference
# ---------------------------------------------------------------------------


def attend_naive(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,
    window: Optional[int] = None,
    cap: Optional[float] = None,
) -> jax.Array:
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    qg = _expand_gqa(q, kh).astype(jnp.float32)
    scale = float(1.0 / np.sqrt(dh))
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    scores = softcap(scores, cap)
    skv = k.shape[1]
    qpos = jnp.arange(sq) + q_offset  # (Sq,)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked (flash-style, pure JAX)
# ---------------------------------------------------------------------------


def attend_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    chunk: int = 1024,
) -> jax.Array:
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    skv = k.shape[1]
    chunk = min(chunk, skv)
    nchunks = -(-skv // chunk)
    pad = nchunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    eff_len = jnp.asarray(kv_len if kv_len is not None else skv)

    qg = _expand_gqa(q, kh).astype(jnp.float32)  # (B, Sq, Kh, G, Dh)
    scale = float(1.0 / np.sqrt(dh))
    qpos = (jnp.arange(sq) + q_offset).astype(jnp.int32)

    kc = k.reshape(b, nchunks, chunk, kh, dh)
    vc = v.reshape(b, nchunks, chunk, kh, dh)

    def body(carry, inputs):
        acc, m, lsum = carry  # acc (B,Sq,Kh,G,Dh) f32; m,lsum (B,Sq,Kh,G)
        kb, vb, c_idx = inputs  # kb/vb (B, C, Kh, Dh)
        kpos = c_idx * chunk + jnp.arange(chunk, dtype=jnp.int32)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb.astype(jnp.float32)) * scale
        s = softcap(s, cap)
        valid = kpos[None, :] < eff_len  # (Sq-broadcast, C)
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            valid = valid & (kpos[None, :] > (qpos[:, None] - window))
        s = jnp.where(valid[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = lsum * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, sq, kh, h // kh, dh), jnp.float32)
    m0 = jnp.full((b, sq, kh, h // kh), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, h // kh), jnp.float32)
    xs = (
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.arange(nchunks, dtype=jnp.int32),
    )
    (acc, m, lsum), _ = jax.lax.scan(body, (acc0, m0, l0), xs)
    o = acc / jnp.maximum(lsum[..., None], 1e-37)
    return o.reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def attend(
    q, k, v, *, impl: str = "chunked", causal: bool = True, q_offset=0,
    kv_len=None, window=None, cap=None, chunk: int = 1024,
):
    if impl == "naive":
        return attend_naive(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
                            window=window, cap=cap)
    if impl == "chunked":
        return attend_chunked(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
                              window=window, cap=cap, chunk=chunk)
    if impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
                               window=window, cap=cap)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# full attention layer (projections + rope + attend), with KV-cache support
# ---------------------------------------------------------------------------


def self_attention(
    params,
    x,
    *,
    n_kv_heads: int,
    rope_theta: Optional[float],
    impl: str,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    chunk: int = 1024,
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
):
    """Returns (out, new_cache). ``cache``: {'k','v': (B, Smax, Kh, Dh), 'pos': ()}."""
    b, s, _ = x.shape
    q, k, v = qkv_project(params, x)
    if positions is None:
        if cache is not None:
            positions = cache["pos"] + jnp.arange(s, dtype=jnp.int32)[None, :]
        else:
            positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    if rope_theta is not None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    if s > 1:
        # context-parallel attention (train/prefill): q sequence-sharded over
        # the model axis, kv replicated — every score einsum is local, which
        # removes the GQA resharding storms when head counts don't divide
        # the TP degree (§Perf iteration 1)
        q = with_logical_constraint(q, ("batch", "attn_seq", "heads", "head_dim"))
        k = with_logical_constraint(k, ("batch", None, None, None))
        v = with_logical_constraint(v, ("batch", None, None, None))
    new_cache = None
    if cache is not None:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache["pos"], axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache["pos"], axis=1)
        new_cache = {"k": kc, "v": vc, "pos": cache["pos"] + s}
        if s > 1:
            # chunked prefill: the cache was empty (pos = 0); attend against
            # the fresh replicated k/v instead of the seq-sharded cache
            o = attend(q, k, v, impl=impl, causal=causal, window=window, cap=cap,
                       chunk=chunk)
        else:
            # decode: flash-decode style — kv cache sequence-sharded over the
            # model axis; scores/partial softmax local, tiny all-reduces
            o = attend(q, kc, vc, impl="naive", causal=causal, q_offset=cache["pos"],
                       kv_len=cache["pos"] + s, window=window, cap=cap)
        o = with_logical_constraint(o, ("batch", "attn_seq" if s > 1 else None,
                                        "heads", "head_dim"))
    else:
        o = attend(q, k, v, impl=impl, causal=causal, window=window, cap=cap, chunk=chunk)
        o = with_logical_constraint(o, ("batch", "attn_seq", "heads", "head_dim"))
    return out_project(params, o), new_cache


def cross_attention_spec(d: int, n_heads: int, n_kv_heads: int, head_dim: int) -> Dict[str, Any]:
    return attention_spec(d, n_heads, n_kv_heads, head_dim)


def cross_attention(params, x, enc_kv: Tuple[jax.Array, jax.Array], impl: str, chunk: int = 1024):
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"]["kernel"].astype(x.dtype))
    k, v = enc_kv
    o = attend(q, k, v, impl=impl, causal=False, chunk=chunk)
    return out_project(params, o)


def encoder_kv(params, enc_out) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"]["kernel"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"]["kernel"].astype(enc_out.dtype))
    return k, v


def make_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int, dtype) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
