"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch is sort-based (argsort by expert id + capacity clamp) rather than
GShard one-hot einsums: the one-hot dispatch tensor is O(T²) at 4k–32k
sequence lengths, while sort-based stays O(T·k + E·C·D) and maps onto an
expert-parallel ('experts' → model axis) mesh, where the gathered (E, C, D)
buffer becomes the all-to-all payload.

Aux losses (load-balance, router-z) follow Switch/ST-MoE.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.parallel.sharding import with_logical_constraint

from .layers import ParamSpec, mlp, mlp_spec


def moe_spec(d: int, cfg: MoEConfig, activation: str, use_bias: bool) -> Dict[str, Any]:
    e, f = cfg.n_experts, cfg.d_ff_expert
    mult_gated = activation in ("swiglu", "geglu")
    spec: Dict[str, Any] = {
        "router": {"kernel": ParamSpec((d, e), ("embed", "experts"), dtype="float32")},
        "wi": ParamSpec((e, d, f), ("experts", "embed", "mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed")),
    }
    if mult_gated:
        spec["wg"] = ParamSpec((e, d, f), ("experts", "embed", "mlp"))
    if cfg.shared_d_ff:
        spec["shared"] = mlp_spec(d, cfg.shared_d_ff, activation, use_bias)
    return spec


def _expert_ffn_batched(params, x, activation: str):
    """x: (B, E, C, D) → (B, E, C, D); E shards over model, B over data."""
    wi = params["wi"].astype(x.dtype)
    wo = params["wo"].astype(x.dtype)
    h = jnp.einsum("becd,edf->becf", x, wi)
    if "wg" in params:
        g = jnp.einsum("becd,edf->becf", x, params["wg"].astype(x.dtype))
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    h = with_logical_constraint(h, ("batch", "experts", None, "mlp"))
    return jnp.einsum("becf,efd->becd", h, wo)


def moe_layer(
    params,
    x: jax.Array,
    cfg: MoEConfig,
    activation: str,
    *,
    capacity: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) → (out (B, S, D), aux-loss dict).

    Dispatch is **row-local** (per sequence, §Perf iteration 4): every
    sequence routes its own S·k assignments with its own capacity, so the
    sort/cumsum/scatter machinery is batched over B and stays sharded over
    the data axis, while the (B, E, C, D) expert buffers shard E over the
    model axis — the only cross-shard movement is the implicit
    data↔expert all-to-all on the (small) buffers.  A global-sort dispatch
    forces GSPMD to replicate (T·k, D) tensors (measured: a 6 GiB f32
    all-reduce per layer per microbatch on moonshot train_4k).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    # ---- routing (fp32 for numerics)
    logits = x.astype(jnp.float32) @ params["router"]["kernel"]  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch/ST-MoE)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=2), axis=(0, 1)
    )
    load_balance = e * jnp.sum(me * ce) / k
    router_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {
        "load_balance_loss": cfg.load_balance_coef * load_balance,
        "router_z_loss": cfg.router_z_coef * router_z,
    }

    # ---- row-local sort-based dispatch with capacity clamp
    if capacity is None:
        capacity = int(cfg.capacity_factor * s * k / e + 1)
    capacity = min(capacity, s)

    out = _dispatch_ffn_combine(params, x, expert_idx, gate_vals, capacity,
                                cfg, activation)

    if cfg.shared_d_ff:
        out = out + mlp(params["shared"], x, activation).astype(jnp.float32)

    return out.astype(x.dtype), aux


def _dispatch_combine_local(params, x, expert_idx, gate_vals, capacity: int,
                            e: int, k: int, activation: str,
                            ffn=None, expert_offset=0, e_local=None) -> jax.Array:
    """Row-local dispatch → expert FFN → (partial) combine.

    Pure function of local shards; every op batches over B (no cross-row
    indexing).  With ``expert_offset``/``e_local`` set, only the local
    expert slice is buffered/computed/combined — the caller psums partial
    outputs over the expert-parallel axis."""
    b, s, d = x.shape
    tk = s * k
    e_local = e_local if e_local is not None else e
    flat_expert = expert_idx.reshape(b, tk)
    flat_gate = gate_vals.reshape(b, tk)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None], (b, tk)
    )

    order = jnp.argsort(flat_expert, axis=1, stable=True)  # (B, S·k)
    se = jnp.take_along_axis(flat_expert, order, axis=1)
    sg = jnp.take_along_axis(flat_gate, order, axis=1)
    stok = jnp.take_along_axis(flat_token, order, axis=1)
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    counts = jnp.zeros((b, e), jnp.int32).at[rows, se].add(1)
    group_start = jnp.cumsum(counts, axis=1) - counts  # (B, E)
    pos = jnp.arange(tk, dtype=jnp.int32)[None] - jnp.take_along_axis(group_start, se, axis=1)
    keep = pos < capacity

    se_loc = se - expert_offset
    in_range = keep & (se_loc >= 0) & (se_loc < e_local)
    slot = jnp.where(in_range, se_loc * capacity + pos, e_local * capacity - 1)
    x_tok = jnp.take_along_axis(x, stok[..., None], axis=1)  # (B, S·k, D)
    buf = jnp.zeros((b, e_local * capacity, d), x.dtype)
    buf = buf.at[rows, slot].add(jnp.where(in_range[..., None], x_tok, 0).astype(x.dtype))
    buf = buf.reshape(b, e_local, capacity, d)

    y = (ffn or _expert_ffn_batched_local)(params, buf, activation)  # (B, E_loc, C, D)
    y = y.reshape(b, e_local * capacity, d)

    vals = jnp.where(in_range[..., None], jnp.take_along_axis(y, slot[..., None], axis=1), 0)
    out = jnp.zeros((b, s, d), jnp.float32)
    return out.at[rows, stok].add(vals.astype(jnp.float32) * sg[..., None])


def _dispatch_ffn_combine(params, x, expert_idx, gate_vals, capacity: int,
                          cfg: MoEConfig, activation: str) -> jax.Array:
    """Expert-parallel dispatch (§Perf iterations 4–6).

    With a mesh whose 'model' axis divides E, the whole dispatch → FFN →
    combine runs inside shard_map: sorts/scatters are rank-local and the
    data↔expert movement is exactly two all-to-alls of the (B, E, C, D)
    buffers.  Under plain GSPMD, cross-sharding scatters replicate
    (T·k, D)-sized tensors (measured 6–15 TiB of all-reduce per step on
    moonshot train_4k).  Falls back to the local path without a mesh.
    """
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import current_mesh

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    mesh = current_mesh()
    ep = int(mesh.shape.get("model", 1)) if mesh is not None else 1
    batch_axes = tuple(a for a in ("pod", "data") if a in (mesh.axis_names if mesh else ()))
    dp = 1
    for a in batch_axes:
        dp *= int(mesh.shape[a])
    if mesh is None or ep <= 1 or e % ep != 0 or b % max(dp, 1) != 0:
        return _dispatch_combine_local(params, x, expert_idx, gate_vals, capacity,
                                       e, k, activation)

    e_local = e // ep

    def body(x_l, ei_l, gv_l, wi, wg, wo):
        # x is replicated across the model axis within each data group, so
        # each model rank computes ONLY its expert slice for its rows and the
        # partial outputs psum over 'model' (§Perf iteration 7) — no
        # all-to-all, no row duplication.
        p = {"wi": wi, "wo": wo}
        if wg is not None:
            p["wg"] = wg
        offset = jax.lax.axis_index("model") * e_local
        partial = _dispatch_combine_local(p, x_l, ei_l, gv_l, capacity, e, k,
                                          activation, expert_offset=offset,
                                          e_local=e_local)
        return jax.lax.psum(partial, "model")

    has_wg = "wg" in params
    data_spec = P(batch_axes, None, None)
    w_spec = P("model", None, None)
    if has_wg:
        fn = jax.shard_map(body, mesh=mesh,
                           in_specs=(data_spec, data_spec, data_spec, w_spec, w_spec, w_spec),
                           out_specs=data_spec, check_vma=False)
        return fn(x, expert_idx, gate_vals, params["wi"], params["wg"], params["wo"])

    def body_nog(x_l, ei_l, gv_l, wi, wo):
        return body(x_l, ei_l, gv_l, wi, None, wo)

    fn = jax.shard_map(body_nog, mesh=mesh,
                       in_specs=(data_spec, data_spec, data_spec, w_spec, w_spec),
                       out_specs=data_spec, check_vma=False)
    return fn(x, expert_idx, gate_vals, params["wi"], params["wo"])


def _expert_ffn_batched_local(params, x, activation: str):
    """(B, E_loc, C, D) FFN on already-local expert weights (no constraints)."""
    wi = params["wi"].astype(x.dtype)
    wo = params["wo"].astype(x.dtype)
    h = jnp.einsum("becd,edf->becf", x, wi)
    if "wg" in params and params["wg"] is not None:
        g = jnp.einsum("becd,edf->becf", x, params["wg"].astype(x.dtype))
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("becf,efd->becd", h, wo)
