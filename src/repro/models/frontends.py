"""Modality frontends — STUBS per the assignment spec.

``[audio]``/``[vlm]`` architectures specify the transformer *backbone* only;
``input_specs()`` provides precomputed frame/patch embeddings.  The stubs
here are a linear adapter + (for audio) fixed sinusoidal positions, standing
in for the conv feature extractor / ViT tower.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from .layers import ParamSpec, sinusoidal_positions


def frontend_spec(cfg: ArchConfig) -> Dict[str, Any]:
    if cfg.frontend == "audio":
        # conv1/conv2 feature extractor is stubbed by a linear adapter over
        # precomputed frame embeddings (B, S_enc, d_model)
        return {"adapter": {"kernel": ParamSpec((cfg.d_model, cfg.d_model), ("embed", "embed"))}}
    if cfg.frontend == "vision":
        # InternViT tower stub: patch embeddings arrive precomputed,
        # mapped through the MLP projector into backbone space
        return {"adapter": {"kernel": ParamSpec((cfg.d_model, cfg.d_model), ("embed", "embed"))}}
    return {}


def apply_frontend(params, cfg: ArchConfig, feats: jax.Array) -> jax.Array:
    """feats: (B, S_enc, d_model) precomputed embeddings → backbone inputs."""
    x = feats.astype(cfg.dtype) @ params["adapter"]["kernel"].astype(cfg.dtype)
    if cfg.frontend == "audio":
        pos = jnp.asarray(sinusoidal_positions(feats.shape[1], cfg.d_model), cfg.dtype)
        x = x + pos[None]
    return x
