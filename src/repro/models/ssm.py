"""Mamba-2 (SSD — state-space duality) block in pure JAX.

Chunked SSD algorithm (Dao & Gu 2024): the sequence is split into chunks of
Q tokens; within a chunk the output is a masked quadratic (attention-like)
term; across chunks a (H, N, P) state is carried by a sequential scan —
linear in S, matmul-rich (MXU-friendly), and O(1)-state for decode.

Decode carries {conv tail (B, d_conv-1, d_xBC), state (B, H, N, P)}.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.parallel.sharding import with_logical_constraint

from .layers import ParamSpec


def ssd_spec(d_model: int, cfg: SSMConfig) -> Dict[str, Any]:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    d_xbc = di + 2 * gn
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "w_in": {"kernel": ParamSpec((d_model, di + d_xbc + nh), ("embed", "mlp"))},
        "conv_w": ParamSpec((cfg.d_conv, d_xbc), (None, "conv_io")),
        "conv_b": ParamSpec((d_xbc,), ("conv_io",), init="zeros"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "norm_scale": ParamSpec((di,), ("mlp",), init="ones"),
        "w_out": {"kernel": ParamSpec((di, d_model), ("mlp", "embed"))},
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B, S, C); w: (K, C). Returns (y, new_tail)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_tail = xp[:, xp.shape[1] - (k - 1) :, :]
    return jax.nn.silu(y + b[None, None, :]), new_tail


def _ssd_chunked(x, dt, A, B, C, D, chunk: int, state0: Optional[jax.Array] = None):
    """Core SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (softplus'd); A: (H,) (negative);
    B, C: (B, S, G, N); D: (H,).  Returns (y (B,S,H,P), final state (B,H,N,P)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # reshape to chunks: (B, nc, Q, ...)
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, g, n)
    Cc = C.reshape(b, nc, q, g, n)
    rep = h // g

    da = dtc * A[None, None, None, :]          # (B, nc, Q, H) log-decay per step
    cum = jnp.cumsum(da, axis=2)               # within-chunk cumulative
    seg_total = cum[:, :, -1, :]                # (B, nc, H)

    # ---- intra-chunk (quadratic within Q): L[i,j] = exp(cum_i - cum_j) · (i >= j)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Q,Q,H)
    ii = jnp.arange(q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    Bh = jnp.repeat(Bc, rep, axis=3)            # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bcqhn,bcshn->bcqsh", Ch, Bh)          # (B,nc,Q,Q,H)
    w = scores * L * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w, xc)

    # ---- chunk states: S_c = Σ_j exp(seg_total - cum_j) dt_j B_j ⊗ x_j
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)     # (B,nc,Q,H)
    wB = Bh * (decay_to_end * dtc)[..., None]                  # (B,nc,Q,H,N)
    chunk_states = jnp.einsum("bcqhn,bcqhp->bchnp", wB, xc)    # (B,nc,H,N,P)

    # ---- inter-chunk scan carrying (B,H,N,P)
    if state0 is None:
        state0 = jnp.zeros((b, h, n, p), x.dtype)

    def scan_body(state, inputs):
        seg, cs = inputs  # seg (B,H), cs (B,H,N,P)
        out_state = state  # state BEFORE this chunk
        new_state = state * jnp.exp(seg)[..., None, None] + cs
        return new_state, out_state

    xs = (jnp.moveaxis(seg_total, 1, 0), jnp.moveaxis(chunk_states, 1, 0))
    final_state, prev_states = jax.lax.scan(scan_body, state0, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (B,nc,H,N,P)

    # ---- inter-chunk contribution: y_i += (C_i · S_prev) · exp(cum_i)
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", Ch, prev_states) * jnp.exp(cum)[..., None]

    y = y_intra + y_inter + xc * D[None, None, None, :, None]
    y = y.reshape(b, nc * q, h, p)[:, :s]
    return y, final_state


def ssd_block(
    params,
    x: jax.Array,
    cfg: SSMConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-2 block. x: (B, S, D). cache: {'conv', 'state'} for decode."""
    b, s, d_model = x.shape
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    d_xbc = di + 2 * gn

    proj = x @ params["w_in"]["kernel"].astype(x.dtype)  # (B,S, di + d_xbc + nh)
    z, xbc, dt_raw = jnp.split(proj, [di, di + d_xbc], axis=-1)

    conv_tail = cache["conv"] if cache is not None else None
    xbc, new_tail = _causal_conv(xbc, params["conv_w"].astype(x.dtype),
                                 params["conv_b"].astype(x.dtype), conv_tail)
    xs, B, C = jnp.split(xbc, [di, di + gn], axis=-1)
    xs = xs.reshape(b, s, nh, cfg.head_dim)
    B = B.reshape(b, s, cfg.n_groups, cfg.d_state)
    C = C.reshape(b, s, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,) negative

    xs = with_logical_constraint(xs, ("batch", "seq", "ssm_heads", None))

    state0 = cache["state"] if cache is not None else None
    y, final_state = _ssd_chunked(
        xs.astype(jnp.float32), dt, A, B.astype(jnp.float32), C.astype(jnp.float32),
        params["D"].astype(jnp.float32), cfg.chunk,
        state0=None if state0 is None else state0.astype(jnp.float32),
    )
    y = y.reshape(b, s, di).astype(x.dtype)

    # gated RMSNorm (Mamba-2)
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y32 = y32 * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"].astype(jnp.float32)
    y = y32.astype(x.dtype)

    out = y @ params["w_out"]["kernel"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail, "state": final_state.astype(cache["state"].dtype)}
    return out, new_cache


def make_ssd_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> Dict[str, jax.Array]:
    di = cfg.d_inner(d_model)
    nh = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di + 2 * gn), dtype),
        "state": jnp.zeros((batch, nh, cfg.d_state, cfg.head_dim), dtype),
    }
