"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The recurrence h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t) is exactly the
DSL's ``computation(FORWARD)`` pattern (DESIGN.md §4): sequential in one
axis, parallel in all others.  Training/prefill uses an associative scan
(log-depth); decode is a single fused step.  A Pallas chunked-scan kernel
(repro.kernels.rglru) provides the TPU fast path.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.parallel.sharding import with_logical_constraint

from .layers import ParamSpec

_C = 8.0  # Griffin's fixed exponent scale


def rglru_block_spec(d_model: int, cfg: RGLRUConfig) -> Dict[str, Any]:
    dr = cfg.d_rnn or int(1.5 * d_model)
    return {
        # two input branches (recurrent + gate), GeGLU-style
        "w_x": {"kernel": ParamSpec((d_model, dr), ("embed", "mlp"))},
        "w_gate": {"kernel": ParamSpec((d_model, dr), ("embed", "mlp"))},
        "conv_w": ParamSpec((cfg.d_conv, dr), (None, "conv_io")),
        "conv_b": ParamSpec((dr,), ("conv_io",), init="zeros"),
        # RG-LRU gates
        "w_input_gate": ParamSpec((dr,), ("mlp",), init="zeros"),
        "b_input_gate": ParamSpec((dr,), ("mlp",), init="zeros"),
        "w_rec_gate": ParamSpec((dr,), ("mlp",), init="zeros"),
        "b_rec_gate": ParamSpec((dr,), ("mlp",), init="zeros"),
        "lambda_param": ParamSpec((dr,), ("mlp",), init="ones"),
        "w_out": {"kernel": ParamSpec((dr, d_model), ("mlp", "embed"))},
    }


def _rglru_scan(x: jax.Array, a: jax.Array, h0: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t·h_{t−1} + x_t via associative scan over S. x,a: (B,S,D)."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    if h0 is not None:
        # fold initial state into the first element
        x = x.at[:, 0, :].add(a[:, 0, :] * h0)
    aa, hh = jax.lax.associative_scan(combine, (a, x), axis=1)
    return hh, hh[:, -1, :]


def rglru(
    params, x: jax.Array, *, h0: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """Core RG-LRU over (B, S, Dr). Returns (y, final h)."""
    x32 = x.astype(jnp.float32)
    gate_in = jax.nn.sigmoid(x32 * params["w_input_gate"] + params["b_input_gate"])
    gate_rec = jax.nn.sigmoid(x32 * params["w_rec_gate"] + params["b_rec_gate"])
    log_a = -_C * gate_rec * jax.nn.softplus(params["lambda_param"])
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = mult * gate_in * x32
    h, h_last = _rglru_scan(gated, a, None if h0 is None else h0.astype(jnp.float32))
    return h.astype(x.dtype), h_last.astype(x.dtype)


def rglru_step(params, x: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step. x, h: (B, Dr)."""
    x32 = x.astype(jnp.float32)
    gate_in = jax.nn.sigmoid(x32 * params["w_input_gate"] + params["b_input_gate"])
    gate_rec = jax.nn.sigmoid(x32 * params["w_rec_gate"] + params["b_rec_gate"])
    log_a = -_C * gate_rec * jax.nn.softplus(params["lambda_param"])
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h_new = a * h.astype(jnp.float32) + mult * gate_in * x32
    return h_new.astype(x.dtype), h_new.astype(x.dtype)


def _causal_conv(x, w, b, tail):
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return y + b[None, None, :], xp[:, xp.shape[1] - (k - 1) :, :]


def rglru_block(
    params,
    x: jax.Array,
    cfg: RGLRUConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full recurrent block: in-proj ∥ gate, conv1d, RG-LRU, gated out-proj.

    cache: {'conv': (B, d_conv−1, Dr), 'h': (B, Dr)} for decode.
    """
    gate = jax.nn.gelu(x @ params["w_gate"]["kernel"].astype(x.dtype))
    xr = x @ params["w_x"]["kernel"].astype(x.dtype)
    tail = cache["conv"] if cache is not None else None
    xr, new_tail = _causal_conv(xr, params["conv_w"].astype(x.dtype),
                                params["conv_b"].astype(x.dtype), tail)
    xr = with_logical_constraint(xr, ("batch", "seq", "mlp"))
    h0 = cache["h"] if cache is not None else None
    y, h_last = rglru(params, xr, h0=h0)
    out = (y * gate) @ params["w_out"]["kernel"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail, "h": h_last}
    return out, new_cache


def make_rglru_cache(batch: int, d_model: int, cfg: RGLRUConfig, dtype) -> Dict[str, jax.Array]:
    dr = cfg.d_rnn or int(1.5 * d_model)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, dr), dtype),
        "h": jnp.zeros((batch, dr), dtype),
    }
