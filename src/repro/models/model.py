"""Model facade: spec/init/loss/prefill/decode for every assigned family.

`build_model(cfg)` returns an :class:`LM` whose methods are pure functions
of (params, batch/cache) — ready for jit / shard_map / the dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import with_logical_constraint

from . import attention as attn_mod
from . import frontends, transformer
from .layers import (
    ParamSpec,
    embed,
    embedding_spec,
    init_param_tree,
    make_norm,
    softcap,
    spec_tree_shapes,
    unembed,
)
from .rglru import make_rglru_cache
from .ssm import make_ssd_cache


@dataclass
class LM:
    cfg: ArchConfig

    # ------------------------------------------------------------------ specs

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        norm_spec, _ = make_norm(cfg.norm)
        spec: Dict[str, Any] = {
            "embed": embedding_spec(cfg.padded_vocab, cfg.d_model),
            "final_norm": norm_spec(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = {"kernel": ParamSpec((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"))}
        if cfg.is_encdec:
            spec["frontend"] = frontends.frontend_spec(cfg)
            spec["encoder"] = transformer.encoder_stack_spec(cfg)
            spec["enc_norm"] = norm_spec(cfg.d_model)
            spec["decoder"] = transformer.xdec_stack_spec(cfg)
            spec["dec_pos_embed"] = ParamSpec((8192, cfg.d_model), (None, "embed"), scale=0.01)
        else:
            if cfg.frontend:
                spec["frontend"] = frontends.frontend_spec(cfg)
            spec["decoder"] = transformer.decoder_stack_spec(cfg)
        return spec

    def init_params(self, rng: jax.Array) -> Dict[str, Any]:
        return init_param_tree(self.param_specs(), rng)

    def param_shapes(self) -> Dict[str, Any]:
        return spec_tree_shapes(self.param_specs())

    # ------------------------------------------------------------ embeddings

    def _embed_inputs(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"], cfg.dtype)
        if cfg.frontend == "vision" and "patches" in batch:
            pe = frontends.apply_frontend(params["frontend"], cfg, batch["patches"])
            x = jnp.concatenate([pe, x], axis=1)
        x = with_logical_constraint(x, ("batch", "attn_seq", "embed"))
        return x

    def _logits(self, params, x) -> jax.Array:
        cfg = self.cfg
        _, norm = make_norm(cfg.norm)
        x = norm(params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = x @ params["lm_head"]["kernel"].astype(x.dtype)
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        if cfg.padded_vocab != cfg.vocab:
            # exact semantics: padded vocab rows never receive probability
            pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
            logits = jnp.where(pad_mask, logits, -1e30)
        return with_logical_constraint(logits, ("batch", "attn_seq", "vocab"))

    # ----------------------------------------------------------------- train

    def forward(self, params, batch, *, remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Full-sequence logits. batch: tokens (B,S) [+ frames/patches]."""
        cfg = self.cfg
        if cfg.is_encdec:
            enc_in = frontends.apply_frontend(params["frontend"], cfg, batch["frames"])
            enc = transformer.encoder_stack(params["encoder"], enc_in, cfg, remat=remat)
            _, norm = make_norm(cfg.norm)
            enc = norm(params["enc_norm"], enc)
            enc_kv = self._cross_kv(params, enc)
            x = embed(params["embed"], batch["tokens"], cfg.dtype)
            pos = params["dec_pos_embed"][: x.shape[1]].astype(cfg.dtype)
            x = x + pos[None]
            x, _ = transformer.xdec_stack(params["decoder"], x, cfg, enc_kv=enc_kv, remat=remat)
            return self._logits(params, x), {}
        x = self._embed_inputs(params, batch)
        x, _, aux = transformer.decoder_stack(params["decoder"], x, cfg, remat=remat)
        return self._logits(params, x), aux

    def loss(self, params, batch, *, remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Next-token CE (+ MoE aux). batch needs 'labels' (B, S), -1 = masked."""
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]
        if cfg.frontend == "vision" and "patches" in batch:
            # image positions carry no LM loss
            pads = jnp.full(batch["patches"].shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pads, labels], axis=1)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = ce
        metrics = {"ce_loss": ce, "tokens": jnp.sum(mask)}
        for k, v in aux.items():
            total = total + v
            metrics[k] = v
        metrics["loss"] = total
        return total, metrics

    def _cross_kv(self, params, enc_out):
        """Per-decoder-layer cross K/V, stacked (L, B, S_enc, Kh, Dh)."""

        def kv(lp):
            return attn_mod.encoder_kv(lp["xattn"], enc_out)

        return jax.vmap(kv, in_axes=0, out_axes=0)(params["decoder"]["blocks"])

    # ----------------------------------------------------------------- serve

    def make_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        hd = cfg.resolved_head_dim if cfg.n_heads else 0

        def kv_cache(n):
            return {
                "k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
                "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
            }

        if cfg.is_encdec:
            return {"layers": kv_cache(cfg.n_layers), "pos": jnp.zeros((), jnp.int32)}
        if cfg.family == "ssm":
            base = make_ssd_cache(batch, cfg.d_model, cfg.ssm, cfg.dtype)
            return {
                "layers": jax.tree_util.tree_map(
                    lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), base
                ),
                "pos": jnp.zeros((), jnp.int32),
            }
        if cfg.family == "hybrid":
            pat = cfg.rglru.pattern
            n_groups, rem = divmod(cfg.n_layers, len(pat))

            def layer_cache(kind, stacked_n=None):
                if kind == "rglru":
                    base = make_rglru_cache(batch, cfg.d_model, cfg.rglru, cfg.dtype)
                else:
                    base = {
                        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
                        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cfg.dtype),
                    }
                if stacked_n is None:
                    return base
                return jax.tree_util.tree_map(
                    lambda a: jnp.zeros((stacked_n,) + a.shape, a.dtype), base
                )

            cache: Dict[str, Any] = {
                "groups": {f"{i}_{kind}": layer_cache(kind, n_groups) for i, kind in enumerate(pat)},
                "pos": jnp.zeros((), jnp.int32),
            }
            for r in range(rem):
                kind = pat[r % len(pat)]
                cache[f"tail_{r}_{kind}"] = layer_cache(kind)
            return cache
        return {"layers": kv_cache(cfg.n_layers), "pos": jnp.zeros((), jnp.int32)}

    def _with_pos(self, cache_layers, pos):
        """Distribute the global position scalar into per-layer kv caches."""
        return cache_layers, pos

    def prefill(self, params, batch, cache) -> Tuple[jax.Array, Dict[str, Any]]:
        """Run the prompt through the model, filling ``cache``.

        Returns (logits for the last position (B, vocab), new cache).
        """
        return self._serve(params, batch, cache)

    def decode_step(self, params, batch, cache) -> Tuple[jax.Array, Dict[str, Any]]:
        """One-token step: batch['tokens'] is (B, 1)."""
        return self._serve(params, batch, cache)

    def _serve(self, params, batch, cache):
        cfg = self.cfg
        pos = cache["pos"]
        if cfg.is_encdec:
            if "enc_kv" in batch:
                enc_kv = batch["enc_kv"]
            else:
                enc_in = frontends.apply_frontend(params["frontend"], cfg, batch["frames"])
                enc = transformer.encoder_stack(params["encoder"], enc_in, cfg, remat=False)
                _, norm = make_norm(cfg.norm)
                enc_kv = self._cross_kv(params, norm(params["enc_norm"], enc))
            x = embed(params["embed"], batch["tokens"], cfg.dtype)
            s = x.shape[1]
            posids = pos + jnp.arange(s, dtype=jnp.int32)
            x = x + jnp.take(params["dec_pos_embed"].astype(cfg.dtype), posids, axis=0)[None]
            layer_caches = self._inject_pos(cache["layers"], pos)
            x, new_layers = transformer.xdec_stack(
                params["decoder"], x, cfg, enc_kv=enc_kv, cache=layer_caches, remat=False
            )
            new_cache = {"layers": self._strip_pos(new_layers), "pos": pos + s}
            logits = self._logits(params, x[:, -1:, :])[:, 0]
            return logits, new_cache

        x = self._embed_inputs(params, batch)
        s = x.shape[1]
        if cfg.family in ("ssm",):
            layer_caches = cache["layers"]
            x, new_layers, _ = transformer.decoder_stack(
                params["decoder"], x, cfg, cache=layer_caches, remat=False
            )
            new_cache = {"layers": new_layers, "pos": pos + s}
        elif cfg.family == "hybrid":
            hyb = {}
            for k, v in cache.items():
                if k == "pos":
                    continue
                hyb[k] = self._inject_pos(v, pos, stacked=(k == "groups"))
            x, new_hyb, _ = transformer.decoder_stack(
                params["decoder"], x, cfg, cache=hyb, remat=False
            )
            new_cache = {**self._strip_pos(new_hyb), "pos": pos + s}
        else:
            layer_caches = self._inject_pos(cache["layers"], pos)
            x, new_layers, _ = transformer.decoder_stack(
                params["decoder"], x, cfg, cache=layer_caches, remat=False
            )
            new_cache = {"layers": self._strip_pos(new_layers), "pos": pos + s}
        logits = self._logits(params, x[:, -1:, :])[:, 0]
        return logits, new_cache

    # kv caches used inside blocks carry their own 'pos'; inject/strip the
    # global scalar so the serve-level cache holds it exactly once.  For
    # stacked (scanned) caches the scalar is broadcast to (L,) so lax.scan
    # can slice one per layer.
    def _inject_pos(self, tree, pos, stacked: bool = True):
        def walk(node):
            if isinstance(node, dict):
                if "k" in node and "v" in node:
                    if stacked:
                        n = node["k"].shape[0]
                        return {**node, "pos": jnp.full((n,), pos, jnp.int32)}
                    return {**node, "pos": pos}
                return {k: walk(v) for k, v in node.items()}
            return node

        return walk(tree)

    def _strip_pos(self, tree):
        def walk(node):
            if isinstance(node, dict):
                if set(node.keys()) >= {"k", "v"}:
                    return {k: v for k, v in node.items() if k != "pos"}
                return {k: walk(v) for k, v in node.items()}
            return node

        return walk(tree)


def build_model(cfg: ArchConfig) -> LM:
    return LM(cfg)


def exact_param_count(cfg: ArchConfig) -> int:
    """Exact parameter count from the spec tree (no materialization)."""
    import numpy as np

    specs = LM(cfg).param_specs()
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def active_param_count(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE experts scaled to top-k/E)."""
    import numpy as np

    total = exact_param_count(cfg)
    if cfg.family != "moe":
        return total
    specs = LM(cfg).param_specs()
    expert_leaves = []

    def collect(tree, in_moe):
        if isinstance(tree, ParamSpec):
            return
        if isinstance(tree, dict):
            for k, v in tree.items():
                if k in ("wi", "wg", "wo") and in_moe and isinstance(v, ParamSpec):
                    expert_leaves.append(v)
                else:
                    collect(v, in_moe or k == "moe")

    collect(specs, False)
    expert_total = sum(int(np.prod(s.shape)) for s in expert_leaves)
    frac = cfg.moe.top_k / cfg.moe.n_experts
    return int(total - expert_total * (1.0 - frac))
