"""Block definitions + scan-over-layers stacks for every assigned family.

Uniform stacks (dense / moe / ssm / encoder / decoder) scan over stacked
per-layer parameters — small HLO, fast multi-pod compiles, standard remat.
The hybrid (RecurrentGemma) stack scans over repeating [rglru, rglru, attn]
groups with an unrolled remainder.
"""

from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import with_logical_constraint

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import ParamSpec, make_norm, mlp, mlp_spec


# ---------------------------------------------------------------------------
# spec stacking
# ---------------------------------------------------------------------------


def stack_specs(spec: Any, n: int) -> Any:
    def _stack(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + tuple(s.shape), (None,) + tuple(s.logical),
                         dtype=s.dtype, init=s.init, scale=s.scale)

    return jax.tree_util.tree_map(_stack, spec, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# blocks (params, x, cache) -> (x, cache, aux)
# ---------------------------------------------------------------------------


def dense_block_spec(cfg: ArchConfig) -> Dict[str, Any]:
    norm_spec, _ = make_norm(cfg.norm)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    spec = {
        "ln1": norm_spec(d),
        "attn": attn_mod.attention_spec(d, cfg.n_heads, cfg.n_kv_heads, hd, cfg.use_bias),
    }
    if cfg.family == "moe":
        spec["moe"] = moe_mod.moe_spec(d, cfg.moe, cfg.activation, cfg.use_bias)
    else:
        spec["mlp"] = mlp_spec(d, cfg.d_ff, cfg.activation, cfg.use_bias)
    if not cfg.parallel_block:
        spec["ln2"] = norm_spec(d)
    return spec


def dense_block(params, x, cfg: ArchConfig, *, cache=None, window=None, impl=None):
    _, norm = make_norm(cfg.norm)
    impl = impl or cfg.attention_impl
    aux: Dict[str, jax.Array] = {}
    if cfg.parallel_block:
        h = norm(params["ln1"], x)
        attn_out, new_cache = attn_mod.self_attention(
            params["attn"], h, n_kv_heads=cfg.n_kv_heads, rope_theta=cfg.rope_theta,
            impl=impl, window=window, chunk=cfg.attention_chunk, cache=cache,
        )
        if cfg.family == "moe":
            ff_out, aux = moe_mod.moe_layer(params["moe"], h, cfg.moe, cfg.activation)
        else:
            ff_out = mlp(params["mlp"], h, cfg.activation)
        x = x + attn_out + ff_out
    else:
        h = norm(params["ln1"], x)
        # pin the sequence sharding on the (bf16) norm output so GSPMD places
        # any gather after the cast and keeps matmul inputs seq-sharded
        h = with_logical_constraint(h, ("batch", "attn_seq", "embed"))
        attn_out, new_cache = attn_mod.self_attention(
            params["attn"], h, n_kv_heads=cfg.n_kv_heads, rope_theta=cfg.rope_theta,
            impl=impl, window=window, chunk=cfg.attention_chunk, cache=cache,
        )
        attn_out = with_logical_constraint(attn_out, ("batch", "attn_seq", "embed"))
        x = x + attn_out
        h2 = norm(params["ln2"], x)
        h2 = with_logical_constraint(h2, ("batch", "attn_seq", "embed"))
        if cfg.family == "moe":
            ff_out, aux = moe_mod.moe_layer(params["moe"], h2, cfg.moe, cfg.activation)
        else:
            ff_out = mlp(params["mlp"], h2, cfg.activation)
        ff_out = with_logical_constraint(ff_out, ("batch", "attn_seq", "embed"))
        x = x + ff_out
    # sequence-parallel residual stream (§Perf iteration 2): the stream stays
    # sequence-sharded over the model axis; norms/elementwise run sharded and
    # only K/V (small) are gathered inside attention
    x = with_logical_constraint(x, ("batch", "attn_seq", "embed"))
    return x, new_cache, aux


def ssm_block_spec(cfg: ArchConfig) -> Dict[str, Any]:
    norm_spec, _ = make_norm(cfg.norm)
    return {"ln": norm_spec(cfg.d_model), "ssm": ssm_mod.ssd_spec(cfg.d_model, cfg.ssm)}


def ssm_block(params, x, cfg: ArchConfig, *, cache=None):
    _, norm = make_norm(cfg.norm)
    h = norm(params["ln"], x)
    y, new_cache = ssm_mod.ssd_block(params["ssm"], h, cfg.ssm, cache=cache)
    return x + y, new_cache, {}


def rglru_block_spec(cfg: ArchConfig) -> Dict[str, Any]:
    norm_spec, _ = make_norm(cfg.norm)
    d = cfg.d_model
    return {
        "ln1": norm_spec(d),
        "rec": rglru_mod.rglru_block_spec(d, cfg.rglru),
        "ln2": norm_spec(d),
        "mlp": mlp_spec(d, cfg.d_ff, cfg.activation, cfg.use_bias),
    }


def rglru_block(params, x, cfg: ArchConfig, *, cache=None):
    _, norm = make_norm(cfg.norm)
    y, new_cache = rglru_mod.rglru_block(params["rec"], norm(params["ln1"], x), cfg.rglru, cache=cache)
    x = x + y
    x = x + mlp(params["mlp"], norm(params["ln2"], x), cfg.activation)
    return x, new_cache, {}


# ---------------------------------------------------------------------------
# uniform decoder stack (scan over layers)
# ---------------------------------------------------------------------------


def _scan_stack(body: Callable, x, stacked_params, cache, remat: bool):
    """body(layer_params, x, layer_cache) -> (x, new_layer_cache, aux)."""
    has_cache = cache is not None

    def fn(carry, inp):
        lp, c = inp if has_cache else (inp, None)
        y, nc, aux = body(lp, carry, c)
        return y, (nc, aux)

    if remat:
        fn = jax.checkpoint(fn, prevent_cse=False)
    xs = (stacked_params, cache) if has_cache else stacked_params
    x, (new_cache, auxes) = jax.lax.scan(fn, x, xs)
    return x, (new_cache if has_cache else None), auxes


def decoder_stack_spec(cfg: ArchConfig) -> Dict[str, Any]:
    if cfg.family == "ssm":
        block = ssm_block_spec(cfg)
        return {"blocks": stack_specs(block, cfg.n_layers)}
    if cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        n_groups, rem = divmod(cfg.n_layers, len(pat))
        group = {f"{i}_{kind}": (rglru_block_spec(cfg) if kind == "rglru" else dense_block_spec(cfg))
                 for i, kind in enumerate(pat)}
        spec = {"groups": stack_specs(group, n_groups)}
        for r in range(rem):
            kind = pat[r % len(pat)]
            spec[f"tail_{r}_{kind}"] = rglru_block_spec(cfg) if kind == "rglru" else dense_block_spec(cfg)
        return spec
    return {"blocks": stack_specs(dense_block_spec(cfg), cfg.n_layers)}


def decoder_stack(params, x, cfg: ArchConfig, *, cache=None, remat=True, impl=None):
    """Returns (x, new_cache, aux_losses_summed)."""
    auxsum: Dict[str, jax.Array] = {}

    def add_aux(aux):
        for k, v in aux.items():
            auxsum[k] = auxsum.get(k, 0.0) + jnp.sum(v)

    if cfg.family == "ssm":
        body = lambda lp, h, c: ssm_block(lp, h, cfg, cache=c)  # noqa: E731
        x, new_cache, _ = _scan_stack(body, x, params["blocks"], cache, remat)
        return x, new_cache, auxsum

    if cfg.family == "hybrid":
        pat = cfg.rglru.pattern
        n_groups, rem = divmod(cfg.n_layers, len(pat))

        def group_body(gp, h, gc):
            nc = {}
            for i, kind in enumerate(pat):
                key = f"{i}_{kind}"
                c = None if gc is None else gc[key]
                if kind == "rglru":
                    h, nci, _ = rglru_block(gp[key], h, cfg, cache=c)
                else:
                    h, nci, _ = dense_block(gp[key], h, cfg, cache=c,
                                            window=cfg.sliding_window, impl=impl)
                nc[key] = nci
            return h, nc, {}

        gcache = None if cache is None else cache["groups"]
        x, new_gcache, _ = _scan_stack(group_body, x, params["groups"], gcache, remat)
        new_cache = {"groups": new_gcache}
        for r in range(rem):
            kind = pat[r % len(pat)]
            key = f"tail_{r}_{kind}"
            c = None if cache is None else cache[key]
            if kind == "rglru":
                x, nc, _ = rglru_block(params[key], x, cfg, cache=c)
            else:
                x, nc, _ = dense_block(params[key], x, cfg, cache=c,
                                       window=cfg.sliding_window, impl=impl)
            new_cache[key] = nc
        return x, (new_cache if cache is not None else None), auxsum

    # dense / moe / vlm / internlm backbone
    body = lambda lp, h, c: dense_block(lp, h, cfg, cache=c, window=cfg.sliding_window, impl=impl)  # noqa: E731
    x, new_cache, auxes = _scan_stack(body, x, params["blocks"], cache, remat)
    if cfg.family == "moe":
        for k in ("load_balance_loss", "router_z_loss"):
            if k in auxes:
                auxsum[k] = jnp.sum(auxes[k])
    return x, new_cache, auxsum


# ---------------------------------------------------------------------------
# encoder-decoder (whisper-style)
# ---------------------------------------------------------------------------


def encoder_block_spec(cfg: ArchConfig) -> Dict[str, Any]:
    norm_spec, _ = make_norm(cfg.norm)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": norm_spec(d),
        "attn": attn_mod.attention_spec(d, cfg.n_heads, cfg.n_kv_heads, hd, cfg.use_bias),
        "ln2": norm_spec(d),
        "mlp": mlp_spec(d, cfg.d_ff, cfg.activation, cfg.use_bias),
    }


def encoder_block(params, x, cfg: ArchConfig, impl=None):
    _, norm = make_norm(cfg.norm)
    h, _ = attn_mod.self_attention(
        params["attn"], norm(params["ln1"], x), n_kv_heads=cfg.n_kv_heads,
        rope_theta=None, impl=impl or cfg.attention_impl, causal=False,
        chunk=cfg.attention_chunk,
    )
    x = x + h
    x = x + mlp(params["mlp"], norm(params["ln2"], x), cfg.activation)
    return x


def xdec_block_spec(cfg: ArchConfig) -> Dict[str, Any]:
    norm_spec, _ = make_norm(cfg.norm)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {
        "ln1": norm_spec(d),
        "attn": attn_mod.attention_spec(d, cfg.n_heads, cfg.n_kv_heads, hd, cfg.use_bias),
        "ln_x": norm_spec(d),
        "xattn": attn_mod.cross_attention_spec(d, cfg.n_heads, cfg.n_kv_heads, hd),
        "ln2": norm_spec(d),
        "mlp": mlp_spec(d, cfg.d_ff, cfg.activation, cfg.use_bias),
    }


def xdec_block(params, x, cfg: ArchConfig, *, enc_kv=None, cache=None, impl=None):
    """Decoder block with cross-attention. enc_kv: (k, v) from the encoder."""
    _, norm = make_norm(cfg.norm)
    impl = impl or cfg.attention_impl
    h, new_cache = attn_mod.self_attention(
        params["attn"], norm(params["ln1"], x), n_kv_heads=cfg.n_kv_heads,
        rope_theta=None, impl=impl, chunk=cfg.attention_chunk, cache=cache,
    )
    x = x + h
    x = x + attn_mod.cross_attention(params["xattn"], norm(params["ln_x"], x), enc_kv,
                                     impl, cfg.attention_chunk)
    x = x + mlp(params["mlp"], norm(params["ln2"], x), cfg.activation)
    return x, new_cache, {}


def encoder_stack_spec(cfg: ArchConfig) -> Dict[str, Any]:
    return {"blocks": stack_specs(encoder_block_spec(cfg), cfg.n_encoder_layers)}


def encoder_stack(params, x, cfg: ArchConfig, remat=True, impl=None):
    def body(carry, lp):
        return encoder_block(lp, carry, cfg, impl=impl), None

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, _ = jax.lax.scan(fn, x, params["blocks"])
    return x


def xdec_stack_spec(cfg: ArchConfig) -> Dict[str, Any]:
    return {"blocks": stack_specs(xdec_block_spec(cfg), cfg.n_layers)}


def xdec_stack(params, x, cfg: ArchConfig, *, enc_kv, cache=None, remat=True, impl=None):
    """enc_kv: stacked (L, B, S_enc, Kh, Dh) pair."""

    has_cache = cache is not None

    def body(carry, inp):
        if has_cache:
            lp, ekv, c = inp
        else:
            (lp, ekv), c = inp, None
        y, nc, _ = xdec_block(lp, carry, cfg, enc_kv=ekv, cache=c, impl=impl)
        return y, nc

    fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    xs = (params["blocks"], enc_kv, cache) if has_cache else (params["blocks"], enc_kv)
    x, new_cache = jax.lax.scan(fn, x, xs)
    return x, (new_cache if has_cache else None)
