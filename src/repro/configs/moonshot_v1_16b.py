"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (kv=16) expert d_ff=1408,
vocab=163840, 64 experts top-6 + shared expert (Moonlight/DeepSeek-V3-style).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, MoEConfig, register

FULL = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, shared_d_ff=2816),
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab=512,
    # dropless (capacity ≥ T) so decode matches forward exactly in tests
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, shared_d_ff=128, capacity_factor=4.0),
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention; 500k decode needs sub-quadratic attention"),),
    )
)
