"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064, RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, register

FULL = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=10000.0,
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=96,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention; 500k decode needs sub-quadratic attention"),),
    )
)
