"""Import side-effect module: registers every assigned architecture."""

from . import (  # noqa: F401
    command_r_35b,
    deepseek_coder_33b,
    internvl2_1b,
    mamba2_370m,
    moonshot_v1_16b,
    phi3_mini_3p8b,
    phi3p5_moe_42b,
    recurrentgemma_2b,
    stablelm_12b,
    whisper_medium,
)
