"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352.  [hf:stabilityai/stablelm-2-12b; hf]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, register

FULL = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    norm="layernorm",
    activation="swiglu",
    use_bias=False,
    rope_theta=10000.0,
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=512,
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention; 500k decode needs sub-quadratic attention"),),
    )
)
