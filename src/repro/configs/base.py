"""Architecture/config system.

One :class:`ArchConfig` per assigned architecture (see sibling modules);
``reduced()`` yields the CPU-smoke-test variant of the same family.
Input-shape sets (train_4k / prefill_32k / decode_32k / long_500k) are
declared in :mod:`repro.configs.shapes`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    # number of dense (non-MoE) d_ff units run in parallel with experts
    shared_d_ff: int = 0


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: Optional[int] = None  # default: round(expand*d_model) per RecurrentGemma
    d_conv: int = 4
    # block pattern: how many recurrent blocks per attention block
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | geglu | gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    parallel_block: bool = False  # command-r style attn ∥ mlp
    sliding_window: Optional[int] = None  # local attention width
    logit_softcap: Optional[float] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (audio) / vlm frontends
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend sequence length (frames / patches)
    frontend: Optional[str] = None  # 'audio' | 'vision' | None
    # attention impl: naive | chunked | pallas (serving/dry-run default: chunked)
    attention_impl: str = "chunked"
    attention_chunk: int = 1024
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # full attention everywhere? (False for ssm/hybrid) — drives long_500k skip
    quadratic_attention: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so embedding/logits shard on any TP axis
        (pad logits are masked to −inf in the head — exact semantics)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6·N·D."""
        d = self.d_model
        hd = self.resolved_head_dim if self.n_heads else 0
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d

        def mlp_params(ff: int) -> int:
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            return mult * d * ff

        if self.family == "moe":
            assert self.moe is not None
            per_layer = attn + self.moe.n_experts * mlp_params(self.moe.d_ff_expert) + d * self.moe.n_experts
            if self.moe.shared_d_ff:
                per_layer += mlp_params(self.moe.shared_d_ff)
        elif self.family == "ssm":
            assert self.ssm is not None
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            per_layer = d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + nh) + di * d \
                + self.ssm.d_conv * (di + 2 * self.ssm.n_groups * self.ssm.d_state)
        elif self.family == "hybrid":
            assert self.rglru is not None
            drnn = self.rglru.d_rnn or int(1.5 * d)
            rec = d * 2 * drnn + drnn * d + self.rglru.d_conv * drnn + 2 * drnn
            pattern = self.rglru.pattern
            n_attn = sum(1 for p in pattern for _ in [0] if p == "attn")
            frac_attn = n_attn / len(pattern)
            per_layer = frac_attn * attn + (1 - frac_attn) * rec + mlp_params(self.d_ff)
        else:
            per_layer = attn + mlp_params(self.d_ff)

        n = emb + self.n_layers * per_layer
        if self.is_encdec:
            # encoder blocks + decoder cross-attention
            n += self.n_encoder_layers * (attn + mlp_params(self.d_ff))
            n += self.n_layers * attn  # cross-attn per decoder layer
        return int(n)

    def n_active_params(self) -> int:
        """Active parameters per token (≠ n_params for MoE)."""
        if self.family != "moe":
            return self.n_params()
        assert self.moe is not None
        total = self.n_params()
        d = self.d_model
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        all_experts = self.n_layers * self.moe.n_experts * mult * d * self.moe.d_ff_expert
        active_experts = self.n_layers * self.moe.top_k * mult * d * self.moe.d_ff_expert
        return int(total - all_experts + active_experts)


_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclass(frozen=True)
class ArchEntry:
    full: ArchConfig
    reduced: ArchConfig
    shapes: Tuple[str, ...]  # applicable shape ids
    skips: Tuple[Tuple[str, str], ...] = ()  # (shape_id, reason)


def register(entry: ArchEntry) -> ArchEntry:
    _REGISTRY[entry.full.name] = entry
    return entry


def get_arch(name: str) -> ArchEntry:
    if name not in _REGISTRY:
        # import sibling config modules lazily
        from . import all_archs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> Tuple[str, ...]:
    from . import all_archs  # noqa: F401

    return tuple(sorted(_REGISTRY))
