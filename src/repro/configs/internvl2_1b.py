"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT tower stubbed, Qwen2-0.5B-style backbone (qkv-bias,
tied embeddings).  [arXiv:2404.16821; hf]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, register

FULL = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    norm="rmsnorm",
    activation="swiglu",
    use_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    frontend="vision",
    encoder_seq=256,  # patch embeddings per image (stub)
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=56,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    encoder_seq=4,
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention; 500k decode needs sub-quadratic attention"),),
    )
)
