"""whisper-medium [audio] — enc-dec, conv frontend stubbed.
24L (enc+dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
[arXiv:2212.04356; unverified]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, register

FULL = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    activation="gelu",
    use_bias=True,
    tie_embeddings=True,
    rope_theta=10000.0,  # unused: enc-dec blocks use learned/sinusoidal positions
    n_encoder_layers=24,
    encoder_seq=1500,
    frontend="audio",
    quadratic_attention=True,
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    n_encoder_layers=2,
    encoder_seq=16,
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention (enc-dec); 500k decode needs sub-quadratic attention"),),
    )
)
