"""Input-shape sets for the assigned LM architectures.

``train_*`` lowers ``train_step``; ``prefill_*`` lowers ``prefill``;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), per the assignment.  ``long_500k`` requires
sub-quadratic attention and only applies to the ssm/hybrid families
(skips recorded in configs + DESIGN.md §4).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

ALL_SHAPE_IDS: Tuple[str, ...] = tuple(SHAPES)


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]
