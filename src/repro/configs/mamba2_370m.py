"""mamba2-370m [ssm] — 48L d_model=1024, attention-free, vocab=50280,
ssm_state=128 (SSD, state-space duality).  [arXiv:2405.21060; unverified]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, SSMConfig, register

FULL = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    quadratic_attention=False,
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=64,
    vocab=512,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, n_groups=1, chunk=8),
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
)
