"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention in a (rglru, rglru, attn) pattern.
[arXiv:2402.19427; hf]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, RGLRUConfig, register

FULL = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    norm="rmsnorm",
    activation="geglu",
    tie_embeddings=True,  # gemma family ties input/output embeddings
    sliding_window=2048,
    logit_softcap=30.0,
    rglru=RGLRUConfig(d_rnn=2560, d_conv=4, pattern=("rglru", "rglru", "attn")),
    rope_theta=10000.0,
    quadratic_attention=False,  # local attention + linear recurrence
)

REDUCED = replace(
    FULL,
    n_layers=4,  # 1 full (rglru, rglru, attn) group + 1 tail rglru
    d_model=80,
    n_heads=4,
    n_kv_heads=1,
    d_ff=160,
    vocab=512,
    sliding_window=8,
    rglru=RGLRUConfig(d_rnn=80, d_conv=4, pattern=("rglru", "rglru", "attn")),
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    )
)
