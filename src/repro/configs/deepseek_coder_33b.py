"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256, llama-arch.  [arXiv:2401.14196; hf]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, register

FULL = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=100000.0,
)

REDUCED = replace(
    FULL,
    n_layers=3,
    d_model=56 * 2,  # keep head_dim divisible
    n_heads=4,
    n_kv_heads=2,
    d_ff=224,
    vocab=512,
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention; 500k decode needs sub-quadratic attention"),),
    )
)
