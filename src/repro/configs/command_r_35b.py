"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, parallel attn∥mlp block, no-bias, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, register

FULL = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    norm="layernorm",
    activation="swiglu",
    use_bias=False,
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=352,
    vocab=512,
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention; 500k decode needs sub-quadratic attention"),),
    )
)
