"""Config registry: one module per assigned architecture + shape sets +
the paper's own stencil benchmark suite (stencil_suite)."""

from .base import ArchConfig, ArchEntry, MoEConfig, RGLRUConfig, SSMConfig, get_arch, list_archs
from .shapes import ALL_SHAPE_IDS, SHAPES, ShapeSpec, get_shape

__all__ = [
    "ArchConfig",
    "ArchEntry",
    "MoEConfig",
    "RGLRUConfig",
    "SSMConfig",
    "get_arch",
    "list_archs",
    "SHAPES",
    "ALL_SHAPE_IDS",
    "ShapeSpec",
    "get_shape",
]
