"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
(per expert), vocab=32064, 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from dataclasses import replace

from .base import ArchConfig, ArchEntry, MoEConfig, register

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab=32064,
    norm="layernorm",
    activation="swiglu",
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400),
)

REDUCED = replace(
    FULL,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab=512,
    # dropless (capacity ≥ T) so decode matches forward exactly in tests
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, capacity_factor=2.0),
    attention_impl="naive",
    dtype="float32",
)

ENTRY = register(
    ArchEntry(
        full=FULL,
        reduced=REDUCED,
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skips=(("long_500k", "pure full attention; 500k decode needs sub-quadratic attention"),),
    )
)
