"""Checkpoint store: compressed npz shards with atomic commit + async IO.

Shards are zstd-compressed when the optional ``zstandard`` package is
installed (the ``[compression]`` extra) and fall back to stdlib ``zlib``
otherwise; the codec is recorded in ``meta.json`` and in the shard suffix.
Reading a zstd-compressed checkpoint without ``zstandard`` raises an
explicit error at load time — importing this module never requires it.

Layout::

    <dir>/step_000042/
        meta.json            # step, pytree structure, leaf manifest, codec
        shard_00000.npz.zst  # leaf arrays (host-local shard; .zlib fallback)
        COMMIT               # written last — partial checkpoints are ignored

Elastic restore: leaves are stored whole (gathered) keyed by pytree path, so
a checkpoint written on one mesh restores onto any other mesh/topology — the
target shardings come from the model's logical-axis rules, not from the
checkpoint (DESIGN.md §5).  Async saves overlap serialization with training.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, List, Optional, Tuple

import jax
import numpy as np

try:
    import zstandard
except ImportError:  # optional: the [compression] extra
    zstandard = None

import zlib

_COMMIT = "COMMIT"


def _compress(data: bytes) -> Tuple[bytes, str]:
    """Returns (payload, codec name)."""
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(data), "zst"
    return zlib.compress(data, level=6), "zlib"


def _decompress(payload: bytes, codec: str, src: Path) -> bytes:
    if codec == "zst":
        if zstandard is None:
            raise RuntimeError(
                f"checkpoint {src} is zstd-compressed but the 'zstandard' package is not "
                "installed — install the [compression] extra to read it"
            )
        return zstandard.ZstdDecompressor().decompress(payload)
    if codec == "zlib":
        return zlib.decompress(payload)
    raise ValueError(f"checkpoint {src} uses unknown codec {codec!r}")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_str(p), v) for p, v in flat]


def save_checkpoint(directory: str | Path, step: int, tree: Any, *, keep: int = 3) -> Path:
    """Synchronous sharded save with atomic COMMIT."""
    directory = Path(directory)
    target = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten_with_paths(tree)
    manifest = []
    import io

    raw = io.BytesIO()
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        key = f"leaf_{i:05d}"
        arrays[key] = arr
        manifest.append({"path": path, "key": key, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)})
    np.savez(raw, **arrays)
    payload, codec = _compress(raw.getvalue())
    (tmp / f"shard_00000.npz.{codec}").write_bytes(payload)

    meta = {
        "step": step,
        "format": 1,
        "codec": codec,
        "leaves": manifest,
        "written_at": time.time(),
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    (tmp / _COMMIT).write_text("ok")
    if target.exists():
        shutil.rmtree(target)
    tmp.rename(target)
    _gc_old(directory, keep)
    return target


def _gc_old(directory: Path, keep: int) -> None:
    steps = sorted(p for p in directory.glob("step_*") if (p / _COMMIT).exists())
    for old in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in directory.glob("step_*") if (p / _COMMIT).exists()
    )
    return steps[-1] if steps else None


def load_checkpoint(directory: str | Path, template: Any, step: Optional[int] = None,
                    shardings: Any = None) -> Tuple[int, Any]:
    """Restore into the structure of ``template`` (values ignored).

    ``shardings``: optional pytree of NamedShardings (elastic re-shard onto
    the current mesh via jax.device_put).
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {directory}")
    src = directory / f"step_{step:09d}"
    if not (src / _COMMIT).exists():
        raise FileNotFoundError(f"checkpoint {src} is not committed")
    meta = json.loads((src / "meta.json").read_text())
    # codec recorded since format 1+codec; older checkpoints are zstd-only
    codec = meta.get("codec", "zst")
    shard = src / f"shard_00000.npz.{codec}"
    import io

    raw = io.BytesIO(_decompress(shard.read_bytes(), codec, src))
    arrays = np.load(raw)
    by_path = {m["path"]: arrays[m["key"]] for m in meta["leaves"]}

    flat_t = jax.tree_util.tree_flatten_with_path(template)
    leaves_t, treedef = flat_t
    out = []
    missing = []
    for path, leaf in leaves_t:
        key = _path_str(path)
        if key not in by_path:
            missing.append(key)
            out.append(leaf)
            continue
        arr = by_path[key]
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        out.append(np.asarray(arr, dtype=want_dtype))
    if missing:
        raise KeyError(f"checkpoint {src} is missing leaves: {missing[:5]}... "
                       f"({len(missing)} total)")
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s) if s is not None else x, tree, shardings
        )
    return meta["step"], tree


class CheckpointManager:
    """Async wrapper: save() snapshots to host memory synchronously, writes in
    a background thread; wait() joins; restore_or_init resumes elastically."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            try:
                save_checkpoint(self.directory, step, host_tree, keep=self.keep)
            except BaseException as e:  # noqa: BLE001 — surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_or_init(self, template: Any, init_fn: Callable[[], Any],
                        shardings: Any = None) -> Tuple[int, Any]:
        step = latest_step(self.directory)
        if step is None:
            return 0, init_fn()
        return load_checkpoint(self.directory, template, step, shardings)
