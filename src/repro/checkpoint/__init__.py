"""Checkpointing substrate: sharded, async, atomic, elastic-restorable."""

from .store import (
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint"]
