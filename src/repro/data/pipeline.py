"""Synthetic token pipeline: deterministic, step-keyed, shard-aware.

Fault-tolerance contract: ``batch_at(step)`` is a pure function of
(seed, step, shard), so a restart from checkpoint step N reproduces the
exact token stream — no data-loader state to checkpoint.  The generated
stream is a mixture of Zipf-distributed unigrams and short Markov loops so
losses decrease realistically rather than saturating instantly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import numpy as np


@dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard_index: int = 0
    shard_count: int = 1
    frames_shape: Optional[Tuple[int, int]] = None  # (S_enc, d_model) for audio
    patches_shape: Optional[Tuple[int, int]] = None  # (P, d_model) for vlm

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.shard_count == 0, (
            f"global batch {self.global_batch} not divisible by {self.shard_count} shards"
        )
        return self.global_batch // self.shard_count

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_index])
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s, v = self.local_batch, self.seq_len, self.vocab
        # zipf unigrams, clipped to vocab
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        tokens = (base % max(v - 3, 1)) + 2  # reserve 0=pad, 1=bos
        # splice short repeated motifs (learnable structure)
        n_motifs = max(1, s // 64)
        for i in range(b):
            for _ in range(n_motifs):
                mlen = int(rng.integers(4, 12))
                start = int(rng.integers(0, max(s - 2 * mlen, 1)))
                motif = tokens[i, start : start + mlen]
                dst = int(rng.integers(0, max(s - mlen, 1)))
                tokens[i, dst : dst + mlen] = motif
        tokens[:, 0] = 1  # bos
        tokens = tokens.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1
        batch: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels.astype(np.int32)}
        if self.frames_shape is not None:
            batch["frames"] = rng.normal(size=(b,) + self.frames_shape).astype(np.float32)
        if self.patches_shape is not None:
            batch["patches"] = rng.normal(size=(b,) + self.patches_shape).astype(np.float32)
        return batch


def make_batch_specs(cfg, shape, dtype_tokens="int32") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a train batch."""
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), np.int32),
        "labels": jax.ShapeDtypeStruct((b, s), np.int32),
    }
    if cfg.frontend == "vision":
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), np.float32)
    if cfg.is_encdec:
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), np.float32)
    return specs
