"""Deterministic synthetic data pipeline (restart-exact, shard-aware)."""

from .pipeline import SyntheticLMDataset, make_batch_specs

__all__ = ["SyntheticLMDataset", "make_batch_specs"]
