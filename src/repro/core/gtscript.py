"""User-facing GTScript symbols (the embedded DSL surface).

This module defines the names that appear *inside* stencil definition
functions (``computation``, ``interval``, ``PARALLEL``, ...) and the two
decorators ``@function`` and ``@stencil``.  Per the paper, GTScript is a
strict syntactic subset of Python: definition functions are parsed with the
stock ``ast`` module and are **never executed** as Python — the symbols here
exist so the source is importable, introspectable and IDE-friendly.
"""

from __future__ import annotations

import inspect
import textwrap
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from .ir import IterationOrder

__all__ = [
    "Field",
    "IJK",
    "IJ",
    "K",
    "PARALLEL",
    "FORWARD",
    "BACKWARD",
    "computation",
    "interval",
    "function",
    "stencil",
    "lazy_stencil",
    "GTScriptFunction",
    "GTScriptSyntaxError",
    "GTScriptSemanticError",
]


class GTScriptSyntaxError(SyntaxError):
    """Raised when a definition function uses Python outside the GTScript subset."""


class GTScriptSemanticError(ValueError):
    """Raised when a syntactically valid stencil has invalid semantics
    (e.g. a race in a PARALLEL computation, paper §2.2)."""


# ---------------------------------------------------------------------------
# Axes / field type annotations
# ---------------------------------------------------------------------------

IJK = ("I", "J", "K")
IJ = ("I", "J")
K = ("K",)


class _FieldType:
    """Result of ``Field[dtype]`` / ``Field[dtype, axes]`` used in annotations."""

    def __init__(self, dtype: Any, axes: Tuple[str, ...] = IJK):
        self.dtype = np.dtype(dtype)
        self.axes = tuple(axes)

    def __repr__(self) -> str:
        return f"Field[{self.dtype}, {self.axes}]"


class _FieldMeta(type):
    def __getitem__(cls, item) -> _FieldType:
        if isinstance(item, tuple) and len(item) == 2 and isinstance(item[1], tuple):
            dtype, axes = item
            return _FieldType(dtype, axes)
        return _FieldType(item)


class Field(metaclass=_FieldMeta):
    """Annotation type for stencil field parameters: ``Field[np.float64]``."""


# ---------------------------------------------------------------------------
# In-body keywords (parsed, never executed)
# ---------------------------------------------------------------------------

PARALLEL = IterationOrder.PARALLEL
FORWARD = IterationOrder.FORWARD
BACKWARD = IterationOrder.BACKWARD


def _never_executed(name: str):
    def _fn(*_args, **_kwargs):
        raise RuntimeError(
            f"gtscript.{name}() is a DSL keyword: it is parsed from the stencil "
            "source and must not be called outside a stencil definition."
        )

    return _fn


computation = _never_executed("computation")
interval = _never_executed("interval")


# ---------------------------------------------------------------------------
# @gtscript.function
# ---------------------------------------------------------------------------


class GTScriptFunction:
    """A pure, inlinable GTScript function (paper Fig. 1, line 3).

    The wrapped Python function is parsed on demand; calls inside stencils
    are inlined by the frontend with additive offset composition (calling
    ``f(phi[1, 0, 0])`` where ``f`` reads ``arg[0, 1, 0]`` yields a read of
    ``phi[1, 1, 0]``).
    """

    def __init__(self, definition: Callable):
        self.definition = definition
        self.__name__ = definition.__name__
        self.__doc__ = definition.__doc__
        self._source: Optional[str] = None

    @property
    def source(self) -> str:
        if self._source is None:
            self._source = textwrap.dedent(inspect.getsource(self.definition))
        return self._source

    def __call__(self, *args, **kwargs):
        raise RuntimeError(
            f"GTScript function {self.__name__!r} can only be called from inside "
            "a stencil definition (it is inlined at compile time)."
        )

    def __repr__(self) -> str:
        return f"GTScriptFunction({self.__name__})"


def function(definition: Callable) -> GTScriptFunction:
    return GTScriptFunction(definition)


# ---------------------------------------------------------------------------
# @gtscript.stencil
# ---------------------------------------------------------------------------


def stencil(
    backend: str = "numpy",
    definition: Optional[Callable] = None,
    *,
    externals: Optional[Dict[str, Any]] = None,
    name: Optional[str] = None,
    rebuild: bool = False,
    validate_args: bool = True,
    **backend_opts: Any,
):
    """Compile a definition function into a :class:`StencilObject`.

    Parameters mirror the paper: ``backend`` selects the code generator
    (``debug`` | ``numpy`` | ``jax`` | ``pallas``), ``externals`` are
    compile-time constants, and ``rebuild`` bypasses the fingerprint cache.
    ``validate_args`` reproduces the run-time storage checks whose cost is
    the dashed-vs-solid gap in the paper's Fig. 3; pass ``False`` to skip.

    Extra ``backend_opts`` configure the optimization pass pipeline
    (``opt_level=0..3``, ``disable_passes=(...)``, ``enable_passes=(...)`` —
    see ``repro.core.passes``) and backend codegen.  Pallas only:
    ``block=(bi, bj)`` pins the horizontal tile, while ``autotune=True``
    searches candidate tiles at first call per domain and persists the
    winner keyed on the cache fingerprint (``repro.core.autotune``; optional
    ``autotune_candidates`` / ``autotune_iters`` / ``autotune_warmup``).  A
    pinned ``block`` always wins over the autotuner.  The chosen tile,
    per-candidate timings, and the backend's DMA/k-blocking schedule surface
    through ``exec_info["autotune"]`` / ``exec_info["schedule"]``.
    """

    def _impl(func: Callable):
        # Imported lazily: frontend/codegen pull in heavier deps.
        from .stencil import build_stencil_object

        return build_stencil_object(
            definition=func,
            backend=backend,
            externals=dict(externals or {}),
            name=name or func.__name__,
            rebuild=rebuild,
            validate_args=validate_args,
            backend_opts=backend_opts,
        )

    if definition is not None:
        return _impl(definition)
    return _impl


def lazy_stencil(backend: str = "numpy", **kwargs):
    """Like :func:`stencil` but defers parsing/codegen to first call."""

    def _impl(func: Callable):
        class _Lazy:
            def __init__(self):
                self._obj = None
                self.__name__ = func.__name__

            def _build(self):
                if self._obj is None:
                    self._obj = stencil(backend, **kwargs)(func)
                return self._obj

            def __call__(self, *a, **kw):
                return self._build()(*a, **kw)

            def __getattr__(self, item):
                return getattr(self._build(), item)

        return _Lazy()

    return _impl
