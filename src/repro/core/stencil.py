"""StencilObject: the compiled, callable artifact produced by @gtscript.stencil.

Implements the paper's call conventions: fields (Storage or bare arrays) are
positional-or-keyword in declaration order, scalar parameters are
keyword-only, and the iteration space is implicit — deduced from field sizes
and the stencil shape — with optional ``domain=`` / ``origin=`` overrides
(§2.2).  ``validate_args`` reproduces the run-time storage checks whose cost
is the paper's Fig. 3 dashed-vs-solid gap; ``exec_info`` captures the same
timings the paper reports.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.obs import trace as otrace

from . import analysis, caching, frontend, ir, passes
from .storage import Storage

_AXIS_INDEX = {"I": 0, "J": 1, "K": 2}

# distinguishes "tile not given" (schedule-pass default applies) from an
# explicit ``tile=None`` (tiling off) in backend_opts
_TILE_UNSET = object()

# Orchestration-tracing hook (installed by ``repro.program.trace``): called at
# the top of ``StencilObject.__call__`` so a ``@program`` tracer can intercept
# calls made on traced field handles and record a dataflow node instead of
# executing.  Returning :data:`NOT_TRACED` means "not tracing — run eagerly".
NOT_TRACED = object()
_trace_hook = None


def set_trace_hook(hook) -> None:
    """Install (or clear, with ``None``) the program-tracer call hook."""
    global _trace_hook
    _trace_hook = hook


class FieldInfo:
    def __init__(self, decl: ir.FieldDecl, extent: ir.Extent, k_extent: Tuple[int, int]):
        self.name = decl.name
        self.dtype = np.dtype(decl.dtype)
        self.axes = decl.axes
        self.extent = extent
        self.k_extent = k_extent

    @property
    def halo_lo(self) -> Tuple[int, int, int]:
        (ilo, _), (jlo, _), (klo, _) = self.extent.as_tuple()
        return (max(0, -ilo), max(0, -jlo), max(0, -klo))

    @property
    def halo_hi(self) -> Tuple[int, int, int]:
        (_, ihi), (_, jhi), (_, khi) = self.extent.as_tuple()
        return (max(0, ihi), max(0, jhi), max(0, khi))

    def __repr__(self) -> str:
        return f"FieldInfo({self.name}, dtype={self.dtype}, axes={self.axes}, extent={self.extent.as_tuple()})"


class StencilObject:
    """A compiled stencil. See module docstring for call conventions."""

    def __init__(
        self,
        name: str,
        backend: str,
        definition_ir: ir.StencilDefinition,
        implementation_ir: ir.StencilImplementation,
        generated_source: str,
        run_fn: Callable,
        validate_args: bool = True,
        fingerprint: str = "",
        pass_report: Optional[list] = None,
        module=None,
        autotune_cfg: Optional[Dict[str, Any]] = None,
        pinned_block: Optional[Tuple[int, int]] = None,
    ):
        self.name = name
        self.backend = backend
        self.definition_ir = definition_ir
        self.implementation_ir = implementation_ir
        self.generated_source = generated_source
        self._run = run_fn
        self.validate_args_default = validate_args
        self.fingerprint = fingerprint
        # per-pass compile-time instrumentation (passes.PassContext.records)
        self.pass_report = list(pass_report or [])
        # pallas schedule/autotune state: the generated module (for SCHEDULE /
        # _vmem_bytes metadata), the autotune configuration, and an explicit
        # user-pinned block (which always wins over the autotuner)
        self._module = module
        self._autotune_cfg = dict(autotune_cfg or {})
        self._pinned_block = tuple(pinned_block) if pinned_block is not None else None
        self._block_cache: Dict[Tuple[int, int, int], Any] = {}

        # tile-capable numpy module (stage tiling on): run() takes block=
        self._numpy_tiled = backend == "numpy" and hasattr(module, "_BLOCK_DEFAULT")

        impl = implementation_ir
        kext = dict(impl.k_extents)
        self.field_info: Dict[str, FieldInfo] = {
            f.name: FieldInfo(f, impl.extent_of(f.name), kext.get(f.name, (0, 0)))
            for f in impl.api_fields
        }
        self.scalar_info = {s.name: np.dtype(s.dtype) for s in impl.scalars}
        self._field_order = [f.name for f in impl.api_fields]
        self._jit_cache: Dict[Any, Callable] = {}

    # ------------------------------------------------------------------ binding

    def _bind(self, args, kwargs):
        fields: Dict[str, Any] = {}
        scalars: Dict[str, Any] = {}
        for name, val in zip(self._field_order, args):
            fields[name] = val
        if len(args) > len(self._field_order):
            raise TypeError(
                f"{self.name}() takes {len(self._field_order)} positional field arguments, "
                f"got {len(args)}"
            )
        for key, val in kwargs.items():
            if key in self.field_info:
                if key in fields:
                    raise TypeError(f"{self.name}() got duplicate field argument {key!r}")
                fields[key] = val
            elif key in self.scalar_info:
                scalars[key] = val
            else:
                raise TypeError(f"{self.name}() got unexpected argument {key!r}")
        missing = [n for n in self._field_order if n not in fields]
        if missing:
            raise TypeError(f"{self.name}() missing field arguments: {missing}")
        missing_s = [n for n in self.scalar_info if n not in scalars]
        if missing_s:
            raise TypeError(f"{self.name}() missing scalar arguments: {missing_s}")
        return fields, scalars

    @staticmethod
    def _raw(value):
        return value.data if isinstance(value, Storage) else value

    def _axes_shape(self, name: str, shape: Tuple[int, ...]) -> Dict[str, int]:
        axes = self.field_info[name].axes
        if len(shape) != len(axes):
            raise ValueError(
                f"{self.name}(): field {name!r} has axes {axes} but a {len(shape)}-d array was passed"
            )
        return dict(zip(axes, shape))

    def _default_origin(self, name: str, value) -> Tuple[int, ...]:
        if isinstance(value, Storage) and value.default_origin is not None and any(value.default_origin):
            return tuple(value.default_origin)
        info = self.field_info[name]
        lo = info.halo_lo
        # K origin defaults to 0: vertical reads stay in-domain by construction
        return tuple(0 if a == "K" else lo[_AXIS_INDEX[a]] for a in info.axes)

    def _resolve_origins(self, fields, origin) -> Dict[str, Tuple[int, int, int]]:
        origins: Dict[str, Tuple[int, int, int]] = {}
        for name, val in fields.items():
            info = self.field_info[name]
            if origin is None:
                o = self._default_origin(name, val)
            elif isinstance(origin, dict):
                o = origin.get(name, self._default_origin(name, val))
                o = tuple(o)[: len(info.axes)] if len(o) >= len(info.axes) else tuple(o)
            else:
                o = tuple(origin)
                o = tuple(o[_AXIS_INDEX[a]] for a in info.axes)
            if len(o) != len(info.axes):
                raise ValueError(f"{self.name}(): origin {o} rank mismatch for field {name!r}")
            # expand to 3-tuple (I, J, K) with zeros on missing axes
            o3 = [0, 0, 0]
            for a, v in zip(info.axes, o):
                o3[_AXIS_INDEX[a]] = int(v)
            origins[name] = tuple(o3)
        return origins

    def _deduce_domain(self, fields, origins) -> Tuple[int, int, int]:
        dom = [None, None, None]
        for name, val in fields.items():
            info = self.field_info[name]
            shape = self._axes_shape(name, tuple(self._raw(val).shape))
            hi = info.halo_hi
            o3 = origins[name]
            for a, n in shape.items():
                ax = _AXIS_INDEX[a]
                avail = n - o3[ax] - hi[ax]
                dom[ax] = avail if dom[ax] is None else min(dom[ax], avail)
        # K axis when only IJ fields: no constraint → default 1 level
        result = tuple(d if d is not None else 1 for d in dom)
        return result  # type: ignore[return-value]

    # --------------------------------------------------------------- validation

    def _validate(self, fields, scalars, domain, origins) -> None:
        ni, nj, nk = domain
        if min(ni, nj, nk) <= 0:
            raise ValueError(f"{self.name}(): empty compute domain {domain}")
        if nk < self.implementation_ir.min_k_levels:
            raise ValueError(
                f"{self.name}(): domain has {nk} vertical levels but the stencil's intervals "
                f"require at least {self.implementation_ir.min_k_levels}"
            )
        for name, val in fields.items():
            info = self.field_info[name]
            arr = self._raw(val)
            if np.dtype(str(arr.dtype)) != info.dtype:
                raise TypeError(
                    f"{self.name}(): field {name!r} expects dtype {info.dtype}, got {arr.dtype}"
                )
            shape = self._axes_shape(name, tuple(arr.shape))
            o3 = origins[name]
            lo, hi = info.halo_lo, info.halo_hi
            dom3 = {"I": ni, "J": nj, "K": nk}
            for a, n in shape.items():
                ax = _AXIS_INDEX[a]
                # vertical reads are checked statically to stay inside the
                # domain (analysis._check_vertical_bounds) — no K halo needed
                lo_ax = 0 if a == "K" else lo[ax]
                hi_ax = 0 if a == "K" else hi[ax]
                if o3[ax] < lo_ax:
                    raise ValueError(
                        f"{self.name}(): field {name!r} origin {o3[ax]} along {a} is smaller than "
                        f"the required halo {lo_ax}"
                    )
                need = o3[ax] + dom3[a] + hi_ax
                if n < need:
                    raise ValueError(
                        f"{self.name}(): field {name!r} extends to {n} along {a} but needs "
                        f"{need} (origin {o3[ax]} + domain {dom3[a]} + halo {hi_ax})"
                    )
        for name, val in scalars.items():
            if not np.isscalar(val) and not (hasattr(val, "ndim") and val.ndim == 0):
                raise TypeError(f"{self.name}(): parameter {name!r} must be a scalar, got {type(val)}")

    # ------------------------------------------------------------------ calling

    def __call__(
        self,
        *args,
        domain: Optional[Tuple[int, int, int]] = None,
        origin=None,
        validate_args: Optional[bool] = None,
        exec_info: Optional[dict] = None,
        **kwargs,
    ):
        if _trace_hook is not None:
            traced = _trace_hook(self, args, kwargs, domain=domain, origin=origin)
            if traced is not NOT_TRACED:
                return traced
        if exec_info is not None and exec_info.get("trace") is True:
            # per-call trace opt-in: capture this call's spans into a fresh
            # tracer and hand back Chrome-trace JSON under exec_info["trace"]
            from repro.obs import export as obs_export

            del exec_info["trace"]
            with otrace.capture() as cap:
                result = self.__call__(
                    *args, domain=domain, origin=origin,
                    validate_args=validate_args, exec_info=exec_info, **kwargs,
                )
            exec_info["trace"] = obs_export.chrome_trace(cap.snapshot())
            return result
        if exec_info is not None:
            exec_info["call_start_time"] = time.perf_counter()
            exec_info["pass_report"] = list(self.pass_report)
        fields, scalars = self._bind(args, kwargs)
        origins = self._resolve_origins(fields, origin)
        if domain is None:
            domain = self._deduce_domain(fields, origins)
        domain = tuple(int(d) for d in domain)  # type: ignore[assignment]

        do_validate = self.validate_args_default if validate_args is None else validate_args
        if do_validate:
            self._validate(fields, scalars, domain, origins)

        raw_fields = {n: self._raw(v) for n, v in fields.items()}

        block = None
        if self.backend == "pallas":
            # resolve the tile before tracing: timing cannot happen under jit
            block, autotune_record = self._resolve_block(
                domain, [(n, tuple(v.shape)) for n, v in raw_fields.items()]
            )
            if exec_info is not None:
                exec_info["schedule"] = getattr(self._module, "SCHEDULE", None)
                if autotune_record is not None:
                    exec_info["autotune"] = autotune_record
        elif self._numpy_tiled:
            block, autotune_record = self._resolve_block(
                domain, [(n, tuple(v.shape)) for n, v in raw_fields.items()]
            )
            if exec_info is not None:
                exec_info["numpy_tile"] = dict(
                    getattr(self._module, "_TILING", {}),
                    block=tuple(block) if block else tuple(self._module._BLOCK_DEFAULT),
                )
                if autotune_record is not None:
                    exec_info["autotune"] = autotune_record

        if exec_info is not None:
            exec_info["run_start_time"] = time.perf_counter()

        with otrace.span(
            "stencil.run", category="stencil",
            stencil=self.name, backend=self.backend, domain=list(domain),
        ):
            if self.backend in ("debug", "numpy"):
                for n, v in raw_fields.items():
                    if not isinstance(v, np.ndarray):
                        raise TypeError(
                            f"{self.name}(): backend {self.backend!r} requires NumPy-backed fields; "
                            f"{n!r} is {type(v)} (use storage backend={self.backend!r})"
                        )
                if self._numpy_tiled:
                    self._run(raw_fields, scalars, domain, origins, block=block)
                else:
                    self._run(raw_fields, scalars, domain, origins)
                result = None
            else:  # jax / pallas
                fn = self._jitted(domain, origins, block)
                updates = fn(raw_fields, dict(scalars))
                for n, new in updates.items():
                    val = fields[n]
                    if isinstance(val, Storage):
                        val.data = new
                result = updates

        if exec_info is not None:
            if result is not None:
                for v in result.values():
                    v.block_until_ready()
            exec_info["run_end_time"] = time.perf_counter()
        return result

    def _resolve_block(
        self, domain, operand_shapes=None
    ) -> Tuple[Optional[Tuple[int, int]], Optional[dict]]:
        """The pallas tile for this domain + operand geometry: pinned block
        wins, otherwise the autotuner's (cached) choice, otherwise the
        generated default.  ``operand_shapes`` carries the FULL argument
        shapes (member/batch axes included) so a batched run never reuses a
        tile tuned for unbatched shapes."""
        if self._pinned_block is not None or not self._autotune_cfg.get("autotune"):
            return self._pinned_block, None
        if self._module is None:
            return None, None
        if operand_shapes is not None:
            operand_shapes = tuple(
                sorted((str(n), tuple(int(x) for x in s)) for n, s in operand_shapes)
            )
        key = (tuple(domain), operand_shapes)
        cached = self._block_cache.get(key)
        if cached is None:
            from . import autotune

            kwargs = {}
            if self._autotune_cfg.get("autotune_candidates") is not None:
                kwargs["candidates"] = self._autotune_cfg["autotune_candidates"]
            if self._autotune_cfg.get("autotune_iters") is not None:
                kwargs["iters"] = int(self._autotune_cfg["autotune_iters"])
            if self._autotune_cfg.get("autotune_warmup") is not None:
                kwargs["warmup"] = int(self._autotune_cfg["autotune_warmup"])
            cached = autotune.select_block(
                self._module,
                self.name,
                self.fingerprint,
                tuple(domain),
                operand_shapes=operand_shapes,
                **kwargs,
            )
            self._block_cache[key] = cached
        return cached

    def _jitted(self, domain, origins, block=None) -> Callable:
        key = (tuple(domain), tuple(sorted(origins.items())), block)
        fn = self._jit_cache.get(key)
        if fn is None:
            import jax

            run = self._run

            def _pure(fields, scalars):
                if block is not None:
                    return run(fields, scalars, tuple(domain), dict(origins), block=tuple(block))
                return run(fields, scalars, tuple(domain), dict(origins))

            fn = jax.jit(_pure)
            self._jit_cache[key] = fn
        return fn

    def apply(
        self,
        fields: Dict[str, Any],
        scalars: Optional[Dict[str, Any]] = None,
        *,
        domain: Optional[Tuple[int, int, int]] = None,
        origin=None,
        validate_args: Optional[bool] = None,
    ) -> Dict[str, Any]:
        """Functional protocol: ``fields dict -> updated-fields dict``.

        The pure twin of the mutating ``__call__``, for every backend: the
        jax family returns device arrays, numpy/debug copy and run in place.
        This is the same ``fields -> updates`` convention the generated
        ``repro.program`` orchestrators thread between fused groups (they
        call the generated ``run`` functions directly for jit composability);
        ``apply`` is the public single-stencil form of it for composing
        stencils in user code and tests.
        """
        scalars = dict(scalars or {})
        missing = [n for n in self._field_order if n not in fields]
        if missing:
            raise TypeError(f"{self.name}.apply() missing field arguments: {missing}")
        # a superset dict is fine — programs thread one buffer dict through
        # many stencils; only this stencil's own fields participate
        fields = {n: fields[n] for n in self._field_order}
        missing_s = [n for n in self.scalar_info if n not in scalars]
        if missing_s:
            raise TypeError(f"{self.name}.apply() missing scalar arguments: {missing_s}")
        origins = self._resolve_origins(fields, origin)
        if domain is None:
            domain = self._deduce_domain(fields, origins)
        domain = tuple(int(d) for d in domain)
        do_validate = self.validate_args_default if validate_args is None else validate_args
        if do_validate:
            self._validate(fields, scalars, domain, origins)
        raw = {n: self._raw(v) for n, v in fields.items()}
        if self.backend in ("debug", "numpy"):
            work = {n: np.array(v, copy=True) for n, v in raw.items()}
            if self._numpy_tiled:
                block, _ = self._resolve_block(domain, [(n, tuple(v.shape)) for n, v in work.items()])
                self._run(work, scalars, domain, origins, block=block)
            else:
                self._run(work, scalars, domain, origins)
            written = set(self.implementation_ir.written_api_fields())
            return {n: work[n] for n in self._field_order if n in written}
        block = None
        if self.backend == "pallas":
            block, _ = self._resolve_block(domain, [(n, tuple(v.shape)) for n, v in raw.items()])
        return self._jitted(domain, origins, block)(raw, scalars)

    def as_jax_function(
        self,
        domain: Tuple[int, int, int],
        origin=None,
    ) -> Callable:
        """A pure ``fn(fields_dict, scalars_dict) -> updated-fields dict`` for
        composing this stencil inside larger jit programs / shard_map bodies.
        Only available for the jax-family backends."""
        if self.backend not in ("jax", "pallas"):
            raise TypeError(f"as_jax_function() requires the jax/pallas backends, not {self.backend!r}")
        run = self._run

        def _fn(fields: Dict[str, Any], scalars: Optional[Dict[str, Any]] = None):
            org = self._resolve_origins(fields, origin)
            return run(fields, scalars or {}, tuple(domain), org)

        return _fn

    def __repr__(self) -> str:
        return f"StencilObject({self.name!r}, backend={self.backend!r}, fingerprint={self.fingerprint})"


# ---------------------------------------------------------------------------
# build pipeline: definition function → StencilObject
# ---------------------------------------------------------------------------


def build_stencil_object(
    definition: Callable,
    backend: str,
    externals: Dict[str, Any],
    name: str,
    rebuild: bool = False,
    validate_args: bool = True,
    backend_opts: Optional[Dict[str, Any]] = None,
) -> StencilObject:
    with otrace.span("stencil.frontend", category="compile", stencil=name or "", backend=backend):
        definition_ir = frontend.parse_stencil_definition(definition, externals=externals, name=name)
    return build_from_definition(definition_ir, backend, rebuild=rebuild,
                                 validate_args=validate_args, backend_opts=backend_opts)


def build_retyped(
    definition: Callable,
    backend: str,
    dtype: str,
    *,
    externals: Optional[Dict[str, Any]] = None,
    name: Optional[str] = None,
    validate_args: bool = True,
    **backend_opts: Any,
) -> StencilObject:
    """Build a stencil from a float64 definition function with every field,
    scalar, and explicit cast dtype rewritten to ``dtype``
    (``ir.retype_definition``) — the shared path the benchmark stencils use
    to derive float32 variants without duplicating definitions.
    ``dtype="float64"`` is the identity and builds the definition as-is."""
    definition_ir = frontend.parse_stencil_definition(
        definition, externals=dict(externals or {}), name=name
    )
    if dtype != "float64":
        definition_ir = ir.retype_definition(definition_ir, {"float64": dtype})
    return build_from_definition(
        definition_ir, backend, validate_args=validate_args, backend_opts=backend_opts
    )


def build_from_definition(
    definition_ir: ir.StencilDefinition,
    backend: str,
    *,
    rebuild: bool = False,
    validate_args: bool = True,
    backend_opts: Optional[Dict[str, Any]] = None,
) -> StencilObject:
    """Build directly from a Definition IR (used by property tests and any
    alternative frontends — the IR is the toolchain interface, paper §2.3).

    ``backend_opts`` carries the pass-pipeline configuration (``opt_level``,
    ``disable_passes``, ``enable_passes`` — see ``passes.py``) alongside any
    codegen options (e.g. the Pallas ``block`` shape) and the Pallas
    autotuner configuration (``autotune=True`` plus optional
    ``autotune_candidates`` / ``autotune_iters`` / ``autotune_warmup`` — see
    ``autotune.py``).  The autotune keys deliberately stay *out* of the
    cache fingerprint: they change which tile ``run`` is called with, never
    the generated module, and the tuning store is keyed on the fingerprint
    so identical IR + options always share one tuning record."""
    pass_cfg, codegen_opts = passes.split_backend_opts(backend_opts)
    autotune_cfg = {
        k: codegen_opts.pop(k)
        for k in ("autotune", "autotune_candidates", "autotune_iters", "autotune_warmup")
        if k in codegen_opts
    }
    user_tile = codegen_opts.get("tile", _TILE_UNSET)
    if backend == "numpy":
        # numpy stage tiling (a backend-schedule pass, codegen_array.py):
        # explicit ``tile=(TI, TJ)`` pins it, ``tile=None`` disables it,
        # otherwise it rides opt_level / disable_passes like every pass.
        # The effective tile lands in ``codegen_opts`` before fingerprinting.
        from .codegen_array import DEFAULT_NUMPY_TILE

        if user_tile is _TILE_UNSET:
            on = passes.schedule_pass_enabled(
                "numpy_stage_tiling",
                pass_cfg["opt_level"],
                pass_cfg["disable"],
                pass_cfg["enable"],
            )
            codegen_opts["tile"] = DEFAULT_NUMPY_TILE if on else None
    name = definition_ir.name
    with otrace.span("stencil.analyze", category="compile", stencil=name, backend=backend):
        impl = analysis.analyze(definition_ir)
    with otrace.span("stencil.passes", category="compile", stencil=name, backend=backend) as psp:
        impl, pass_report = passes.run_pipeline(impl, **pass_cfg)
        # fold the pass report into span attributes: which passes fired and
        # what each cost, correlated with this build
        psp.set(
            "pass_report",
            [
                {"pass": r["pass"], "seconds": r["seconds"], "changed": r["changed"]}
                for r in pass_report
            ],
        )
    fp = caching.fingerprint(definition_ir, backend, codegen_opts, pass_config=pass_cfg)

    with otrace.span(
        "stencil.codegen", category="compile", stencil=name, backend=backend, fingerprint=fp
    ):
        if backend == "numpy":
            from .codegen_array import generate_numpy_source, tiling_plan

            tile = codegen_opts.get("tile")
            source = generate_numpy_source(impl, tile=tile)
            stats = passes.impl_stats(impl)
            plan = tiling_plan(impl)
            pass_report = list(pass_report) + [
                {
                    "pass": "numpy_stage_tiling",
                    "seconds": 0.0,
                    "before": stats,
                    "after": stats,
                    "changed": tile is not None and plan["tiled_multistages"] > 0,
                    "detail": dict(
                        plan, tile=tuple(tile) if tile else None, enabled=tile is not None
                    ),
                }
            ]
        elif backend == "jax":
            from .codegen_array import generate_jax_source

            source = generate_jax_source(impl)
        elif backend == "debug":
            from .codegen_debug import generate_debug_source

            source = generate_debug_source(impl)
        elif backend == "pallas":
            from .codegen_pallas import generate_pallas_source

            source = generate_pallas_source(impl, **codegen_opts)
        else:
            raise ValueError(f"unknown backend {backend!r} (expected debug|numpy|jax|pallas)")

    with otrace.span(
        "stencil.load_module", category="compile", stencil=name, backend=backend, fingerprint=fp
    ):
        module = caching.load_generated_module(name, fp, source, rebuild=rebuild)
    if backend == "pallas":
        pinned = codegen_opts.get("block")
    elif backend == "numpy" and user_tile is not _TILE_UNSET:
        pinned = user_tile  # explicit tile pin always wins over the autotuner
    else:
        pinned = None
    return StencilObject(
        name=name,
        backend=backend,
        definition_ir=definition_ir,
        implementation_ir=impl,
        generated_source=source,
        run_fn=module.run,
        validate_args=validate_args,
        fingerprint=fp,
        pass_report=pass_report,
        module=module,
        autotune_cfg=autotune_cfg,
        pinned_block=pinned,
    )
