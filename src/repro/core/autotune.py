"""Horizontal tile-size autotuning for the Pallas backend.

The Pallas code generator bakes one ``_BLOCK_DEFAULT`` into the module, but
the best ``(BI, BJ)`` tile depends on the domain, the stencil's VMEM
footprint, and the DMA/compute balance — exactly the schedule knob the paper
argues the toolchain (not the user) should turn.  This module times a small
set of candidate tiles against the stencil's own generated ``run`` (on
synthetic inputs shaped from the module's field metadata, the
``benchmarks/run.py`` timing discipline: warmup, then best-of-N) and picks
the fastest.

Results are **keyed on the pass-aware cache fingerprint** from
``core/caching.py`` and persisted as ``<name>_<fp>.tune.json`` next to the
generated module, so a second build of the identical IR + options is a pure
cache hit — the search never reruns.  A different ``opt_level`` / pass set /
codegen option is a different fingerprint and tunes (and persists)
independently.  The chosen tile and per-candidate timings surface through
``exec_info["autotune"]`` on the stencil call.

Candidates are filtered against the module's per-tile VMEM estimate
(``_vmem_bytes``) so the search never times a tile that cannot fit.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace as otrace

from . import caching

# (BI, BJ) candidates: sublane multiples × the 128-lane TPU vector width.
# Clamped to the domain (and deduplicated) before timing.
DEFAULT_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (8, 128),
    (16, 128),
    (32, 128),
    (8, 256),
    (16, 256),
)

# (TI, TJ) candidates for the tiled numpy backend (codegen_array stage
# tiling): row-major arrays want long contiguous j-runs; the i side sets the
# L2 working-set of a tile's live stage chain.
DEFAULT_NUMPY_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (32, 64),
    (32, 128),
    (64, 128),
    (64, 256),
    (128, 128),
)


def _is_numpy_module(module) -> bool:
    return getattr(module, "_BACKEND", None) == "numpy"

# don't time tiles whose estimated footprint exceeds ~3/4 of a 16 MB VMEM core
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

_lock = threading.Lock()
_memory: Dict[Tuple[str, str, str], Dict[str, Any]] = {}


def candidate_blocks(
    module,
    domain: Tuple[int, int, int],
    candidates: Optional[Sequence[Tuple[int, int]]] = None,
) -> List[Tuple[int, int]]:
    """Domain-clamped, VMEM-filtered, deduplicated candidate tiles."""
    ni, nj, nk = domain
    defaults = DEFAULT_NUMPY_CANDIDATES if _is_numpy_module(module) else DEFAULT_CANDIDATES
    cands = [tuple(c) for c in (candidates or defaults)]
    default = tuple(getattr(module, "_BLOCK_DEFAULT", (8, 128)))
    if default not in cands:
        cands.insert(0, default)
    vmem_bytes = getattr(module, "_vmem_bytes", None)
    seen: set = set()
    out: List[Tuple[int, int]] = []
    for bi, bj in cands:
        eff = (min(int(bi), ni), min(int(bj), nj))
        if eff in seen:
            continue
        seen.add(eff)
        if vmem_bytes is not None and vmem_bytes(eff[0], eff[1], nk) > VMEM_BUDGET_BYTES:
            continue
        out.append(eff)
    if not out:  # every candidate over budget: fall back to the clamped default
        out.append((min(default[0], ni), min(default[1], nj)))
    return out


def _synthetic_call_args(module, domain: Tuple[int, int, int], batch: Optional[int] = None):
    """Fields/scalars/origins for timing, built from the module's metadata.

    Values are uniform in [0.5, 1.5]: away from zero so division-heavy
    stencils (Thomas solvers) stay finite, with enough variation that no
    arithmetic folds away.  ``batch`` prepends a member axis to every field
    so batched runs are timed as they will execute (under ``jax.vmap``).
    Numpy modules (``_BACKEND == 'numpy'``) get mutable host arrays — their
    generated ``run`` writes fields in place.
    """
    if _is_numpy_module(module):
        jnp = np
    else:
        import jax.numpy as jnp

    ni, nj, nk = domain
    H = int(getattr(module, "_H", 0))
    rng = np.random.default_rng(0)
    fields: Dict[str, Any] = {}
    origins: Dict[str, Tuple[int, int, int]] = {}
    for name, axes in module._AXES.items():
        dtype = module._DTYPES[name]
        if axes == ("I", "J", "K"):
            shape: Tuple[int, ...] = (ni + 2 * H, nj + 2 * H, nk)
            origins[name] = (H, H, 0)
        elif axes == ("I", "J"):
            shape = (ni + 2 * H, nj + 2 * H)
            origins[name] = (H, H, 0)
        else:
            shape = (nk,)
            origins[name] = (0, 0, 0)
        if batch is not None:
            shape = (batch,) + shape
        fields[name] = jnp.asarray(0.5 + rng.random(shape), dtype=dtype)
    scalars = {s: 0.5 for s in module._SCALARS}
    return fields, scalars, origins


def batch_count(module, operand_shapes) -> Optional[int]:
    """The leading member-batch extent implied by the operand shapes, or
    ``None`` for an unbatched call (ranks match the module's field axes)."""
    if not operand_shapes:
        return None
    axes = module._AXES
    for name, shape in operand_shapes:
        if name in axes and len(shape) == len(axes[name]) + 1:
            return int(shape[0])
    return None


def _time_block(
    module,
    fields,
    scalars,
    domain: Tuple[int, int, int],
    origins,
    block: Tuple[int, int],
    warmup: int,
    iters: int,
    batch: Optional[int] = None,
) -> float:
    """Best-of-``iters`` wall time of one tiled call, in microseconds."""
    if _is_numpy_module(module):
        # synchronous host execution: nothing to block on, no batching.
        # The generated run() writes fields in place, so each candidate gets
        # a fresh copy of the synthetic data — otherwise recurrence stencils
        # would drift values across candidates and bias the timings.
        fields = {n: np.array(v, copy=True) for n, v in fields.items()}

        def call():
            module.run(fields, scalars, domain, origins, block=block)

        for _ in range(max(1, warmup)):
            call()
        best = float("inf")
        for _ in range(max(1, iters)):
            t0 = time.perf_counter()
            call()
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    import jax

    if batch is None:
        run = lambda: module.run(fields, scalars, domain, origins, block=block)  # noqa: E731
    else:
        vmapped = jax.vmap(
            lambda f, s: module.run(f, s, domain, origins, block=block), in_axes=(0, None)
        )
        run = lambda: vmapped(fields, scalars)  # noqa: E731

    def call():
        jax.block_until_ready(run())

    for _ in range(max(1, warmup)):
        call()  # compile + cache warm
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        call()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _domain_key(domain: Tuple[int, int, int], candidates, operand_shapes=None) -> str:
    """Store key for one tuning record.

    The FULL operand shapes participate alongside the compute domain: a
    member-batched (vmapped) run has the same ``(ni, nj, nk)`` domain as the
    unbatched one but a different DMA/compute balance per tile, so it must
    never reuse a ``(BI, BJ)`` tuned for unbatched shapes (and vice versa).
    """
    key = "x".join(str(d) for d in domain)
    if operand_shapes:
        key += "|" + ";".join(
            f"{name}:{'x'.join(str(s) for s in shape)}" for name, shape in sorted(operand_shapes)
        )
    if candidates:
        key += "|" + ";".join(f"{bi}x{bj}" for bi, bj in candidates)
    return key


def _load_store(path) -> Dict[str, Any]:
    try:
        data = json.loads(path.read_text())
        if isinstance(data, dict) and "domains" in data:
            return data
    except (OSError, ValueError):
        pass
    return {"version": 1, "domains": {}}


def select_block(
    module,
    name: str,
    fingerprint: str,
    domain: Tuple[int, int, int],
    *,
    candidates: Optional[Sequence[Tuple[int, int]]] = None,
    warmup: int = 1,
    iters: int = 3,
    operand_shapes=None,
) -> Tuple[Tuple[int, int], Dict[str, Any]]:
    """The tuned ``(BI, BJ)`` for ``domain``, searching at most once.

    ``operand_shapes`` — ``((field_name, shape), ...)`` of the actual call —
    folds the full operand geometry (member/batch axes included) into the
    store key, and batched shapes are timed under ``jax.vmap`` exactly as
    they will run.  Returns ``(block, record)`` where ``record`` carries the
    per-candidate timings (``cache_hit`` marks a persisted result being
    reused).
    """
    domain = tuple(int(d) for d in domain)
    cands = [tuple(c) for c in candidates] if candidates else None
    operand_shapes = (
        tuple(sorted((str(n), tuple(int(x) for x in s)) for n, s in operand_shapes))
        if operand_shapes
        else None
    )
    dkey = _domain_key(domain, cands, operand_shapes)
    path = caching.tuning_path(name, fingerprint)

    with _lock:
        mem = _memory.get((name, fingerprint, dkey))
        if mem is not None:
            rec = dict(mem, cache_hit=True)
            return tuple(rec["block"]), rec
        store = _load_store(path)
        entry = store["domains"].get(dkey)
        if entry is not None:
            rec = dict(entry, cache_hit=True)
            _memory[(name, fingerprint, dkey)] = dict(entry)
            return tuple(rec["block"]), rec

    with otrace.span(
        "stencil.autotune", category="compile", stencil=name, domain=list(domain)
    ) as tsp:
        blocks = candidate_blocks(module, domain, cands)
        batch = batch_count(module, operand_shapes)
        fields, scalars, origins = _synthetic_call_args(module, domain, batch)
        timings: List[Dict[str, Any]] = []
        for block in blocks:
            us = _time_block(
                module, fields, scalars, domain, origins, block, warmup, iters, batch
            )
            timings.append({"block": list(block), "us": us})
        best = min(timings, key=lambda t: t["us"])
        tsp.set("candidates", len(blocks))
        tsp.set("block", list(best["block"]))
        tsp.set("cache_hit", False)
    record: Dict[str, Any] = {
        "block": list(best["block"]),
        "timings": timings,
        "domain": list(domain),
        "batch": batch,
        "cache_hit": False,
    }

    with _lock:
        persisted = {k: v for k, v in record.items() if k != "cache_hit"}
        _memory[(name, fingerprint, dkey)] = persisted
        store = _load_store(path)
        store["domains"][dkey] = persisted
        try:
            tmp = path.with_suffix(".json.tmp")
            tmp.write_text(json.dumps(store, indent=2) + "\n")
            tmp.replace(path)
        except OSError:
            pass  # read-only cache: in-memory result still serves this process
    return tuple(record["block"]), record


def record_batch_observation(
    name: str,
    fingerprint: str,
    batch: int,
    us_per_step: float,
    *,
    source: str = "serving",
) -> None:
    """Merge one *observed* ``(batch size → wall)`` record into the tune store.

    The serving engine calls this with the per-step dispatch wall of batches
    it actually ran, closing the loop the other way around: ``select_block``
    writes measurements the tuner made, this writes measurements the traffic
    made.  Records land under their own ``serving|batch=N`` domain key —
    they carry ``"batch"``, so :func:`repro.serving.engine.tuned_member_counts`
    picks the extents up as preferred padding targets, while the key shape
    keeps them from ever colliding with a tuner-written ``(BI, BJ)`` record.

    Concurrency: the store is read-merged-rewritten under the module lock
    with an atomic (pid-suffixed tmp + ``replace``) publish, so concurrent
    engines — or an engine racing the tuner — never clobber each other's
    *other* keys; the worst cross-process race loses one observation, never
    the store.  The best (minimum) wall wins; observation counts accumulate.
    An unwritable store is ignored — feedback is an optimization, never a
    liveness dependency."""
    path = caching.tuning_path(name, fingerprint)
    dkey = f"serving|batch={int(batch)}"
    with _lock:
        store = _load_store(path)
        prev = store["domains"].get(dkey)
        count = 1
        best = float(us_per_step)
        if isinstance(prev, dict):
            count += int(prev.get("count", 0))
            prev_us = prev.get("us_per_step")
            if isinstance(prev_us, (int, float)):
                best = min(best, float(prev_us))
        store["domains"][dkey] = {
            "batch": int(batch),
            "us_per_step": best,
            "count": count,
            "source": source,
        }
        try:
            tmp = path.with_name(f"{path.name}.{os.getpid()}.tmp")
            tmp.write_text(json.dumps(store, indent=2) + "\n")
            tmp.replace(path)
        except OSError:
            pass  # read-only store: the next engine re-observes, nothing breaks
