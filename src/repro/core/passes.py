"""Optimization pass pipeline over the Implementation IR.

This is the analysis/transform layer the paper's toolchain puts between the
frontend and the code generators (§2.3): the *same* definition IR is
specialized by composable, individually toggleable passes before any backend
sees it.  Each pass is a named ``Pass`` with a legality argument documented
in ``docs/passes.md``; a shared ``PassContext`` records per-pass wall time
and before/after IR statistics, surfaced to users through
``exec_info["pass_report"]`` (mirroring the paper's Fig. 3 instrumentation).

Pipeline (in application order; ``min_opt_level`` in parentheses)::

    constant_folding        (3)  literal arithmetic + algebraic identities + dead branches
    dead_temp_pruning       (2)  liveness fixpoint: drop unread temporaries and the
                                 stages that only feed them, shrink extents
    interval_splitting      (1)  peel carry-free boundary intervals off sequential
                                 sweeps into vectorized PARALLEL multi-stages so the
                                 steady-state interior loop carries less state
    interval_merging        (2)  merge adjacent k-intervals with identical stage bodies
    multistage_fusion       (1)  fuse adjacent PARALLEL multi-stages so the Pallas
                                 backend keeps intermediates VMEM-resident
    algebraic_reassociation (2)  canonicalize commutative (and, with ``exact=False``,
                                 associative) float chains so equivalent spellings
                                 share one shape for cross_stage_cse to hit
    cross_stage_cse         (3)  hash subexpressions across the fused stages (modulo
                                 a uniform offset shift) and hoist repeats into new
                                 temporaries computed once
    temp_demotion           (2)  demote single-interval, zero-offset temporaries to
                                 stage-local values (no field allocation / DMA)

``opt_level`` semantics: 0 = verbatim lowering (no passes), 1 = fusion +
interval splitting (+ numpy stage tiling, a backend-schedule pass living in
``codegen_array.py``), 2 = + structural passes + reassociation, 3 (default)
= everything.  Individual passes toggle via
``backend_opts={"disable_passes": (...,)}`` / ``{"enable_passes": (...)}``;
``backend_opts={"exact": False}`` additionally unlocks the value-changing
(reassociating) rewrites of ``algebraic_reassociation``.

The environment variables ``REPRO_OPT_LEVEL`` and ``REPRO_DISABLE_PASSES``
(comma-separated pass names) shift the *defaults* seen by every stencil
build in the process — the CI pass-matrix leg uses them to re-run the whole
differential corpus with one pass knocked out, so a miscompiling pass fails
with its name in the job title.
"""

from __future__ import annotations

import dataclasses
import math
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

from . import analysis, ir

DEFAULT_OPT_LEVEL = 3

# Backend-schedule passes: toggled through the same ``opt_level`` /
# ``disable_passes`` surface (and folded into the cache fingerprint via the
# pass configuration) but applied inside a code generator rather than as an
# IR → IR transform.  ``numpy_stage_tiling`` lives in ``codegen_array.py``.
SCHEDULE_PASS_NAMES: Tuple[str, ...] = ("numpy_stage_tiling",)


# ---------------------------------------------------------------------------
# Instrumentation
# ---------------------------------------------------------------------------


def impl_stats(impl: ir.StencilImplementation) -> Dict[str, int]:
    """Coarse IR size statistics (what the passes are expected to shrink)."""
    return {
        "multi_stages": len(impl.multi_stages),
        "intervals": sum(len(ms.intervals) for ms in impl.multi_stages),
        "stages": sum(len(itv.stages) for ms in impl.multi_stages for itv in ms.intervals),
        "temporaries": len(impl.temporaries),
        "locals": len(impl.local_decls),
    }


@dataclass
class PassContext:
    """Shared state of one pipeline run: configuration + per-pass records."""

    opt_level: int = DEFAULT_OPT_LEVEL
    # IEEE-exact mode (default): passes may only apply bit-preserving
    # rewrites.  ``exact=False`` (via backend_opts) additionally legalizes
    # value-changing but algebraically-valid rewrites (reassociation).
    exact: bool = True
    records: List[Dict[str, Any]] = field(default_factory=list)
    # per-pass structured detail (e.g. CSE's eliminated-occurrence count),
    # stashed by Pass.apply and folded into the next record
    _detail: Optional[Dict[str, Any]] = None

    def set_detail(self, detail: Dict[str, Any]) -> None:
        self._detail = dict(detail)

    def pop_detail(self) -> Optional[Dict[str, Any]]:
        d, self._detail = self._detail, None
        return d

    def record(
        self,
        name: str,
        seconds: float,
        before: Dict[str, int],
        after: Dict[str, int],
        changed: bool,
    ) -> None:
        rec = {
            "pass": name,
            "seconds": seconds,
            "before": before,
            "after": after,
            "changed": changed,
        }
        detail = self.pop_detail()
        if detail is not None:
            rec["detail"] = detail
        self.records.append(rec)


class Pass:
    """A named, toggleable IR → IR transform."""

    name: str = "pass"
    min_opt_level: int = 1

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        raise NotImplementedError

    def __call__(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        before = impl_stats(impl)
        t0 = time.perf_counter()
        out = self.apply(impl, ctx)
        seconds = time.perf_counter() - t0
        # structural (deep) inequality: passes may rewrite expressions without
        # moving any of the coarse stats
        ctx.record(self.name, seconds, before, impl_stats(out), out != impl)
        return out


# ---------------------------------------------------------------------------
# Pass 1: constant / scalar folding
# ---------------------------------------------------------------------------

_BINOP_FOLD = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "//": lambda a, b: a // b,
    "%": lambda a, b: a % b,
    "**": lambda a, b: a ** b,
    "and": lambda a, b: bool(a and b),
    "or": lambda a, b: bool(a or b),
    "<": lambda a, b: a < b,
    ">": lambda a, b: a > b,
    "<=": lambda a, b: a <= b,
    ">=": lambda a, b: a >= b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
}

# Pure math builtins safe to evaluate at compile time (python floats are IEEE
# doubles, exactly what the generated code computes on literal operands).
_NATIVE_FOLD = {
    "abs": abs,
    "min": min,
    "max": max,
    # floored modulo, matching np.mod/jnp.mod (and python %) — NOT math.fmod
    "mod": lambda a, b: a % b,
    "sqrt": math.sqrt,
    "exp": math.exp,
    "log": math.log,
    "log2": math.log2,
    "pow": pow,
    "sin": math.sin,
    "cos": math.cos,
    "tan": math.tan,
    "arcsin": math.asin,
    "arccos": math.acos,
    "arctan": math.atan,
    "sinh": math.sinh,
    "cosh": math.cosh,
    "tanh": math.tanh,
    "erf": math.erf,
    "erfc": math.erfc,
    "floor": math.floor,
    "ceil": math.ceil,
    "trunc": math.trunc,
    "isfinite": math.isfinite,
    "isnan": math.isnan,
}


def _literal(value: Any) -> ir.Literal:
    if isinstance(value, bool):
        return ir.Literal(value, "bool")
    if isinstance(value, int):
        return ir.Literal(value, "int")
    return ir.Literal(float(value), "float")


def _is_float_lit(e: ir.Expr, value: float) -> bool:
    return isinstance(e, ir.Literal) and e.dtype == "float" and e.value == value


def _fold_expr_node(e: ir.Expr) -> ir.Expr:
    """Fold one node whose children are already folded.  Anything that could
    raise (division by zero, domain errors) is left for the runtime."""
    if isinstance(e, ir.UnaryOp) and isinstance(e.operand, ir.Literal):
        if e.op == "-" and e.operand.dtype in ("int", "float"):
            return ir.Literal(-e.operand.value, e.operand.dtype)
        if e.op == "not":
            return ir.Literal(not e.operand.value, "bool")
    if isinstance(e, ir.BinOp):
        left, right = e.left, e.right
        if isinstance(left, ir.Literal) and isinstance(right, ir.Literal):
            fn = _BINOP_FOLD.get(e.op)
            if fn is not None:
                try:
                    return _literal(fn(left.value, right.value))
                except Exception:
                    return e
        # value-preserving identities (IEEE-exact: x·1, x/1, x−0 preserve every
        # input bit-for-bit; x+0 does NOT — it flips −0.0 to +0.0 — so it is
        # deliberately absent)
        if e.op == "*" and _is_float_lit(right, 1.0):
            return left
        if e.op == "*" and _is_float_lit(left, 1.0):
            return right
        if e.op == "/" and _is_float_lit(right, 1.0):
            return left
        if e.op == "-" and _is_float_lit(right, 0.0):
            return left
    if isinstance(e, ir.TernaryOp) and isinstance(e.cond, ir.Literal):
        return e.true_expr if e.cond.value else e.false_expr
    if isinstance(e, ir.NativeCall) and all(isinstance(a, ir.Literal) for a in e.args):
        fn = _NATIVE_FOLD.get(e.func)
        if fn is not None:
            try:
                return _literal(fn(*[a.value for a in e.args]))
            except Exception:
                return e
    if isinstance(e, ir.Cast) and isinstance(e.expr, ir.Literal):
        # only value-exact casts fold: narrowing (float32/bfloat16, or an
        # int literal outside the target's range, which wraps at runtime)
        # would change the value the runtime computes.
        _INT_BITS = {"int32": 32, "int64": 64}
        if (
            e.dtype in _INT_BITS
            and e.expr.dtype == "int"
            and -(2 ** (_INT_BITS[e.dtype] - 1)) <= e.expr.value < 2 ** (_INT_BITS[e.dtype] - 1)
        ):
            return e.expr
        if e.dtype == "float64" and e.expr.dtype in ("int", "float", "bool"):
            return ir.Literal(float(e.expr.value), "float")
    return e


def _fold_stmt(s: ir.Stmt) -> List[ir.Stmt]:
    if isinstance(s, ir.Assign):
        return [ir.Assign(s.target, ir.map_exprs_bottom_up(s.value, _fold_expr_node))]
    if isinstance(s, ir.If):
        cond = ir.map_exprs_bottom_up(s.cond, _fold_expr_node)
        body = [f for b in s.body for f in _fold_stmt(b)]
        orelse = [f for b in s.orelse for f in _fold_stmt(b)]
        if isinstance(cond, ir.Literal):
            return body if cond.value else orelse
        if not body and not orelse:
            return []
        if not body:  # folded-away then-branch: invert so no backend emits an empty block
            return [ir.If(ir.UnaryOp("not", cond), tuple(orelse))]
        return [ir.If(cond, tuple(body), tuple(orelse))]
    if isinstance(s, ir.While):
        cond = ir.map_exprs_bottom_up(s.cond, _fold_expr_node)
        if isinstance(cond, ir.Literal) and not cond.value:
            return []
        return [ir.While(cond, tuple(f for b in s.body for f in _fold_stmt(b)))]
    return [s]


class ConstantFolding(Pass):
    """Fold literal arithmetic, prune dead conditional branches, and apply
    value-preserving algebraic identities in stage expressions.

    Legality: folding mirrors exactly what the generated code would compute —
    python-float (IEEE double) arithmetic on literal operands; anything that
    could raise or change a value (narrowing casts, division by zero) is left
    in place.
    """

    name = "constant_folding"
    min_opt_level = 3

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        changed = False
        multi_stages: List[ir.MultiStage] = []
        for ms in impl.multi_stages:
            intervals: List[ir.MultiStageInterval] = []
            for itv in ms.intervals:
                stages: List[ir.Stage] = []
                for st in itv.stages:
                    stmts = tuple(f for s in st.stmts for f in _fold_stmt(s))
                    if stmts != st.stmts:
                        changed = True
                    if stmts:
                        stages.append(ir.make_stage(stmts, st.compute_extent))
                if stages:
                    intervals.append(ir.MultiStageInterval(itv.interval, tuple(stages)))
            if intervals:
                multi_stages.append(ir.MultiStage(ms.order, tuple(intervals)))
        if not changed:
            return impl
        impl = dataclasses.replace(impl, multi_stages=tuple(multi_stages))
        # folding may have killed reads → temporaries can die, extents shrink
        return analysis.recompute_implementation(impl)


# ---------------------------------------------------------------------------
# Pass 2: dead-temporary pruning
# ---------------------------------------------------------------------------


class DeadTempPruning(Pass):
    """Drop temporaries that are never read (and the stages that only feed
    them) and shrink all extents to what the surviving statements require.

    Legality: temporaries are never observable outside the stencil (paper
    §2.2), so removing unread ones cannot change any output.
    """

    name = "dead_temp_pruning"
    min_opt_level = 2

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        return analysis.recompute_implementation(impl)


# ---------------------------------------------------------------------------
# Pass 3: vertical interval splitting (boundary specialization)
# ---------------------------------------------------------------------------


def _ms_writes(ms: ir.MultiStage) -> set:
    return {w for itv in ms.intervals for st in itv.stages for w in st.writes}


def _interval_carry_free(itv: ir.MultiStageInterval, writes: set) -> bool:
    """True when no statement of ``itv`` reads a multi-stage-written field at
    a nonzero vertical offset — the interval has no loop-carried input, so
    its levels are independent of the sweep."""
    for st in itv.stages:
        for stmt in st.stmts:
            for rname, off in ir.stmt_reads(stmt):
                if off[2] != 0 and rname in writes:
                    return False
    return True


class IntervalSplitting(Pass):
    """Peel boundary intervals with no loop-carried input off FORWARD /
    BACKWARD multi-stages into their own *PARALLEL* multi-stages — the
    boundary specialization of the ROADMAP: the first/last levels of a sweep
    (``interval(0, 1)`` inits, ``interval(-1, None)`` closures) usually seed
    or drain the recurrence without depending on it, so they become
    vectorized blocks and the steady-state interior ``fori_loop`` carries
    only the true recurrence state.

    Mechanics: intervals are considered in execution order (descending for
    BACKWARD).  The leading run of *carry-free* intervals — no read of any
    field written in this multi-stage at a nonzero vertical offset — is
    peeled into a PARALLEL multi-stage placed before the remaining sweep;
    the trailing run is peeled symmetrically after it.  A multi-stage whose
    every interval is carry-free converts to PARALLEL outright (a "sweep"
    with no recurrence at all).

    Legality:

    * Both the sequential and PARALLEL emitters execute one interval at a
      time, stage by stage, so peeling whole intervals preserves statement
      order exactly; within a carry-free interval, converting the per-level
      loop to one vectorized block is observationally identical because no
      statement reads multi-stage-written state at a vertical offset (and
      horizontal reads never cross k-planes).
    * Peeled intervals are mutually independent (disjoint k-slabs, no
      carried reads), so each peeled run is re-sorted into ascending order —
      this lets ``interval_merging`` re-merge identical boundary bodies that
      a BACKWARD sweep stored descending.
    * A peel may reclassify a sweep-local temporary as cross-multi-stage
      state (``analysis.sequential_carry_plan`` would then carry it as a
      full 3-D array instead of a rolling window).  Every candidate peel is
      therefore checked against the carry plan of the whole stencil and
      rejected if it would increase ``(full carries, window depth)``
      lexicographically — splitting never pessimizes the k-blocked schedule.

    The peeled-interval count is reported as ``intervals_split`` in the pass
    record's ``detail`` (surfaced via ``exec_info["pass_report"]`` and the
    smoke bench).
    """

    name = "interval_splitting"
    min_opt_level = 1

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        detail = {"intervals_split": 0, "parallelized_sweeps": 0, "rejected_by_carry_guard": 0}
        current = impl
        changed = False
        mi = 0
        while mi < len(current.multi_stages):
            ms = current.multi_stages[mi]
            if ms.order == ir.IterationOrder.PARALLEL:
                mi += 1
                continue
            pieces = self._peel(ms)
            if pieces is None:
                mi += 1
                continue
            trial = dataclasses.replace(
                current,
                multi_stages=current.multi_stages[:mi] + tuple(pieces) + current.multi_stages[mi + 1:],
            )
            if self._carry_totals(trial) > self._carry_totals(current):
                detail["rejected_by_carry_guard"] += 1
                mi += 1
                continue
            detail["intervals_split"] += sum(
                len(p.intervals) for p in pieces if p.order == ir.IterationOrder.PARALLEL
            )
            if all(p.order == ir.IterationOrder.PARALLEL for p in pieces):
                detail["parallelized_sweeps"] += 1
            current = trial
            changed = True
            mi += len(pieces)
        ctx.set_detail(detail)
        if not changed:
            return impl
        # peeled intervals now run under PARALLEL extent semantics (vertical
        # reads become real k-extents, not loop-carried) → re-analyze
        return analysis.recompute_implementation(current)

    @staticmethod
    def _peel(ms: ir.MultiStage) -> Optional[List[ir.MultiStage]]:
        writes = _ms_writes(ms)
        flags = [_interval_carry_free(itv, writes) for itv in ms.intervals]
        n = len(flags)
        p = 0
        while p < n and flags[p]:
            p += 1
        q = n
        while q > p and flags[q - 1]:
            q -= 1
        if p == 0 and q == n:
            return None  # nothing carry-free at either boundary

        def parallel_piece(intervals) -> ir.MultiStage:
            ordered = sorted(intervals, key=lambda itv: itv.interval.start.key())
            return ir.MultiStage(ir.IterationOrder.PARALLEL, tuple(ordered))

        pieces: List[ir.MultiStage] = []
        if p:
            pieces.append(parallel_piece(ms.intervals[:p]))
        if q > p:
            pieces.append(ir.MultiStage(ms.order, tuple(ms.intervals[p:q])))
        if q < n:
            pieces.append(parallel_piece(ms.intervals[q:]))
        return pieces

    @staticmethod
    def _carry_totals(impl: ir.StencilImplementation) -> Tuple[int, int]:
        """(full 3-D carries, summed window depth) across all sweeps — the
        nk-independent lexicographic size of the carried state."""
        plans = analysis.sequential_carry_plan(impl)
        full = sum(len(p.full) for p in plans.values())
        depth = sum(d for p in plans.values() for _, d in p.window)
        return (full, depth)


# ---------------------------------------------------------------------------
# Pass 4: k-interval merging
# ---------------------------------------------------------------------------


class IntervalMerging(Pass):
    """Merge adjacent vertical intervals whose stage bodies are structurally
    identical into a single interval (fewer loop bounds, larger fused blocks).

    Legality: the merged interval executes the same statements over the union
    k-range; bodies are compared with structural equality (same statements
    AND same compute extents), and only representation-adjacent bounds merge,
    so the rewrite is domain-size independent.  For BACKWARD multi-stages the
    interval list is stored in execution (descending) order, so adjacency is
    checked in the reversed direction.

    PARALLEL multi-stages additionally require the body to read no
    body-written field at a nonzero vertical offset: per-interval execution
    completes a writer stage over one slab before a reader stage looks up or
    down within it, so merging the slabs would let the reader observe planes
    the original schedule had not yet written (the ``t`` / ``t[0, 0, 1]``
    miscompile the differential fuzzer caught).
    """

    name = "interval_merging"
    min_opt_level = 2

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        multi_stages: List[ir.MultiStage] = []
        for ms in impl.multi_stages:
            backward = ms.order == ir.IterationOrder.BACKWARD
            parallel = ms.order == ir.IterationOrder.PARALLEL
            merged: List[ir.MultiStageInterval] = []
            for itv in ms.intervals:
                if (
                    merged
                    and ir.stages_structurally_equal(merged[-1].stages, itv.stages)
                    and (not parallel or self._parallel_merge_safe(itv.stages))
                ):
                    prev = merged[-1]
                    if not backward and ir.intervals_adjacent(prev.interval, itv.interval):
                        merged[-1] = ir.MultiStageInterval(
                            ir.interval_span(prev.interval, itv.interval), prev.stages
                        )
                        continue
                    if backward and ir.intervals_adjacent(itv.interval, prev.interval):
                        merged[-1] = ir.MultiStageInterval(
                            ir.interval_span(itv.interval, prev.interval), prev.stages
                        )
                        continue
                merged.append(itv)
            multi_stages.append(ir.MultiStage(ms.order, tuple(merged)))
        return dataclasses.replace(impl, multi_stages=tuple(multi_stages))

    @staticmethod
    def _parallel_merge_safe(stages: Tuple[ir.Stage, ...]) -> bool:
        """No vertical read of a body-written field → slab merge is exact."""
        writes = {w for st in stages for w in st.writes}
        for st in stages:
            for stmt in st.stmts:
                for rname, off in ir.stmt_reads(stmt):
                    if off[2] != 0 and rname in writes:
                        return False
        return True


# ---------------------------------------------------------------------------
# Pass 5: multi-stage fusion
# ---------------------------------------------------------------------------


class MultiStageFusion(Pass):
    """Fuse adjacent PARALLEL multi-stages into one — the GridTools fusion
    that lets the Pallas backend keep all intermediate stages VMEM-resident
    instead of round-tripping through HBM between kernels.

    Two compatible shapes:

    * identical single-interval structure → stages are concatenated into the
      shared interval (enables cross-computation temporary demotion);
    * anything else → the interval lists are concatenated *in order*.  Our
      backends execute a PARALLEL multi-stage interval-by-interval,
      stage-by-stage, each statement fully vectorized over its region, so
      concatenation preserves the original statement order exactly — which
      makes it unconditionally legal.  Sequential (FORWARD/BACKWARD)
      multi-stages never fuse: their k-sweep ordering is semantic.
    """

    name = "multistage_fusion"
    min_opt_level = 1

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        fused: List[ir.MultiStage] = []
        for ms in impl.multi_stages:
            if (
                fused
                and ms.order == ir.IterationOrder.PARALLEL
                and fused[-1].order == ir.IterationOrder.PARALLEL
            ):
                prev = fused.pop()
                if (
                    len(prev.intervals) == 1
                    and len(ms.intervals) == 1
                    and prev.intervals[0].interval == ms.intervals[0].interval
                ):
                    intervals = (
                        ir.MultiStageInterval(
                            prev.intervals[0].interval,
                            tuple(prev.intervals[0].stages) + tuple(ms.intervals[0].stages),
                        ),
                    )
                else:
                    intervals = tuple(prev.intervals) + tuple(ms.intervals)
                fused.append(ir.MultiStage(ir.IterationOrder.PARALLEL, intervals))
            else:
                fused.append(ms)
        return dataclasses.replace(impl, multi_stages=tuple(fused))


# ---------------------------------------------------------------------------
# Pass 6: algebraic reassociation / commutative canonicalization
# ---------------------------------------------------------------------------

_COMMUTATIVE_OPS = {"+", "*"}


def _expr_sort_key(e: ir.Expr) -> Tuple:
    """Deterministic structural ordering key for commutative canonicalization.

    Field offsets participate as *numeric* tuples, so two operand lists that
    differ only by a uniform offset shift sort into the same relative order —
    which keeps this canonicalization composable with ``cross_stage_cse``'s
    shift-canonical matching.
    """
    if isinstance(e, ir.Literal):
        return ("0literal", e.dtype, repr(e.value))
    if isinstance(e, ir.ScalarRef):
        return ("1scalar", e.name)
    if isinstance(e, ir.FieldAccess):
        return ("2field", e.name, tuple(int(x) for x in e.offset))
    if isinstance(e, ir.UnaryOp):
        return ("3unary", e.op, _expr_sort_key(e.operand))
    if isinstance(e, ir.BinOp):
        return ("4bin", e.op, _expr_sort_key(e.left), _expr_sort_key(e.right))
    if isinstance(e, ir.TernaryOp):
        return (
            "5ternary",
            _expr_sort_key(e.cond),
            _expr_sort_key(e.true_expr),
            _expr_sort_key(e.false_expr),
        )
    if isinstance(e, ir.NativeCall):
        return ("6call", e.func) + tuple(_expr_sort_key(a) for a in e.args)
    if isinstance(e, ir.Cast):
        return ("7cast", e.dtype, _expr_sort_key(e.expr))
    return ("9other", repr(e))


def _flatten_chain(e: ir.Expr, op: str) -> List[ir.Expr]:
    if isinstance(e, ir.BinOp) and e.op == op:
        return _flatten_chain(e.left, op) + _flatten_chain(e.right, op)
    return [e]


def _rebuild_chain(op: str, terms: List[ir.Expr]) -> ir.Expr:
    out = terms[0]
    for t in terms[1:]:
        out = ir.BinOp(op, out, t)
    return out


class AlgebraicReassociation(Pass):
    """Canonicalize commutative float chains so algebraically-equal spellings
    share one structural shape, which is what ``cross_stage_cse`` hashes —
    ``u * v`` and ``v * u`` (or k-shifted neighbor sums written in either
    order) collapse into one hoisted temporary instead of two misses.

    Two tiers, split by IEEE legality:

    * **Commutative canonicalization** (always on): operands of ``+`` / ``*``
      are ordered by a deterministic structural key.  IEEE-754 addition and
      multiplication are commutative *including* rounding — ``a + b`` and
      ``b + a`` produce the same bits — so this tier is exact and safe for
      the bit-identical differential suite.
    * **Reassociation** (only with ``backend_opts={"exact": False}``): whole
      same-op chains are flattened, sorted, and rebuilt left-associated
      (``a + (b + c)`` → ``(a + b) + c`` with sorted terms).  Changing the
      association changes rounding, so users must explicitly waive bit
      reproducibility — the flag travels with the pass configuration into
      the cache fingerprint.

    Node-rewrite counts surface as ``commuted`` / ``reassociated`` in the
    pass record's ``detail``.
    """

    name = "algebraic_reassociation"
    min_opt_level = 2

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        counts = {"commuted": 0, "reassociated": 0, "exact": ctx.exact}
        exact = ctx.exact

        def canon(e: ir.Expr) -> ir.Expr:
            if not (isinstance(e, ir.BinOp) and e.op in _COMMUTATIVE_OPS):
                return e
            if not exact:
                terms = _flatten_chain(e, e.op)
                if len(terms) > 2:
                    rebuilt = _rebuild_chain(e.op, sorted(terms, key=_expr_sort_key))
                    if rebuilt != e:
                        counts["reassociated"] += 1
                        return rebuilt
                    return e
            if _expr_sort_key(e.right) < _expr_sort_key(e.left):
                counts["commuted"] += 1
                return ir.BinOp(e.op, e.right, e.left)
            return e

        changed = False
        multi_stages: List[ir.MultiStage] = []
        for ms in impl.multi_stages:
            intervals: List[ir.MultiStageInterval] = []
            for itv in ms.intervals:
                stages: List[ir.Stage] = []
                for st in itv.stages:
                    stmts = tuple(ir.map_stmt_exprs(s, canon) for s in st.stmts)
                    if stmts != st.stmts:
                        changed = True
                        stages.append(ir.make_stage(stmts, st.compute_extent))
                    else:
                        stages.append(st)
                intervals.append(ir.MultiStageInterval(itv.interval, tuple(stages)))
            multi_stages.append(ir.MultiStage(ms.order, tuple(intervals)))
        ctx.set_detail(counts)
        if not changed:
            return impl
        # pure expression-shape rewrite: accesses, extents and liveness are
        # untouched, so no re-analysis is needed
        return dataclasses.replace(impl, multi_stages=tuple(multi_stages))


# ---------------------------------------------------------------------------
# Pass 7: cross-stage common-subexpression elimination
# ---------------------------------------------------------------------------


_BOOL_BINOPS = {"<", ">", "<=", ">=", "==", "!=", "and", "or"}
_BOOL_NATIVES = {"isnan", "isfinite"}


class _DtypeConflict(Exception):
    """Raised when a subexpression mixes distinct concrete dtypes."""


def _infer_expr_dtype(
    e: ir.Expr,
    field_dtype: Dict[str, str],
    scalar_dtype: Dict[str, str],
) -> Optional[str]:
    """Concrete dtype of ``e``, None when only weak literals constrain it.

    Raises :class:`_DtypeConflict` on mixed concrete dtypes — the CSE pass
    skips such expressions rather than guess a promotion rule.
    """

    def unify(a: Optional[str], b: Optional[str]) -> Optional[str]:
        if a is None:
            return b
        if b is None or a == b:
            return a
        raise _DtypeConflict(f"{a} vs {b}")

    if isinstance(e, ir.Literal):
        return "bool" if e.dtype == "bool" else None
    if isinstance(e, ir.ScalarRef):
        return scalar_dtype.get(e.name)
    if isinstance(e, ir.FieldAccess):
        return field_dtype.get(e.name)
    if isinstance(e, ir.UnaryOp):
        inner = _infer_expr_dtype(e.operand, field_dtype, scalar_dtype)
        return "bool" if e.op == "not" else inner
    if isinstance(e, ir.BinOp):
        left = _infer_expr_dtype(e.left, field_dtype, scalar_dtype)
        right = _infer_expr_dtype(e.right, field_dtype, scalar_dtype)
        if e.op in _BOOL_BINOPS:
            return "bool"
        return unify(left, right)
    if isinstance(e, ir.TernaryOp):
        return unify(
            _infer_expr_dtype(e.true_expr, field_dtype, scalar_dtype),
            _infer_expr_dtype(e.false_expr, field_dtype, scalar_dtype),
        )
    if isinstance(e, ir.NativeCall):
        if e.func in _BOOL_NATIVES:
            return "bool"
        out: Optional[str] = None
        for a in e.args:
            out = unify(out, _infer_expr_dtype(a, field_dtype, scalar_dtype))
        return out
    if isinstance(e, ir.Cast):
        return e.dtype
    return None


def _expr_weight(e: ir.Expr) -> Tuple[int, int]:
    """(op_count, field_access_count) of ``e`` — the hoisting-worthiness metric."""
    ops = accesses = 0
    for node in ir.walk_exprs(e):
        if isinstance(node, ir.FieldAccess):
            accesses += 1
        elif isinstance(node, (ir.BinOp, ir.UnaryOp, ir.TernaryOp, ir.NativeCall, ir.Cast)):
            ops += 1
    return ops, accesses


def _cse_worthwhile(e: ir.Expr) -> bool:
    """Worth a temporary: compound, and either touches >= 2 field values or
    performs >= 2 operations on at least one (single accesses / bare
    negations are cheaper re-done than materialized)."""
    if not isinstance(e, (ir.BinOp, ir.UnaryOp, ir.TernaryOp, ir.NativeCall, ir.Cast)):
        return False
    ops, accesses = _expr_weight(e)
    return accesses >= 2 or (ops >= 2 and accesses >= 1)


def _canonicalize(e: ir.Expr) -> Tuple[Optional[ir.Expr], Tuple[int, int, int]]:
    """Shift ``e`` so its first field access sits at zero offset.

    Two subexpressions that differ only by a uniform offset shift (the
    ``gcv`` / ``gcv(k-1)`` motif of tridiagonal assembly) share a canonical
    form and can be computed once.  Returns (canonical expr, shift) where
    ``e == shift_accesses(canonical, shift)``; (None, 0-shift) when ``e``
    contains no field access.
    """
    for node in ir.walk_exprs(e):
        if isinstance(node, ir.FieldAccess):
            shift = node.offset
            if shift == (0, 0, 0):
                return e, shift
            neg = (-shift[0], -shift[1], -shift[2])
            return ir.shift_accesses(e, neg), shift
    return None, (0, 0, 0)


def _shifted_interval(
    itv: ir.VerticalInterval, lo: int, hi: int
) -> Optional[ir.VerticalInterval]:
    """The interval covering ``itv`` shifted by every k in [lo, hi] — where a
    k-shifted hoist must evaluate.  None when not representable as axis
    bounds (the hoist is then rejected)."""
    try:
        return ir.VerticalInterval(
            ir.AxisBound(itv.start.level, itv.start.offset + lo),
            ir.AxisBound(itv.end.level, itv.end.offset + hi),
        )
    except ValueError:
        return None


class CrossStageCSE(Pass):
    """Hoist subexpressions repeated across the stages of a PARALLEL
    multi-stage interval (modulo a uniform offset shift) into a temporary
    computed once — typical wins are the shifted neighbor-sum / coefficient
    chains of tridiagonal assembly, which otherwise recompute per stage.

    Legality:

    * Only PARALLEL multi-stages participate: sequential sweeps carry
      loop-order semantics where a k-shifted occurrence reads a *different
      iteration's* value of any field written in the sweep.
    * A repeat is only hoisted when no stage between (and including) its
      first and last occurrence writes any field the expression reads, so
      every occurrence provably sees identical operand values.
    * Occurrences whose shifts agree on k insert the defining stage right
      before the first use, inside the same interval.  Occurrences that
      differ by a *vertical* shift evaluate the expression at k-planes
      outside the source interval, so the defining stage is emitted in its
      own vertical interval spanning the union of evaluation planes — which
      is exactly the set of planes some occurrence already evaluated the
      expression at, so every operand read stays in-domain.  (Such hoists
      additionally require that *no* stage up to the last occurrence writes
      an operand, since the defining interval runs before the whole source
      interval.)  Unrepresentable unions reject the hoist.
    * Occurrences are collected from top-level assignment expressions only;
      statements nested in conditionals keep their expressions (the masked
      write machinery stays untouched).
    * The hoisted temporary's dtype is structurally inferred; expressions
      mixing concrete dtypes are skipped rather than promoted.

    The vectorized backends evaluate the hoisted statement over the union of
    its readers' extents — exactly the regions the occurrences covered.
    Eliminated-occurrence counts are reported via the pass record's
    ``detail`` (surfaced in ``exec_info["pass_report"]``).
    """

    name = "cross_stage_cse"
    min_opt_level = 3

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        field_dtype = {f.name: f.dtype for f in impl.all_fields}
        scalar_dtype = {s.name: s.dtype for s in impl.scalars}
        taken = set(field_dtype) | set(scalar_dtype)
        new_temps: List[ir.FieldDecl] = []
        eliminated = 0
        counter = 0

        def fresh_name() -> str:
            nonlocal counter
            while True:
                name = f"_cse{counter}"
                counter += 1
                if name not in taken:
                    taken.add(name)
                    return name

        multi_stages: List[ir.MultiStage] = []
        for ms in impl.multi_stages:
            if ms.order != ir.IterationOrder.PARALLEL:
                multi_stages.append(ms)
                continue
            intervals: List[ir.MultiStageInterval] = []
            for itv in ms.intervals:
                stages = list(itv.stages)
                defines: List[ir.MultiStageInterval] = []
                rejected: set = set()
                while True:
                    hoist = self._pick_hoist(stages, rejected)
                    if hoist is None:
                        break
                    key, occurrences = hoist
                    try:
                        dtype = _infer_expr_dtype(key, field_dtype, scalar_dtype)
                    except _DtypeConflict:
                        dtype = None
                    if dtype is None:
                        rejected.add(key)  # untypeable: leave it in place
                        continue
                    # Re-base the canonical so the occurrence-shift hull
                    # contains zero on every axis: the Extent model pads
                    # regions to include the origin, so any other base would
                    # over-approximate the operands' halos (and can demand
                    # halo the user never allocated).
                    base = tuple(min(s[ax] for _, s in occurrences) for ax in range(3))
                    shifts = [
                        (s[0] - base[0], s[1] - base[1], s[2] - base[2])
                        for _, s in occurrences
                    ]
                    k_shifts = sorted(s[2] for s in shifts)
                    define_itv = itv.interval
                    if k_shifts[0] != 0 or k_shifts[-1] != 0:
                        define_itv = _shifted_interval(itv.interval, k_shifts[0], k_shifts[-1])
                        if define_itv is None:
                            rejected.add(key)  # evaluation range unrepresentable
                            continue
                    temp = fresh_name()
                    first = min(idx for idx, _ in occurrences)
                    stages = self._rewrite(stages, key, temp, base)
                    define = ir.make_stage(
                        (ir.Assign(ir.FieldAccess(temp, (0, 0, 0)), ir.shift_accesses(key, base)),),
                        ir.Extent.zero(),
                    )
                    if define_itv is itv.interval:
                        stages.insert(first, define)
                    else:
                        defines.append(ir.MultiStageInterval(define_itv, (define,)))
                    new_temps.append(ir.FieldDecl(temp, dtype, ir.AXES_IJK, is_api=False))
                    field_dtype[temp] = dtype
                    eliminated += len(occurrences) - 1
                intervals.extend(defines)
                intervals.append(ir.MultiStageInterval(itv.interval, tuple(stages)))
            multi_stages.append(ir.MultiStage(ms.order, tuple(intervals)))

        ctx.set_detail({"hoisted": len(new_temps), "eliminated": eliminated})
        if not new_temps:
            return impl
        impl = dataclasses.replace(
            impl,
            multi_stages=tuple(multi_stages),
            temporaries=tuple(impl.temporaries) + tuple(new_temps),
        )
        # new defining stages need compute extents; reader extents may grow
        return analysis.recompute_implementation(impl)

    # -- helpers -----------------------------------------------------------

    @staticmethod
    def _collect(
        stages: List[ir.Stage], rejected: set
    ) -> Dict[ir.Expr, List[Tuple[int, Tuple[int, int, int]]]]:
        occ: Dict[ir.Expr, List[Tuple[int, Tuple[int, int, int]]]] = {}
        for idx, st in enumerate(stages):
            for stmt in st.stmts:
                if not isinstance(stmt, ir.Assign):
                    continue  # conditionals keep their expressions
                for node in ir.walk_exprs(stmt.value):
                    if not _cse_worthwhile(node):
                        continue
                    key, shift = _canonicalize(node)
                    if key is None or key in rejected:
                        continue
                    occ.setdefault(key, []).append((idx, shift))
        return occ

    def _pick_hoist(
        self, stages: List[ir.Stage], rejected: set
    ) -> Optional[Tuple[ir.Expr, List[Tuple[int, Tuple[int, int, int]]]]]:
        """The biggest legal repeated subexpression, or None."""
        candidates = []
        for key, occurrences in self._collect(stages, rejected).items():
            if len(occurrences) < 2:
                continue
            reads = {e.name for e in ir.walk_exprs(key) if isinstance(e, ir.FieldAccess)}
            lo = min(idx for idx, _ in occurrences)
            hi = max(idx for idx, _ in occurrences)
            if any(shift[2] != 0 for _, shift in occurrences):
                lo = 0  # defining interval runs before the whole source interval
            if any(set(stages[i].writes) & reads for i in range(lo, hi + 1)):
                continue  # an operand is rewritten between occurrences
            ops, accesses = _expr_weight(key)
            candidates.append((len(occurrences), ops + accesses, key, occurrences))
        if not candidates:
            return None
        # most occurrences first, then largest expression; repr breaks ties
        # deterministically so codegen is reproducible
        candidates.sort(key=lambda c: (-c[0], -c[1], repr(c[2])))
        _, _, key, occurrences = candidates[0]
        return key, occurrences

    def _rewrite(
        self, stages: List[ir.Stage], key: ir.Expr, temp: str, base: Tuple[int, int, int]
    ) -> List[ir.Stage]:
        def rewrite_expr(e: ir.Expr) -> ir.Expr:
            if _cse_worthwhile(e):
                canon, shift = _canonicalize(e)
                if canon == key:
                    return ir.FieldAccess(
                        temp, (shift[0] - base[0], shift[1] - base[1], shift[2] - base[2])
                    )
            if isinstance(e, ir.UnaryOp):
                return ir.UnaryOp(e.op, rewrite_expr(e.operand))
            if isinstance(e, ir.BinOp):
                return ir.BinOp(e.op, rewrite_expr(e.left), rewrite_expr(e.right))
            if isinstance(e, ir.TernaryOp):
                return ir.TernaryOp(
                    rewrite_expr(e.cond), rewrite_expr(e.true_expr), rewrite_expr(e.false_expr)
                )
            if isinstance(e, ir.NativeCall):
                return ir.NativeCall(e.func, tuple(rewrite_expr(a) for a in e.args))
            if isinstance(e, ir.Cast):
                return ir.Cast(e.dtype, rewrite_expr(e.expr))
            return e

        out: List[ir.Stage] = []
        for st in stages:
            stmts = tuple(
                ir.Assign(s.target, rewrite_expr(s.value)) if isinstance(s, ir.Assign) else s
                for s in st.stmts
            )
            out.append(ir.make_stage(stmts, st.compute_extent) if stmts != st.stmts else st)
        return out


# ---------------------------------------------------------------------------
# Pass 8: temporary demotion
# ---------------------------------------------------------------------------


class TempDemotion(Pass):
    """Demote temporaries to stage-local values: no field allocation, no
    zero-init, no functional slice updates — the vectorized backends bind the
    computed block/plane directly to a variable.

    A temporary demotes when (all conditions checked structurally):

    * every access (read or write) happens inside one multi-stage interval,
      so one bound variable covers its whole live range;
    * every read is at zero offset — the value never crosses the horizontal
      plane or the k-sweep, so no neighborhood/history is needed;
    * every touching stage has the same compute extent — the writer's block
      is shape-identical to every reader's region;
    * its first access is an unconditional top-level write (never in
      ``zero_init_temps``), so the variable is always defined before use;
    * it spans all of I, J, K (frontend default for temporaries).
    """

    name = "temp_demotion"
    min_opt_level = 2

    def apply(self, impl: ir.StencilImplementation, ctx: PassContext) -> ir.StencilImplementation:
        temps = {f.name: f for f in impl.temporaries}
        if not temps:
            return impl

        sites: Dict[str, set] = {n: set() for n in temps}
        read_offsets: Dict[str, set] = {n: set() for n in temps}
        extents: Dict[str, List[ir.Extent]] = {n: [] for n in temps}
        first_access: Dict[str, str] = {}  # name -> 'uncond_write' | 'other'

        for mi, ms in enumerate(impl.multi_stages):
            for ii, itv in enumerate(ms.intervals):
                for st in itv.stages:
                    touched: List[str] = []
                    for stmt in st.stmts:
                        for rname, off in ir.stmt_reads(stmt):
                            if rname in temps:
                                read_offsets[rname].add(off)
                                touched.append(rname)
                        uncond = {stmt.target.name} if isinstance(stmt, ir.Assign) else set()
                        for w in ir.stmt_writes(stmt):
                            if w in temps:
                                touched.append(w)
                                first_access.setdefault(
                                    w, "uncond_write" if w in uncond else "other"
                                )
                    for n in touched:
                        sites[n].add((mi, ii))
                        extents[n].append(st.compute_extent)

        zero_init = set(impl.zero_init_temps)
        demoted: List[ir.FieldDecl] = []
        for name, decl in temps.items():
            if decl.axes != ir.AXES_IJK or name in zero_init:
                continue
            if len(sites[name]) != 1:
                continue
            if any(off != (0, 0, 0) for off in read_offsets[name]):
                continue
            if first_access.get(name) != "uncond_write":
                continue
            exts = extents[name]
            if not exts or any(e != exts[0] for e in exts):
                continue
            demoted.append(decl)

        if not demoted:
            return impl
        names = {d.name for d in demoted}
        return dataclasses.replace(
            impl,
            temporaries=tuple(f for f in impl.temporaries if f.name not in names),
            local_decls=tuple(impl.local_decls) + tuple(demoted),
        )


# ---------------------------------------------------------------------------
# Pipeline assembly
# ---------------------------------------------------------------------------

PIPELINE: Tuple[Pass, ...] = (
    ConstantFolding(),
    DeadTempPruning(),
    IntervalSplitting(),
    IntervalMerging(),
    MultiStageFusion(),
    AlgebraicReassociation(),
    CrossStageCSE(),
    TempDemotion(),
)

PASS_NAMES: Tuple[str, ...] = tuple(p.name for p in PIPELINE)
# every name the disable/enable surface accepts (IR passes + the
# backend-schedule passes applied inside the code generators)
ALL_PASS_NAMES: Tuple[str, ...] = PASS_NAMES + SCHEDULE_PASS_NAMES


def build_pipeline(
    opt_level: int = DEFAULT_OPT_LEVEL,
    disable: Iterable[str] = (),
    enable: Iterable[str] = (),
) -> List[Pass]:
    disable = set(disable)
    enable = set(enable)
    unknown = (disable | enable) - set(ALL_PASS_NAMES)
    if unknown:
        raise ValueError(
            f"unknown pass name(s) {sorted(unknown)}; available: {list(ALL_PASS_NAMES)}"
        )
    selected = []
    for p in PIPELINE:
        on = opt_level >= p.min_opt_level
        if p.name in disable:
            on = False
        if p.name in enable:
            on = True
        if on:
            selected.append(p)
    return selected


def schedule_pass_enabled(
    name: str,
    opt_level: int = DEFAULT_OPT_LEVEL,
    disable: Iterable[str] = (),
    enable: Iterable[str] = (),
    min_opt_level: int = 1,
) -> bool:
    """The ``build_pipeline`` on/off rule applied to a backend-schedule pass
    (``SCHEDULE_PASS_NAMES``) — shared by the code generators so the toggle
    surface stays uniform with the IR passes."""
    assert name in SCHEDULE_PASS_NAMES, name
    on = opt_level >= min_opt_level
    if name in set(disable):
        on = False
    if name in set(enable):
        on = True
    return on


def run_pipeline(
    impl: ir.StencilImplementation,
    opt_level: int = DEFAULT_OPT_LEVEL,
    disable: Iterable[str] = (),
    enable: Iterable[str] = (),
    exact: bool = True,
) -> Tuple[ir.StencilImplementation, List[Dict[str, Any]]]:
    """Apply the configured passes; returns (optimized IR, pass report)."""
    ctx = PassContext(opt_level=int(opt_level), exact=bool(exact))
    for p in build_pipeline(ctx.opt_level, disable, enable):
        impl = p(impl, ctx)
    return impl, ctx.records


def split_backend_opts(backend_opts: Optional[Dict[str, Any]]) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Split ``backend_opts`` into (pass configuration, codegen options).

    Pass configuration keys: ``opt_level`` (int), ``disable_passes`` /
    ``enable_passes`` (iterables of pass names, including the
    backend-schedule passes of ``SCHEDULE_PASS_NAMES``), and ``exact``
    (bool; ``False`` legalizes value-changing rewrites like reassociation).
    Everything else goes to the backend's source generator (e.g. the Pallas
    ``block`` shape or the numpy ``tile``).

    ``REPRO_OPT_LEVEL`` / ``REPRO_DISABLE_PASSES`` shift the process-wide
    defaults (explicit per-stencil options still win for ``opt_level``;
    env-disabled passes are unioned in) — the CI pass matrix runs the
    differential suite through these.
    """
    opts = dict(backend_opts or {})
    env_level = os.environ.get("REPRO_OPT_LEVEL", "")
    default_level = int(env_level) if env_level else DEFAULT_OPT_LEVEL
    env_disable = {p for p in os.environ.get("REPRO_DISABLE_PASSES", "").split(",") if p}
    cfg = {
        "opt_level": int(opts.pop("opt_level", default_level)),
        "disable": tuple(sorted(set(opts.pop("disable_passes", ())) | env_disable)),
        "enable": tuple(sorted(opts.pop("enable_passes", ()))),
        "exact": bool(opts.pop("exact", True)),
    }
    return cfg, opts
