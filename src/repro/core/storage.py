"""Field storage: the paper's backend-aware NumPy-like containers.

A :class:`Storage` owns a buffer (NumPy for the ``debug``/``numpy`` backends,
a ``jax.Array`` for ``jax``/``pallas``), carries a ``default_origin`` (the
position of the compute-domain origin inside the buffer — i.e. the halo) and
implements ``__array__`` so it inter-operates copy-free with the rest of the
Python ecosystem (the paper's buffer-protocol point).

Backend-specific layout: an optional ``alignment`` pads the trailing
dimensions of the *allocation* up to the (8, 128) sublane×lane register tile
so Pallas block shapes stay hardware-aligned; the logical shape is unchanged
(on the numpy backends reads and writes go through a view into the padded
base, on the jax family XLA owns device layout and the padded shape is
metadata).

Ensemble member batching: a storage whose leading axis is ``N`` holds one
field for every ensemble member (``axes=("N", "I", "J", "K")``, origin 0
along ``N``).  Stencils never see the member axis — ``repro.ensemble``
slices per-member views for compilation and batches execution with
``jax.vmap``; alignment is computed per member so batched and unbatched
allocations share one register-tile layout.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import numpy as np

_JAX_BACKENDS = ("jax", "pallas")
_ALL_BACKENDS = ("debug", "numpy") + _JAX_BACKENDS

# TPU register tile: (sublane, lane) — trailing-two-dim padding target.
ALIGNMENT_TPU = (8, 128)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _aligned_shape(
    shape: Tuple[int, ...], alignment: Tuple[int, int], skip_leading: int = 0
) -> Tuple[int, ...]:
    """Round the trailing two dims up to the (sublane, lane) tile.

    1-D (per-member) shapes pad the single dim to the lane width; the first
    ``skip_leading`` dims (the ensemble member axis ``N``) are never padded —
    batching a field must not disturb its per-member register-tile layout.
    """
    head, body = shape[:skip_leading], shape[skip_leading:]
    if len(body) == 0:
        return shape
    if len(body) == 1:
        return head + (_round_up(body[0], alignment[1]),)
    out = list(body)
    out[-2] = _round_up(out[-2], alignment[0])
    out[-1] = _round_up(out[-1], alignment[1])
    return head + tuple(out)


class Storage:
    """A field container bound to a backend."""

    def __init__(
        self,
        data: Any,
        backend: str = "numpy",
        default_origin: Tuple[int, ...] = (0, 0, 0),
        axes: Tuple[str, ...] = ("I", "J", "K"),
        *,
        aligned_shape: Optional[Tuple[int, ...]] = None,
    ):
        if backend not in _ALL_BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {_ALL_BACKENDS}")
        self.backend = backend
        self.axes = tuple(axes)
        self.default_origin = tuple(default_origin)[: len(self.axes)]
        if backend in _JAX_BACKENDS:
            import jax.numpy as jnp

            self.data = jnp.asarray(data)
        else:
            self.data = np.asarray(data)
        # the allocation shape behind the logical view (== shape when the
        # storage was allocated without alignment padding)
        self.aligned_shape = tuple(aligned_shape) if aligned_shape is not None else tuple(self.data.shape)

    # -- NumPy-like surface ----------------------------------------------------

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def dtype(self):
        return np.dtype(str(self.data.dtype))

    @property
    def ndim(self) -> int:
        return self.data.ndim

    def __array__(self, dtype=None):
        arr = np.asarray(self.data)
        return arr.astype(dtype) if dtype is not None else arr

    def __getitem__(self, idx):
        return self.data[idx]

    def __setitem__(self, idx, value):
        if self.backend in _JAX_BACKENDS:
            self.data = self.data.at[idx].set(value)
        else:
            self.data[idx] = value

    def __repr__(self) -> str:
        return (
            f"Storage(shape={self.shape}, dtype={self.dtype}, backend={self.backend!r}, "
            f"default_origin={self.default_origin})"
        )

    # -- ensemble member axis --------------------------------------------------

    @property
    def is_member_batched(self) -> bool:
        """True when the storage carries a leading ensemble member axis ``N``."""
        return bool(self.axes) and self.axes[0] == "N"

    @property
    def members(self) -> Optional[int]:
        return int(self.shape[0]) if self.is_member_batched else None

    def member(self, m: int) -> "Storage":
        """The per-member ``(I, J, K)`` storage for member ``m`` — a copy-free
        view on the numpy backends, a device slice on the jax family."""
        if not self.is_member_batched:
            raise ValueError(f"storage with axes {self.axes} has no member axis")
        return Storage(
            self.data[m],
            backend=self.backend,
            default_origin=self.default_origin[1:],
            axes=self.axes[1:],
            aligned_shape=self.aligned_shape[1:],
        )

    def synchronize(self) -> None:
        """Block until pending device work on this storage is done."""
        if self.backend in _JAX_BACKENDS:
            self.data.block_until_ready()

    def to_numpy(self) -> np.ndarray:
        return np.asarray(self.data)


def _alloc(shape, dtype, backend, default_origin, fill, axes, alignment=None) -> Storage:
    shape = tuple(int(s) for s in shape)
    if default_origin is None:
        default_origin = (0,) * len(shape)
    if axes is None:
        axes = ("I", "J", "K")[: len(shape)] if len(shape) <= 3 else tuple(f"D{i}" for i in range(len(shape)))
    if alignment is True:
        alignment = ALIGNMENT_TPU
    skip = 1 if axes and axes[0] == "N" else 0
    padded = _aligned_shape(shape, alignment, skip) if alignment else shape
    if backend in _JAX_BACKENDS:
        import jax.numpy as jnp

        # XLA owns device layout (it tiles to (8, 128) internally), so the
        # jax-family buffer is allocated at the logical shape; ``alignment``
        # only records the padded shape the TPU backends will see.
        if fill == "ones":
            data = jnp.ones(shape, dtype=dtype)
        else:  # no uninitialized memory in JAX: 'empty' also zero-fills
            data = jnp.zeros(shape, dtype=dtype)
    else:
        if fill == "zeros" or (fill == "ones" and padded != shape):
            base = np.zeros(padded, dtype=dtype)
        else:
            base = np.empty(padded, dtype=dtype)
        # the logical array is a view into the aligned allocation: rows keep
        # lane-aligned strides, np.asarray stays copy-free
        data = base[tuple(slice(0, s) for s in shape)]
        if fill == "ones":
            data[...] = 1.0
    return Storage(data, backend=backend, default_origin=default_origin, axes=axes, aligned_shape=padded)


def zeros(shape, dtype="float64", backend="numpy", default_origin=None, axes=None, alignment=None) -> Storage:
    return _alloc(shape, dtype, backend, default_origin, "zeros", axes, alignment)


def ones(shape, dtype="float64", backend="numpy", default_origin=None, axes=None, alignment=None) -> Storage:
    return _alloc(shape, dtype, backend, default_origin, "ones", axes, alignment)


def empty(shape, dtype="float64", backend="numpy", default_origin=None, axes=None, alignment=None) -> Storage:
    return _alloc(shape, dtype, backend, default_origin, "empty", axes, alignment)


def from_array(array, backend="numpy", default_origin=None, dtype=None, axes=None) -> Storage:
    arr = np.asarray(array)
    if dtype is not None:
        arr = arr.astype(dtype)
    if default_origin is None:
        default_origin = (0,) * arr.ndim
    if axes is None:
        axes = ("I", "J", "K")[: arr.ndim] if arr.ndim <= 3 else tuple(f"D{i}" for i in range(arr.ndim))
    return Storage(arr, backend=backend, default_origin=default_origin, axes=axes)


def storage_for_domain(
    domain: Tuple[int, int, int],
    halo: Tuple[int, int, int],
    dtype="float64",
    backend="numpy",
    fill="zeros",
    axes=("I", "J", "K"),
    alignment=None,
    members: Optional[int] = None,
) -> Storage:
    """Allocate a storage sized domain+2·halo with origin at the halo.

    ``members=N`` prepends an ensemble member axis (``axes=("N", ...)``,
    origin 0 along it); trailing-dim ``alignment`` is computed per member,
    so batched and unbatched allocations share one register-tile layout.
    """
    ni, nj, nk = domain
    hi, hj, hk = halo
    full = []
    origin = []
    for ax, (n, h) in zip(("I", "J", "K"), ((ni, hi), (nj, hj), (nk, hk))):
        if ax in axes:
            full.append(n + 2 * h)
            origin.append(h)
    out_axes = tuple(a for a in ("I", "J", "K") if a in axes)
    if members is not None:
        full.insert(0, int(members))
        origin.insert(0, 0)
        out_axes = ("N",) + out_axes
    return _alloc(tuple(full), dtype, backend, tuple(origin), fill, out_axes, alignment)
