"""Field storage: the paper's backend-aware NumPy-like containers.

A :class:`Storage` owns a buffer (NumPy for the ``debug``/``numpy`` backends,
a ``jax.Array`` for ``jax``/``pallas``), carries a ``default_origin`` (the
position of the compute-domain origin inside the buffer — i.e. the halo) and
implements ``__array__`` so it inter-operates copy-free with the rest of the
Python ecosystem (the paper's buffer-protocol point).

Backend-specific layout: for the TPU backends an optional alignment pads the
trailing dimensions up to the (8, 128) sublane×lane register tile so Pallas
block shapes stay hardware-aligned; the logical shape is unchanged (reads and
writes go through a view).
"""

from __future__ import annotations

from typing import Any, Tuple

import numpy as np

_JAX_BACKENDS = ("jax", "pallas")
_ALL_BACKENDS = ("debug", "numpy") + _JAX_BACKENDS


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class Storage:
    """A field container bound to a backend."""

    def __init__(
        self,
        data: Any,
        backend: str = "numpy",
        default_origin: Tuple[int, ...] = (0, 0, 0),
        axes: Tuple[str, ...] = ("I", "J", "K"),
    ):
        if backend not in _ALL_BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected one of {_ALL_BACKENDS}")
        self.backend = backend
        self.axes = tuple(axes)
        self.default_origin = tuple(default_origin)[: len(self.axes)]
        if backend in _JAX_BACKENDS:
            import jax.numpy as jnp

            self.data = jnp.asarray(data)
        else:
            self.data = np.asarray(data)

    # -- NumPy-like surface ----------------------------------------------------

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape)

    @property
    def dtype(self):
        return np.dtype(str(self.data.dtype))

    @property
    def ndim(self) -> int:
        return self.data.ndim

    def __array__(self, dtype=None):
        arr = np.asarray(self.data)
        return arr.astype(dtype) if dtype is not None else arr

    def __getitem__(self, idx):
        return self.data[idx]

    def __setitem__(self, idx, value):
        if self.backend in _JAX_BACKENDS:
            self.data = self.data.at[idx].set(value)
        else:
            self.data[idx] = value

    def __repr__(self) -> str:
        return (
            f"Storage(shape={self.shape}, dtype={self.dtype}, backend={self.backend!r}, "
            f"default_origin={self.default_origin})"
        )

    def synchronize(self) -> None:
        """Block until pending device work on this storage is done."""
        if self.backend in _JAX_BACKENDS:
            self.data.block_until_ready()

    def to_numpy(self) -> np.ndarray:
        return np.asarray(self.data)


def _alloc(shape, dtype, backend, default_origin, fill, axes) -> Storage:
    shape = tuple(int(s) for s in shape)
    if default_origin is None:
        default_origin = (0,) * len(shape)
    if backend in _JAX_BACKENDS:
        import jax.numpy as jnp

        if fill == "zeros":
            data = jnp.zeros(shape, dtype=dtype)
        elif fill == "ones":
            data = jnp.ones(shape, dtype=dtype)
        else:
            data = jnp.zeros(shape, dtype=dtype)  # no uninitialized memory in JAX
    else:
        if fill == "zeros":
            data = np.zeros(shape, dtype=dtype)
        elif fill == "ones":
            data = np.ones(shape, dtype=dtype)
        else:
            data = np.empty(shape, dtype=dtype)
    if axes is None:
        axes = ("I", "J", "K")[: len(shape)] if len(shape) <= 3 else tuple(f"D{i}" for i in range(len(shape)))
    return Storage(data, backend=backend, default_origin=default_origin, axes=axes)


def zeros(shape, dtype="float64", backend="numpy", default_origin=None, axes=None) -> Storage:
    return _alloc(shape, dtype, backend, default_origin, "zeros", axes)


def ones(shape, dtype="float64", backend="numpy", default_origin=None, axes=None) -> Storage:
    return _alloc(shape, dtype, backend, default_origin, "ones", axes)


def empty(shape, dtype="float64", backend="numpy", default_origin=None, axes=None) -> Storage:
    return _alloc(shape, dtype, backend, default_origin, "empty", axes)


def from_array(array, backend="numpy", default_origin=None, dtype=None, axes=None) -> Storage:
    arr = np.asarray(array)
    if dtype is not None:
        arr = arr.astype(dtype)
    if default_origin is None:
        default_origin = (0,) * arr.ndim
    if axes is None:
        axes = ("I", "J", "K")[: arr.ndim] if arr.ndim <= 3 else tuple(f"D{i}" for i in range(arr.ndim))
    return Storage(arr, backend=backend, default_origin=default_origin, axes=axes)


def storage_for_domain(
    domain: Tuple[int, int, int],
    halo: Tuple[int, int, int],
    dtype="float64",
    backend="numpy",
    fill="zeros",
    axes=("I", "J", "K"),
) -> Storage:
    """Allocate a storage sized domain+2·halo with origin at the halo."""
    ni, nj, nk = domain
    hi, hj, hk = halo
    full = []
    origin = []
    for ax, (n, h) in zip(("I", "J", "K"), ((ni, hi), (nj, hj), (nk, hk))):
        if ax in axes:
            full.append(n + 2 * h)
            origin.append(h)
    return _alloc(tuple(full), dtype, backend, tuple(origin), fill, tuple(a for a in ("I", "J", "K") if a in axes))
