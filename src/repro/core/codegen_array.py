"""Source generators for the ``numpy`` and ``jax`` backends.

``numpy`` mirrors the paper's NumPy backend (vectorized slices, in-place
writes).  ``jax`` is the XLA-compiled analogue of the paper's gtx86/gtmc
backends: pure-functional, `.at[].set()` writes, `lax.fori_loop` for
FORWARD/BACKWARD sweeps; the resulting ``run`` is jit-compiled by
``stencil.py`` and composes into larger jit programs (models, shard_map).

Horizontal stage tiling (``numpy_stage_tiling``, the numpy analogue of the
Pallas ``(BI, BJ)`` block schedule): PARALLEL multi-stages are emitted as
loops over ``(TI, TJ)`` tiles of the compute domain, with every stage's
vectorized slice clamped to the current tile (extended by the stage's
compute extent, like the Pallas halo'd tile DMA).  One tile's whole stage
chain runs before the next tile starts, so intermediate temporaries stay
cache-resident instead of streaming the full domain per statement — the
cache-blocking transform of the paper's CPU backends.  Legality is the
recompute-in-overlap argument: boundary tiles recompute extended regions,
which is value-preserving only when no stage writes a field that an
earlier-or-same stage reads (no anti-dependency), checked structurally per
multi-stage; failing multi-stages fall back to untiled emission.  The tile
is a runtime knob (``run(..., block=)``) defaulting to the baked
``_BLOCK_DEFAULT``, so the autotuner (``core/autotune.py``) can time
candidate tiles exactly the way it does for Pallas.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from . import analysis, ir
from .codegen_common import (
    ArrayExprPrinter,
    ArrayStmtEmitter,
    Emitter,
    bound_expr,
    emit_helpers,
    emit_parallel_block,
    emit_sweep,
    multistage_plan,
    temp_alloc_shape,
)

# default (TI, TJ) tile for the tiled numpy backend — row-major arrays want
# long contiguous j-runs; 64×128 float64 ≈ 64 KB per field slab (L2-sized
# once a few stages are live)
DEFAULT_NUMPY_TILE: Tuple[int, int] = (64, 128)


def _written_api_fields(impl: ir.StencilImplementation) -> List[str]:
    return list(impl.written_api_fields())


def _ms_tileable(ms: ir.MultiStage) -> bool:
    """A PARALLEL multi-stage tiles when every per-tile read provably sees
    per-tile-written (or never-written) data.  Two structural conditions,
    checked per interval (an interval's tiles all complete before the next
    interval starts, so cross-interval flow is always safe):

    * **no anti-dependency** — no stage writes a field that an
      earlier-or-same stage reads.  Boundary tiles recompute their
      extent-extended overlap regions; an anti-dependency would make the
      recomputation see modified inputs (``o = o + t`` double-applies).
    * **writer coverage** — for every read of a field some stage in the
      interval writes, every writer's compute extent must cover the
      reader's region shifted by the read offset.  The extent fixpoint
      guarantees this for temporaries (they are computed on their full
      required extent), but API fields are only ever written on the bare
      compute domain: a later stage reading one at a horizontal offset (or
      over an extended region) would reach into a neighboring tile whose
      write has not run yet — a miscompile the backend-differential fuzzer
      corpus pins (``_t_api_feedback``)."""
    if ms.order != ir.IterationOrder.PARALLEL:
        return False
    for itv in ms.intervals:
        writer_exts: Dict[str, List[ir.Extent]] = {}
        for st in itv.stages:
            for w in st.writes:
                writer_exts.setdefault(w, []).append(st.compute_extent)
        seen_reads: set = set()
        for st in itv.stages:
            for stmt in st.stmts:
                for rname, off in ir.stmt_reads(stmt):
                    seen_reads.add(rname)
                    for wext in writer_exts.get(rname, ()):
                        need = st.compute_extent.add_offset((off[0], off[1], 0))
                        if (
                            wext.i[0] > need.i[0]
                            or wext.i[1] < need.i[1]
                            or wext.j[0] > need.j[0]
                            or wext.j[1] < need.j[1]
                        ):
                            return False
            if seen_reads & set(st.writes):
                return False
    return True


def tiling_plan(impl: ir.StencilImplementation) -> Dict[str, int]:
    """Per-stencil tiling summary (how many multi-stages the legality check
    admits) — shared by the code generator and the build-time pass report."""
    tiled = untileable = sequential = 0
    for ms in impl.multi_stages:
        if ms.order != ir.IterationOrder.PARALLEL:
            sequential += 1
        elif _ms_tileable(ms):
            tiled += 1
        else:
            untileable += 1
    return {
        "tiled_multistages": tiled,
        "untileable_multistages": untileable,
        "sequential_multistages": sequential,
    }


def generate_array_source(
    impl: ir.StencilImplementation,
    lib: str,
    tile: Optional[Tuple[int, int]] = None,
) -> str:
    """Generate module source for lib in {'np', 'jnp'}.

    ``tile`` (numpy only) emits tile-blocked PARALLEL multi-stages with the
    given default ``(TI, TJ)`` and a ``block=`` override on ``run``."""
    assert lib in ("np", "jnp")
    functional = lib == "jnp"
    assert tile is None or not functional, "stage tiling is numpy-only (XLA tiles itself)"

    axes_of = {f.name: f.axes for f in impl.all_fields}
    dtype_of = {f.name: f.dtype for f in impl.all_fields}

    printer = ArrayExprPrinter(impl, lib, axes_of, dtype_of)
    # k-blocked sweeps (jax only): sweep-local temporaries carry rolling
    # plane windows instead of full 3-D arrays (analysis.sequential_carry_plan)
    carry_plans = analysis.sequential_carry_plan(impl) if functional else {}
    windowed = {name for plan in carry_plans.values() for name, _ in plan.window}

    body = Emitter()
    body.push()  # inside def run

    body.line("ni, nj, nk = domain")
    if tile is not None:
        body.line("_TI, _TJ = block or _BLOCK_DEFAULT")
    for f in impl.api_fields:
        body.line(f"{f.name} = fields['{f.name}']")
        body.line(f"_oi_{f.name}, _oj_{f.name}, _ok_{f.name} = origins['{f.name}']")
    for s in impl.scalars:
        body.line(f"{s.name} = scalars['{s.name}']")
    if impl.temporaries:
        body.line("# --- temporaries (never observable outside the stencil, paper §2.2)")
    for t in impl.temporaries:
        if t.name in windowed:
            continue  # materialized as rolling planes inside their sweep
        shape, origin = temp_alloc_shape(impl, t.name)
        body.line(f"{t.name} = {lib}.zeros({shape}, dtype='{t.dtype}')")
        body.line(f"_oi_{t.name}, _oj_{t.name}, _ok_{t.name} = {origin}")

    for mi, ms in enumerate(impl.multi_stages):
        body.line(f"# === multi-stage {mi}: {multistage_plan(ms)}")
        if ms.order == ir.IterationOrder.PARALLEL:
            if tile is not None and _ms_tileable(ms):
                _emit_tiled_parallel_ms(impl, printer, body, ms, mi)
            else:
                _emit_parallel_ms(impl, printer, body, ms, mi, functional)
        elif functional:
            emit_sweep(impl, printer, body, ms, mi, carry_plans[mi], lib)
        else:
            _emit_sequential_ms(impl, printer, body, ms, mi, functional, lib)

    if functional:
        written = _written_api_fields(impl)
        items = ", ".join(f"'{w}': {w}" for w in written)
        body.line(f"return {{{items}}}")
    else:
        body.line("return None")

    # ---- assemble module
    out = Emitter()
    out.line(f'"""Auto-generated by repro.core — stencil {impl.name!r}, backend '
             f'{"jax" if functional else "numpy"}."""')
    if functional:
        out.line("import jax")
        out.line("import jax.numpy as jnp")
        out.line("from jax import lax")
    else:
        out.line("import numpy as np")
    emit_helpers(out, printer.used_helpers, lib)
    if not functional:
        # metadata mirroring the pallas module exports, so the autotuner can
        # build synthetic arguments and time candidate tiles uniformly
        h = impl.max_halo
        api = {f.name for f in impl.api_fields}
        out.line("_BACKEND = 'numpy'")
        out.line(f"_H = {max(h[0], h[1])}")
        out.line(f"_SCALARS = {[s.name for s in impl.scalars]!r}")
        out.line(f"_AXES = {dict(sorted((n, axes_of[n]) for n in api))!r}")
        out.line(f"_DTYPES = {dict(sorted((n, dtype_of[n]) for n in api))!r}")
        out.line(f"_TILING = {tiling_plan(impl)!r}")
        if tile is not None:
            out.line(f"_BLOCK_DEFAULT = {tuple(tile)!r}")
    out.line()
    if tile is not None:
        out.line("def run(fields, scalars, domain, origins, block=None):")
    else:
        out.line("def run(fields, scalars, domain, origins):")
    return out.source() + body.source()


_emit_parallel_ms = emit_parallel_block


def _emit_tiled_parallel_ms(
    impl: ir.StencilImplementation,
    printer: ArrayExprPrinter,
    body: Emitter,
    ms: ir.MultiStage,
    mi: int,
) -> None:
    """A PARALLEL multi-stage as (TI, TJ) tile loops: each tile runs the
    whole stage chain (over the tile extended by each stage's compute
    extent) before the next tile starts — temporaries stay cache-hot."""
    for ii, itv in enumerate(ms.intervals):
        k0, k1 = f"_k0_{mi}_{ii}", f"_k1_{mi}_{ii}"
        body.line(f"{k0} = {bound_expr(itv.interval.start)}")
        body.line(f"{k1} = {bound_expr(itv.interval.end)}")
        printer.mode = "block"
        printer.k0, printer.k1 = k0, k1
        body.line("for _t0 in range(0, ni, _TI):")
        body.push()
        body.line("_t1 = min(_t0 + _TI, ni)")
        body.line("for _u0 in range(0, nj, _TJ):")
        body.push()
        body.line("_u1 = min(_u0 + _TJ, nj)")
        printer.irange = ("_t0", "_t1")
        printer.jrange = ("_u0", "_u1")
        emitter = ArrayStmtEmitter(printer, body, functional=False)
        for st in itv.stages:
            printer.extent = st.compute_extent
            for stmt in st.stmts:
                emitter.stmt(stmt)
        printer.irange = ("0", "ni")
        printer.jrange = ("0", "nj")
        body.pop()
        body.pop()


def _emit_sequential_ms(
    impl: ir.StencilImplementation,
    printer: ArrayExprPrinter,
    body: Emitter,
    ms: ir.MultiStage,
    mi: int,
    functional: bool,
    lib: str,
) -> None:
    """Plain python k-loops for the numpy backend (in-place plane writes);
    the functional backends go through codegen_common.emit_sweep instead."""
    backward = ms.order == ir.IterationOrder.BACKWARD
    for ii, itv in enumerate(ms.intervals):
        k0, k1 = f"_k0_{mi}_{ii}", f"_k1_{mi}_{ii}"
        body.line(f"{k0} = {bound_expr(itv.interval.start)}")
        body.line(f"{k1} = {bound_expr(itv.interval.end)}")
        printer.mode = "plane"
        if backward:
            body.line(f"for k in range({k1} - 1, {k0} - 1, -1):")
        else:
            body.line(f"for k in range({k0}, {k1}):")
        body.push()
        body.line("pass")
        emitter = ArrayStmtEmitter(printer, body, functional)
        for st in itv.stages:
            printer.extent = st.compute_extent
            for stmt in st.stmts:
                emitter.stmt(stmt)
        body.pop()


def generate_numpy_source(
    impl: ir.StencilImplementation, tile: Optional[Tuple[int, int]] = None
) -> str:
    return generate_array_source(impl, "np", tile=tile)


def generate_jax_source(impl: ir.StencilImplementation) -> str:
    return generate_array_source(impl, "jnp")
