"""Structural fingerprint cache for generated stencil modules.

Per the paper (§2.3): stencils are hashed so that *reformatting* the Python
source does not trigger re-codegen — the fingerprint is computed from the
(normalized) Definition IR, not from source text.  Generated modules are
written to a cache directory as real ``.py`` files (inspectable, steppable)
and re-imported on subsequent runs if the fingerprint matches.
"""

from __future__ import annotations

import hashlib
import importlib.util
import os
import sys
import threading
from pathlib import Path
from types import ModuleType
from typing import Any, Dict, Optional

from . import ir

_CACHE_VERSION = "repro-gt-2"
_lock = threading.Lock()
_memory_cache: Dict[str, ModuleType] = {}


def cache_dir() -> Path:
    root = os.environ.get("REPRO_GT_CACHE")
    if root:
        p = Path(root)
    else:
        p = Path.home() / ".cache" / "repro_gt"
    p.mkdir(parents=True, exist_ok=True)
    return p


def fingerprint(
    definition: ir.StencilDefinition,
    backend: str,
    options: Optional[Dict[str, Any]] = None,
    pass_config: Optional[Dict[str, Any]] = None,
) -> str:
    """Cache key for a generated module.

    Keyed on the normalized Definition IR (so reformatting the python source
    does not re-codegen), the backend, its codegen options, AND the
    optimization-pass configuration: the same definition at a different
    ``opt_level`` / pass set is a different generated module.  The names of
    the registered passes participate too, so adding a pass to the pipeline
    invalidates stale artifacts.
    """
    from . import passes  # local import: passes depends on analysis/ir only

    payload = "|".join(
        [
            _CACHE_VERSION,
            backend,
            repr(definition),
            repr(sorted((options or {}).items())),
            repr(sorted((pass_config or {}).items())),
            repr(passes.PASS_NAMES),
        ]
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def program_fingerprint(
    name: str,
    graph_repr: str,
    part_fingerprints,
    backend: str,
    options: Optional[Dict[str, Any]] = None,
) -> str:
    """Cache key for a compiled *program* (``repro.program``).

    Keyed on the structural dataflow-graph hash plus the fingerprints of the
    constituent (merged) stencils — so a program re-generates exactly when
    one of its stencils, the graph wiring, or the orchestration options
    change, and never when the step function is merely reformatted (the
    graph repr is built from IR-level facts, not source text)."""
    payload = "|".join(
        [
            _CACHE_VERSION,
            "program",
            name,
            backend,
            hashlib.sha256(graph_repr.encode()).hexdigest(),
            repr(tuple(part_fingerprints)),
            repr(sorted((options or {}).items())),
        ]
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def tuning_path(name: str, fp: str) -> Path:
    """Where the Pallas tile autotuner persists its result for a module.

    Lives alongside the generated ``<name>_<fp>.py`` and shares its
    fingerprint, so a tuning record can never outlive the exact IR + options
    it was measured for (``core/autotune.py``)."""
    return cache_dir() / f"{name}_{fp}.tune.json"


def load_generated_module(name: str, fp: str, source: str, rebuild: bool = False) -> ModuleType:
    """Write ``source`` to the cache (if needed) and import it as a module."""
    key = f"{name}_{fp}"
    with _lock:
        if not rebuild and key in _memory_cache:
            return _memory_cache[key]
        module_name = f"_repro_gt_{key}"
        try:
            path = cache_dir() / f"{key}.py"
            if rebuild or not path.exists() or path.read_text() != source:
                path.write_text(source)
            spec = importlib.util.spec_from_file_location(module_name, path)
            assert spec and spec.loader
            module = importlib.util.module_from_spec(spec)
            sys.modules[module_name] = module
            spec.loader.exec_module(module)
        except OSError:
            # read-only filesystem: exec in-memory
            module = ModuleType(module_name)
            module.__dict__["__file__"] = f"<generated {key}>"
            exec(compile(source, f"<generated {key}>", "exec"), module.__dict__)
        _memory_cache[key] = module
        return module
