"""Intermediate representations for the GTScript-style stencil DSL.

Two levels, mirroring the paper (GT4Py, §2.3):

* **Definition IR** — what the user wrote: computations / intervals /
  statements with relative field offsets.  Produced by ``frontend.py``.
* **Implementation IR** — what the backends consume: multi-stages with
  scheduled stages, per-stage *compute extents*, classified symbols
  (API fields vs. temporaries vs. scalars) and per-field halo (access)
  extents.  Produced by ``analysis.py``.

All nodes are frozen dataclasses so the whole tree is hashable and a
structural fingerprint (``caching.py``) can be derived from ``repr``.
"""

from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass
from typing import Optional, Tuple, Union


# ---------------------------------------------------------------------------
# Iteration / axis machinery
# ---------------------------------------------------------------------------


class IterationOrder(enum.Enum):
    PARALLEL = "parallel"
    FORWARD = "forward"
    BACKWARD = "backward"

    def __repr__(self) -> str:  # stable across python versions, for hashing
        return f"IterationOrder.{self.name}"


class LevelMarker(enum.Enum):
    START = "start"
    END = "end"

    def __repr__(self) -> str:
        return f"LevelMarker.{self.name}"


@dataclass(frozen=True)
class AxisBound:
    """A bound on the vertical axis: ``level + offset``.

    ``AxisBound(START, 0)`` is the first level of the compute domain,
    ``AxisBound(END, 0)`` is one-past the last level (python convention).
    """

    level: LevelMarker
    offset: int = 0

    def __post_init__(self) -> None:
        if self.level == LevelMarker.START and self.offset < 0:
            raise ValueError("start-relative bound cannot have negative offset")
        if self.level == LevelMarker.END and self.offset > 0:
            raise ValueError("end-relative bound cannot have positive offset")

    def resolve(self, nk: int) -> int:
        base = 0 if self.level == LevelMarker.START else nk
        return base + self.offset

    def key(self) -> Tuple[int, int]:
        """Sortable key assuming a 'large enough' domain."""
        return (0, self.offset) if self.level == LevelMarker.START else (1, self.offset)


@dataclass(frozen=True)
class VerticalInterval:
    start: AxisBound
    end: AxisBound

    def resolve(self, nk: int) -> Tuple[int, int]:
        return self.start.resolve(nk), self.end.resolve(nk)

    @staticmethod
    def full() -> "VerticalInterval":
        return VerticalInterval(AxisBound(LevelMarker.START, 0), AxisBound(LevelMarker.END, 0))

    def min_levels(self) -> int:
        """Minimum nk for which this interval is non-empty."""
        s, e = self.start, self.end
        if s.level == e.level:
            return 1 if (e.offset - s.offset) > 0 or s.level == LevelMarker.END else s.offset + 1
        # start-relative .. end-relative: need nk + e.offset > s.offset
        return s.offset - e.offset + 1


# ---------------------------------------------------------------------------
# Extents (halo regions)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Extent:
    """Per-axis (lo, hi) growth of a region; lo <= 0 <= hi."""

    i: Tuple[int, int] = (0, 0)
    j: Tuple[int, int] = (0, 0)
    k: Tuple[int, int] = (0, 0)

    @staticmethod
    def zero() -> "Extent":
        return Extent()

    def union(self, other: "Extent") -> "Extent":
        return Extent(
            (min(self.i[0], other.i[0]), max(self.i[1], other.i[1])),
            (min(self.j[0], other.j[0]), max(self.j[1], other.j[1])),
            (min(self.k[0], other.k[0]), max(self.k[1], other.k[1])),
        )

    def add_offset(self, off: Tuple[int, int, int]) -> "Extent":
        """Extent of a read at ``off`` performed from everywhere in ``self``."""

        def _axis(lohi: Tuple[int, int], o: int) -> Tuple[int, int]:
            return (lohi[0] + min(o, 0), lohi[1] + max(o, 0))

        return Extent(_axis(self.i, off[0]), _axis(self.j, off[1]), _axis(self.k, off[2]))

    def shift(self, off: Tuple[int, int, int]) -> "Extent":
        return Extent(
            (self.i[0] + off[0], self.i[1] + off[0]),
            (self.j[0] + off[1], self.j[1] + off[1]),
            (self.k[0] + off[2], self.k[1] + off[2]),
        )

    @property
    def halo(self) -> Tuple[int, int, int]:
        return (
            max(-self.i[0], self.i[1]),
            max(-self.j[0], self.j[1]),
            max(-self.k[0], self.k[1]),
        )

    def as_tuple(self) -> Tuple[Tuple[int, int], ...]:
        return (self.i, self.j, self.k)


# ---------------------------------------------------------------------------
# Expressions (Definition IR)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Expr:
    pass


@dataclass(frozen=True)
class Literal(Expr):
    value: Union[int, float, bool]
    dtype: str = "float"  # 'float' | 'int' | 'bool'


@dataclass(frozen=True)
class ScalarRef(Expr):
    """Run-time scalar parameter (keyword-only stencil argument)."""

    name: str


@dataclass(frozen=True)
class FieldAccess(Expr):
    name: str
    offset: Tuple[int, int, int] = (0, 0, 0)


@dataclass(frozen=True)
class UnaryOp(Expr):
    op: str  # '-', '+', 'not'
    operand: Expr


@dataclass(frozen=True)
class BinOp(Expr):
    op: str  # '+', '-', '*', '/', '//', '%', '**', 'and', 'or',
    # '<', '>', '<=', '>=', '==', '!='
    left: Expr
    right: Expr


@dataclass(frozen=True)
class TernaryOp(Expr):
    cond: Expr
    true_expr: Expr
    false_expr: Expr


@dataclass(frozen=True)
class NativeCall(Expr):
    """Call to a whitelisted math builtin (min, max, sqrt, exp, ...)."""

    func: str
    args: Tuple[Expr, ...]


@dataclass(frozen=True)
class Cast(Expr):
    dtype: str
    expr: Expr


NATIVE_FUNCTIONS = {
    "abs": 1,
    "min": 2,
    "max": 2,
    "mod": 2,
    "sqrt": 1,
    "exp": 1,
    "log": 1,
    "log2": 1,
    "pow": 2,
    "sin": 1,
    "cos": 1,
    "tan": 1,
    "arcsin": 1,
    "arccos": 1,
    "arctan": 1,
    "sinh": 1,
    "cosh": 1,
    "tanh": 1,
    "erf": 1,
    "erfc": 1,
    "floor": 1,
    "ceil": 1,
    "trunc": 1,
    "isfinite": 1,
    "isnan": 1,
    "sigmoid": 1,
}


# ---------------------------------------------------------------------------
# Statements (Definition IR)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Stmt:
    pass


@dataclass(frozen=True)
class Assign(Stmt):
    target: FieldAccess  # write offset must be (0, 0, 0)
    value: Expr


@dataclass(frozen=True)
class If(Stmt):
    cond: Expr
    body: Tuple[Stmt, ...]
    orelse: Tuple[Stmt, ...] = ()


@dataclass(frozen=True)
class While(Stmt):
    """Scalar-condition loop; only valid with compile-time-bounded trip
    counts in generated code (used rarely; supported for completeness)."""

    cond: Expr
    body: Tuple[Stmt, ...]


# ---------------------------------------------------------------------------
# Declarations & stencil definition (Definition IR root)
# ---------------------------------------------------------------------------


AXES_IJK = ("I", "J", "K")


@dataclass(frozen=True)
class FieldDecl:
    name: str
    dtype: str = "float64"
    axes: Tuple[str, ...] = AXES_IJK
    is_api: bool = True  # False => temporary


@dataclass(frozen=True)
class ScalarDecl:
    name: str
    dtype: str = "float64"


@dataclass(frozen=True)
class IntervalBlock:
    interval: VerticalInterval
    body: Tuple[Stmt, ...]


@dataclass(frozen=True)
class ComputationBlock:
    order: IterationOrder
    intervals: Tuple[IntervalBlock, ...]


@dataclass(frozen=True)
class StencilDefinition:
    name: str
    api_fields: Tuple[FieldDecl, ...]
    scalars: Tuple[ScalarDecl, ...]
    computations: Tuple[ComputationBlock, ...]
    externals: Tuple[Tuple[str, Union[int, float, bool]], ...] = ()
    docstring: str = ""

    def field_decl(self, name: str) -> Optional[FieldDecl]:
        for f in self.api_fields:
            if f.name == name:
                return f
        return None


# ---------------------------------------------------------------------------
# Implementation IR
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Stage:
    """A group of statements executed together over ``compute_extent``."""

    stmts: Tuple[Stmt, ...]
    compute_extent: Extent
    writes: Tuple[str, ...]
    reads: Tuple[str, ...]


@dataclass(frozen=True)
class MultiStageInterval:
    interval: VerticalInterval
    stages: Tuple[Stage, ...]


@dataclass(frozen=True)
class MultiStage:
    order: IterationOrder
    intervals: Tuple[MultiStageInterval, ...]


@dataclass(frozen=True)
class StencilImplementation:
    name: str
    api_fields: Tuple[FieldDecl, ...]
    temporaries: Tuple[FieldDecl, ...]
    scalars: Tuple[ScalarDecl, ...]
    multi_stages: Tuple[MultiStage, ...]
    # Access extents: for API fields this is the read halo needed around the
    # compute domain; for temporaries it's the region they must be computed on.
    field_extents: Tuple[Tuple[str, Extent], ...]
    k_extents: Tuple[Tuple[str, Tuple[int, int]], ...]  # vertical read offsets
    externals: Tuple[Tuple[str, Union[int, float, bool]], ...] = ()
    min_k_levels: int = 1
    # temporaries whose first write is conditional → zero-initialized
    zero_init_temps: Tuple[str, ...] = ()
    # temporaries demoted by the pass pipeline to stage-local values: every
    # access is zero-offset inside one multi-stage interval, so the vectorized
    # backends bind them as plain block/plane variables (no field allocation).
    # The debug backend may still allocate them as arrays (it is the oracle,
    # not an optimization target) — their extents stay in ``field_extents``.
    local_decls: Tuple[FieldDecl, ...] = ()

    def extent_of(self, name: str) -> Extent:
        for n, e in self.field_extents:
            if n == name:
                return e
        return Extent.zero()

    @property
    def all_fields(self) -> Tuple[FieldDecl, ...]:
        return tuple(self.api_fields) + tuple(self.temporaries) + tuple(self.local_decls)

    def field(self, name: str) -> FieldDecl:
        for f in self.all_fields:
            if f.name == name:
                return f
        raise KeyError(name)

    def written_api_fields(self) -> Tuple[str, ...]:
        """API fields written by any stage, in first-write order — the one
        definition of "what does this stencil produce" shared by the array
        codegen, ``StencilObject.apply``, and the program tracer/graph."""
        api = {f.name for f in self.api_fields}
        written: list = []
        for ms in self.multi_stages:
            for itv in ms.intervals:
                for st in itv.stages:
                    for w in st.writes:
                        if w in api and w not in written:
                            written.append(w)
        return tuple(written)

    @property
    def max_halo(self) -> Tuple[int, int, int]:
        h = (0, 0, 0)
        for name, e in self.field_extents:
            decl = self.field(name)
            if not decl.is_api:
                continue
            eh = e.halo
            h = (max(h[0], eh[0]), max(h[1], eh[1]), max(h[2], eh[2]))
        return h


# ---------------------------------------------------------------------------
# IR traversal helpers
# ---------------------------------------------------------------------------


def walk_exprs(node: Union[Expr, Stmt]):
    """Yield every Expr reachable from ``node`` (pre-order)."""
    if isinstance(node, Expr):
        yield node
        if isinstance(node, UnaryOp):
            yield from walk_exprs(node.operand)
        elif isinstance(node, BinOp):
            yield from walk_exprs(node.left)
            yield from walk_exprs(node.right)
        elif isinstance(node, TernaryOp):
            yield from walk_exprs(node.cond)
            yield from walk_exprs(node.true_expr)
            yield from walk_exprs(node.false_expr)
        elif isinstance(node, NativeCall):
            for a in node.args:
                yield from walk_exprs(a)
        elif isinstance(node, Cast):
            yield from walk_exprs(node.expr)
    elif isinstance(node, Assign):
        yield from walk_exprs(node.target)
        yield from walk_exprs(node.value)
    elif isinstance(node, If):
        yield from walk_exprs(node.cond)
        for s in node.body:
            yield from walk_exprs(s)
        for s in node.orelse:
            yield from walk_exprs(s)
    elif isinstance(node, While):
        yield from walk_exprs(node.cond)
        for s in node.body:
            yield from walk_exprs(s)


def stmt_reads(stmt: Stmt):
    """Yield (name, offset) for every field read in ``stmt``."""
    if isinstance(stmt, Assign):
        for e in walk_exprs(stmt.value):
            if isinstance(e, FieldAccess):
                yield e.name, e.offset
    elif isinstance(stmt, If):
        for e in walk_exprs(stmt.cond):
            if isinstance(e, FieldAccess):
                yield e.name, e.offset
        for s in tuple(stmt.body) + tuple(stmt.orelse):
            yield from stmt_reads(s)
    elif isinstance(stmt, While):
        for e in walk_exprs(stmt.cond):
            if isinstance(e, FieldAccess):
                yield e.name, e.offset
        for s in stmt.body:
            yield from stmt_reads(s)


def stmt_writes(stmt: Stmt):
    """Yield field names written by ``stmt``."""
    if isinstance(stmt, Assign):
        yield stmt.target.name
    elif isinstance(stmt, If):
        for s in tuple(stmt.body) + tuple(stmt.orelse):
            yield from stmt_writes(s)
    elif isinstance(stmt, While):
        for s in stmt.body:
            yield from stmt_writes(s)


def map_field_accesses(node, fn):
    """Rebuild ``node`` applying ``fn(FieldAccess) -> Expr`` to every access."""
    if isinstance(node, FieldAccess):
        return fn(node)
    if isinstance(node, (Literal, ScalarRef)):
        return node
    if isinstance(node, UnaryOp):
        return UnaryOp(node.op, map_field_accesses(node.operand, fn))
    if isinstance(node, BinOp):
        return BinOp(node.op, map_field_accesses(node.left, fn), map_field_accesses(node.right, fn))
    if isinstance(node, TernaryOp):
        return TernaryOp(
            map_field_accesses(node.cond, fn),
            map_field_accesses(node.true_expr, fn),
            map_field_accesses(node.false_expr, fn),
        )
    if isinstance(node, NativeCall):
        return NativeCall(node.func, tuple(map_field_accesses(a, fn) for a in node.args))
    if isinstance(node, Cast):
        return Cast(node.dtype, map_field_accesses(node.expr, fn))
    if isinstance(node, Assign):
        tgt = fn(node.target)
        if not isinstance(tgt, FieldAccess):
            raise TypeError("assignment target must remain a FieldAccess")
        return Assign(tgt, map_field_accesses(node.value, fn))
    if isinstance(node, If):
        return If(
            map_field_accesses(node.cond, fn),
            tuple(map_field_accesses(s, fn) for s in node.body),
            tuple(map_field_accesses(s, fn) for s in node.orelse),
        )
    if isinstance(node, While):
        return While(map_field_accesses(node.cond, fn), tuple(map_field_accesses(s, fn) for s in node.body))
    raise TypeError(f"unhandled IR node {type(node)}")


def rename_fields(node, mapping):
    """Rename field accesses according to ``mapping`` (missing names kept)."""

    def _fn(fa: FieldAccess) -> FieldAccess:
        return FieldAccess(mapping.get(fa.name, fa.name), fa.offset)

    return map_field_accesses(node, _fn)


def shift_accesses(node, offset: Tuple[int, int, int], only: Optional[set] = None):
    """Shift every field access (optionally restricted to ``only``) by offset."""

    def _fn(fa: FieldAccess) -> FieldAccess:
        if only is not None and fa.name not in only:
            return fa
        off = (fa.offset[0] + offset[0], fa.offset[1] + offset[1], fa.offset[2] + offset[2])
        return FieldAccess(fa.name, off)

    return map_field_accesses(node, _fn)


# ---------------------------------------------------------------------------
# IR rewrite helpers (used by the optimization pass pipeline, passes.py)
# ---------------------------------------------------------------------------


def map_exprs_bottom_up(expr: Expr, fn) -> Expr:
    """Rebuild ``expr`` applying ``fn(Expr) -> Expr`` to every node, children
    first — the workhorse of expression-level rewrites (constant folding)."""
    if isinstance(expr, UnaryOp):
        expr = UnaryOp(expr.op, map_exprs_bottom_up(expr.operand, fn))
    elif isinstance(expr, BinOp):
        expr = BinOp(expr.op, map_exprs_bottom_up(expr.left, fn), map_exprs_bottom_up(expr.right, fn))
    elif isinstance(expr, TernaryOp):
        expr = TernaryOp(
            map_exprs_bottom_up(expr.cond, fn),
            map_exprs_bottom_up(expr.true_expr, fn),
            map_exprs_bottom_up(expr.false_expr, fn),
        )
    elif isinstance(expr, NativeCall):
        expr = NativeCall(expr.func, tuple(map_exprs_bottom_up(a, fn) for a in expr.args))
    elif isinstance(expr, Cast):
        expr = Cast(expr.dtype, map_exprs_bottom_up(expr.expr, fn))
    return fn(expr)


def map_stmt_exprs(stmt: Stmt, fn) -> Stmt:
    """Rebuild ``stmt`` applying ``fn`` bottom-up to every contained
    expression tree (assignment values, conditions) — assignment *targets*
    are left alone (they must stay zero-offset FieldAccess nodes)."""
    if isinstance(stmt, Assign):
        return Assign(stmt.target, map_exprs_bottom_up(stmt.value, fn))
    if isinstance(stmt, If):
        return If(
            map_exprs_bottom_up(stmt.cond, fn),
            tuple(map_stmt_exprs(s, fn) for s in stmt.body),
            tuple(map_stmt_exprs(s, fn) for s in stmt.orelse),
        )
    if isinstance(stmt, While):
        return While(
            map_exprs_bottom_up(stmt.cond, fn),
            tuple(map_stmt_exprs(s, fn) for s in stmt.body),
        )
    return stmt


def retype_definition(defn: StencilDefinition, dtype_map) -> StencilDefinition:
    """A copy of ``defn`` with field/scalar (and explicit ``Cast``) dtypes
    rewritten through ``dtype_map`` (e.g. ``{"float64": "float32"}``) —
    how the float32 variants of the benchmark stencils are derived without
    duplicating every definition function."""

    def _cast(e: Expr) -> Expr:
        if isinstance(e, Cast) and e.dtype in dtype_map:
            return Cast(dtype_map[e.dtype], e.expr)
        return e

    computations = tuple(
        ComputationBlock(
            block.order,
            tuple(
                IntervalBlock(ib.interval, tuple(map_stmt_exprs(s, _cast) for s in ib.body))
                for ib in block.intervals
            ),
        )
        for block in defn.computations
    )
    return dataclasses.replace(
        defn,
        name=f"{defn.name}_{'_'.join(sorted(set(dtype_map.values())))}",
        api_fields=tuple(
            dataclasses.replace(f, dtype=dtype_map.get(f.dtype, f.dtype)) for f in defn.api_fields
        ),
        scalars=tuple(
            dataclasses.replace(s, dtype=dtype_map.get(s.dtype, s.dtype)) for s in defn.scalars
        ),
        computations=computations,
    )


def make_stage(stmts: Tuple[Stmt, ...], compute_extent: Extent) -> Stage:
    """Build a Stage with writes/reads recomputed from ``stmts``."""
    writes: list = []
    reads: set = set()
    for s in stmts:
        for w in stmt_writes(s):
            if w not in writes:
                writes.append(w)
        for r, _off in stmt_reads(s):
            reads.add(r)
    return Stage(
        stmts=tuple(stmts),
        compute_extent=compute_extent,
        writes=tuple(sorted(writes)),
        reads=tuple(sorted(reads)),
    )


# ---------------------------------------------------------------------------
# Structural equality / adjacency utilities (frozen dataclasses give deep
# ``==`` for free; these express the pass-pipeline legality questions)
# ---------------------------------------------------------------------------


def stages_structurally_equal(a: Tuple[Stage, ...], b: Tuple[Stage, ...]) -> bool:
    """True when two stage sequences perform identical computations (same
    statements, same compute extents) — the k-interval-merging condition."""
    return len(a) == len(b) and all(
        sa.stmts == sb.stmts and sa.compute_extent == sb.compute_extent for sa, sb in zip(a, b)
    )


def intervals_adjacent(first: VerticalInterval, second: VerticalInterval) -> bool:
    """True when ``second`` starts exactly where ``first`` ends (same axis
    bound representation, so adjacency is domain-size independent)."""
    return first.end == second.start


def interval_span(first: VerticalInterval, second: VerticalInterval) -> VerticalInterval:
    """The single interval covering two adjacent intervals (first below)."""
    return VerticalInterval(first.start, second.end)
