"""repro.core — the paper's primary contribution: a GTScript-style embedded
stencil DSL with an IR-based analysis pipeline and code-generating backends
(debug | numpy | jax | pallas), re-targeted from GridTools/CUDA to JAX/TPU.
"""

from . import gtscript, passes, storage
from .gtscript import (
    BACKWARD,
    FORWARD,
    IJ,
    IJK,
    K,
    PARALLEL,
    Field,
    GTScriptSemanticError,
    GTScriptSyntaxError,
    computation,
    function,
    interval,
    lazy_stencil,
    stencil,
)
from .stencil import StencilObject, build_stencil_object

__all__ = [
    "gtscript",
    "passes",
    "storage",
    "Field",
    "IJK",
    "IJ",
    "K",
    "PARALLEL",
    "FORWARD",
    "BACKWARD",
    "computation",
    "interval",
    "function",
    "stencil",
    "lazy_stencil",
    "StencilObject",
    "build_stencil_object",
    "GTScriptSyntaxError",
    "GTScriptSemanticError",
]
