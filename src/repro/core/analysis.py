"""Analysis pipeline: Definition IR → Implementation IR.

Mirrors the paper's §2.3 pipeline.  Passes, in order:

1. **interval validation** — intervals within a computation must be disjoint
   and are re-ordered to execution order (ascending for FORWARD/PARALLEL,
   descending for BACKWARD).
2. **race / offset checks** — the paper's compile-time access checks:
   in a PARALLEL computation a statement may not read its own target with a
   nonzero offset ("self assignment is forbidden ... if it has
   dependencies"); in FORWARD/BACKWARD computations reads of fields written
   in the same computation may not look *ahead* of the sweep direction, and
   may not use horizontal offsets within the defining statement.
3. **definition checks** — temporaries must be written before read;
   temporaries first defined inside a conditional are zero-initialized.
4. **liveness + extent analysis** — demand-driven reverse fixpoint
   computing, for every field, the region it must be available on
   (halo for API inputs, compute extent for temporaries); dead temporaries
   and the statements that only feed them are pruned.
5. **stage scheduling** — one stage per statement, grouped into
   multi-stages (one per computation block); adjacent PARALLEL multi-stages
   with identical interval structure are fused (the GridTools fusion that
   lets the Pallas backend emit a single VMEM-resident kernel).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from . import ir
from .gtscript import GTScriptSemanticError


# ---------------------------------------------------------------------------
# Pass 1: interval validation / normalization
# ---------------------------------------------------------------------------


def _validate_and_sort_intervals(block: ir.ComputationBlock, name: str) -> ir.ComputationBlock:
    ivs = list(block.intervals)
    # sort by start bound (large-domain ordering)
    ivs.sort(key=lambda ib: ib.interval.start.key())
    for a, b in zip(ivs, ivs[1:]):
        ka, kb = a.interval.end.key(), b.interval.start.key()
        # end of a must be <= start of b under large-domain ordering
        if ka > kb:
            raise GTScriptSemanticError(
                f"stencil {name}: overlapping vertical intervals "
                f"{a.interval} and {b.interval} in a {block.order.name} computation"
            )
    if block.order == ir.IterationOrder.BACKWARD:
        ivs.reverse()
    return ir.ComputationBlock(order=block.order, intervals=tuple(ivs))


# ---------------------------------------------------------------------------
# Pass 2: race / offset checks
# ---------------------------------------------------------------------------


def _check_stmt_offsets(
    stmt: ir.Stmt,
    order: ir.IterationOrder,
    block_writes: set,
    name: str,
) -> None:
    if isinstance(stmt, ir.If):
        for s in tuple(stmt.body) + tuple(stmt.orelse):
            _check_stmt_offsets(s, order, block_writes, name)
        return
    if not isinstance(stmt, ir.Assign):
        return
    target = stmt.target.name
    for rname, off in ir.stmt_reads(stmt):
        di, dj, dk = off
        if rname == target and off != (0, 0, 0):
            if order == ir.IterationOrder.PARALLEL:
                raise GTScriptSemanticError(
                    f"stencil {name}: statement writing {target!r} reads it at offset {off} "
                    "in a PARALLEL computation (self-assignment with dependencies, paper §2.2)"
                )
            if (di, dj) != (0, 0):
                raise GTScriptSemanticError(
                    f"stencil {name}: statement writing {target!r} reads it at horizontal offset "
                    f"{(di, dj)} — the horizontal plane executes in parallel"
                )
        if rname in block_writes and rname != target:
            # cross-statement reads of block-written fields: whole-plane stage
            # semantics make same-level / already-swept levels well defined;
            # looking ahead of the sweep is a compile-time error.
            pass
        if rname in block_writes:
            if order == ir.IterationOrder.FORWARD and dk > 0:
                raise GTScriptSemanticError(
                    f"stencil {name}: read of {rname}[{di},{dj},{dk}] looks ahead of a FORWARD sweep "
                    f"that writes {rname!r}"
                )
            if order == ir.IterationOrder.BACKWARD and dk < 0:
                raise GTScriptSemanticError(
                    f"stencil {name}: read of {rname}[{di},{dj},{dk}] looks behind a BACKWARD sweep "
                    f"that writes {rname!r}"
                )
            if order == ir.IterationOrder.PARALLEL and rname == target and dk != 0:
                raise GTScriptSemanticError(
                    f"stencil {name}: vertical self-dependency {rname}[{di},{dj},{dk}] "
                    "in a PARALLEL computation"
                )


def _check_races(definition: ir.StencilDefinition) -> None:
    for block in definition.computations:
        block_writes: set = set()
        for ib in block.intervals:
            for s in ib.body:
                block_writes.update(ir.stmt_writes(s))
        for ib in block.intervals:
            for s in ib.body:
                _check_stmt_offsets(s, block.order, block_writes, definition.name)


# ---------------------------------------------------------------------------
# Pass 3: definition checks (use-before-def, conditional first definitions)
# ---------------------------------------------------------------------------


def _definition_checks(definition: ir.StencilDefinition) -> Tuple[str, ...]:
    api = {f.name for f in definition.api_fields if f.is_api}
    temps = {f.name for f in definition.api_fields if not f.is_api}
    defined: set = set(api)
    zero_init: List[str] = []

    def _walk(stmts: Sequence[ir.Stmt], conditional: bool) -> None:
        for stmt in stmts:
            if isinstance(stmt, ir.Assign):
                for rname, _off in ir.stmt_reads(stmt):
                    if rname in temps and rname not in defined:
                        raise GTScriptSemanticError(
                            f"stencil {definition.name}: temporary {rname!r} read before definition"
                        )
                if conditional and stmt.target.name in temps and stmt.target.name not in defined:
                    if stmt.target.name not in zero_init:
                        zero_init.append(stmt.target.name)
                defined.add(stmt.target.name)
            elif isinstance(stmt, ir.If):
                for rname, _off in (
                    (e.name, e.offset) for e in ir.walk_exprs(stmt.cond) if isinstance(e, ir.FieldAccess)
                ):
                    if rname in temps and rname not in defined:
                        raise GTScriptSemanticError(
                            f"stencil {definition.name}: temporary {rname!r} read before definition"
                        )
                _walk(stmt.body, True)
                _walk(stmt.orelse, True)

    for block in definition.computations:
        for ib in block.intervals:
            _walk(ib.body, False)
    return tuple(zero_init)


# ---------------------------------------------------------------------------
# Pass 4: liveness + extent analysis (demand-driven reverse fixpoint)
# ---------------------------------------------------------------------------


_MAX_FIXPOINT_ITERS = 64


def _compute_extents(
    definition: ir.StencilDefinition,
) -> Tuple[Dict[str, Optional[ir.Extent]], Dict[int, ir.Extent]]:
    """Returns (required extent per field | None if dead, compute extent per stmt id)."""
    api = {f.name for f in definition.api_fields if f.is_api}

    # flatten statements in program order, remembering identity + block order
    flat: List[ir.Stmt] = []
    stmt_order: Dict[int, ir.IterationOrder] = {}
    for block in definition.computations:
        for ib in block.intervals:
            for s in ib.body:
                flat.append(s)
                stmt_order[id(s)] = block.order

    required: Dict[str, Optional[ir.Extent]] = {}
    for block in definition.computations:
        for ib in block.intervals:
            for s in ib.body:
                for w in ir.stmt_writes(s):
                    if w in api:
                        required[w] = ir.Extent.zero()

    stmt_extent: Dict[int, ir.Extent] = {}

    for it in range(_MAX_FIXPOINT_ITERS):
        changed = False
        for stmt in reversed(flat):
            writes = list(ir.stmt_writes(stmt))
            live = any(required.get(w) is not None for w in writes)
            if not live:
                continue
            ext = ir.Extent.zero()
            for w in writes:
                r = required.get(w)
                if r is None:
                    continue
                # API fields are only ever written on the compute domain
                # (writes never touch the halo); temporaries are computed on
                # their full required extent.
                ext = ext.union(ir.Extent.zero() if w in api else r)
            prev = stmt_extent.get(id(stmt))
            if prev is None or prev != ext:
                stmt_extent[id(stmt)] = ext if prev is None else prev.union(ext)
                ext = stmt_extent[id(stmt)]
                changed = changed or (prev != ext)
            ext = stmt_extent[id(stmt)]
            sequential = stmt_order[id(stmt)] != ir.IterationOrder.PARALLEL
            for rname, off in ir.stmt_reads(stmt):
                if sequential:
                    # vertical offsets in FORWARD/BACKWARD sweeps read levels
                    # already computed inside the domain — they are loop-carried
                    # dependencies, not halo reads, and must not grow extents.
                    off = (off[0], off[1], 0)
                nreq = ext.add_offset(off)
                old = required.get(rname)
                new = nreq if old is None else old.union(nreq)
                if old != new:
                    required[rname] = new
                    changed = True
        if not changed:
            break
    else:
        raise GTScriptSemanticError(
            f"stencil {definition.name}: extent analysis did not converge — a field's halo "
            "grows with every vertical level (vertically-propagating horizontal dependency); "
            "this pattern is not supported"
        )

    for name in api:
        required.setdefault(name, None)
    return required, stmt_extent


# ---------------------------------------------------------------------------
# Pass 5: stage scheduling + fusion
# ---------------------------------------------------------------------------


def _build_stages(
    definition: ir.StencilDefinition,
    stmt_extent: Dict[int, ir.Extent],
) -> List[ir.MultiStage]:
    multi_stages: List[ir.MultiStage] = []
    for block in definition.computations:
        ms_intervals: List[ir.MultiStageInterval] = []
        for ib in block.intervals:
            stages: List[ir.Stage] = []
            for stmt in ib.body:
                ext = stmt_extent.get(id(stmt))
                if ext is None:
                    continue  # dead statement (feeds only unused temporaries)
                stages.append(
                    ir.Stage(
                        stmts=(stmt,),
                        compute_extent=ext,
                        writes=tuple(sorted(set(ir.stmt_writes(stmt)))),
                        reads=tuple(sorted({r for r, _ in ir.stmt_reads(stmt)})),
                    )
                )
            if stages:
                ms_intervals.append(ir.MultiStageInterval(interval=ib.interval, stages=tuple(stages)))
        if ms_intervals:
            multi_stages.append(ir.MultiStage(order=block.order, intervals=tuple(ms_intervals)))
    return multi_stages


def _fuse_parallel_multistages(multi_stages: List[ir.MultiStage]) -> List[ir.MultiStage]:
    """Fuse adjacent PARALLEL multi-stages with identical interval structure.

    This is the GridTools multi-stage fusion that lets a backend keep all
    intermediate stages resident in fast memory (VMEM on TPU).
    """
    fused: List[ir.MultiStage] = []
    for ms in multi_stages:
        if (
            fused
            and ms.order == ir.IterationOrder.PARALLEL
            and fused[-1].order == ir.IterationOrder.PARALLEL
            and tuple(i.interval for i in fused[-1].intervals) == tuple(i.interval for i in ms.intervals)
        ):
            prev = fused.pop()
            merged = tuple(
                ir.MultiStageInterval(interval=a.interval, stages=tuple(a.stages) + tuple(b.stages))
                for a, b in zip(prev.intervals, ms.intervals)
            )
            fused.append(ir.MultiStage(order=ir.IterationOrder.PARALLEL, intervals=merged))
        else:
            fused.append(ms)
    return fused


# ---------------------------------------------------------------------------
# Vertical bounds (the paper's compile-time offset checks, K axis)
# ---------------------------------------------------------------------------


def _check_vertical_bounds(definition: ir.StencilDefinition) -> int:
    """Statically verify vertical reads stay inside [0, nk); returns the
    extra min-k-levels requirement implied by cross-boundary offsets."""
    temps = {f.name for f in definition.api_fields if not f.is_api}
    extra_min_k = 1
    for block in definition.computations:
        for ib in block.intervals:
            s, e = ib.interval.start, ib.interval.end
            for stmt in ib.body:
                for rname, off in ir.stmt_reads(stmt):
                    dk = off[2]
                    if dk == 0 or rname in temps:
                        continue  # temporaries are allocated k-extended
                    if dk < 0:
                        if s.level == ir.LevelMarker.START and s.offset + dk < 0:
                            raise GTScriptSemanticError(
                                f"stencil {definition.name}: read {rname}[k{dk:+d}] from interval "
                                f"starting at level {s.offset} reaches below the vertical domain"
                            )
                        if s.level == ir.LevelMarker.END:
                            extra_min_k = max(extra_min_k, -(s.offset + dk))
                    else:
                        if e.level == ir.LevelMarker.END and e.offset + dk > 0:
                            raise GTScriptSemanticError(
                                f"stencil {definition.name}: read {rname}[k+{dk}] from interval "
                                f"ending at level end{e.offset:+d} reaches above the vertical domain"
                            )
                        if e.level == ir.LevelMarker.START:
                            extra_min_k = max(extra_min_k, e.offset + dk)
    return extra_min_k


# ---------------------------------------------------------------------------
# K-extent bookkeeping
# ---------------------------------------------------------------------------


def _k_extents(definition: ir.StencilDefinition) -> Dict[str, Tuple[int, int]]:
    kext: Dict[str, Tuple[int, int]] = {}
    for block in definition.computations:
        for ib in block.intervals:
            for s in ib.body:
                for rname, off in ir.stmt_reads(s):
                    lo, hi = kext.get(rname, (0, 0))
                    kext[rname] = (min(lo, off[2]), max(hi, off[2]))
    return kext


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def analyze(definition: ir.StencilDefinition, fuse: bool = True) -> ir.StencilImplementation:
    # 1. intervals
    blocks = tuple(_validate_and_sort_intervals(b, definition.name) for b in definition.computations)
    definition = ir.StencilDefinition(
        name=definition.name,
        api_fields=definition.api_fields,
        scalars=definition.scalars,
        computations=blocks,
        externals=definition.externals,
        docstring=definition.docstring,
    )

    # 2. races / offsets
    _check_races(definition)

    # 3. definitions
    zero_init = _definition_checks(definition)

    # 4. liveness + extents
    required, stmt_extent = _compute_extents(definition)

    # 5. stages
    multi_stages = _build_stages(definition, stmt_extent)
    if fuse:
        multi_stages = _fuse_parallel_multistages(multi_stages)

    api_fields = tuple(f for f in definition.api_fields if f.is_api)
    live_temps = tuple(
        f for f in definition.api_fields if not f.is_api and required.get(f.name) is not None
    )

    field_extents = tuple(
        sorted((name, ext) for name, ext in required.items() if ext is not None)
    )
    kext = _k_extents(definition)
    k_extents = tuple(sorted((name, rng) for name, rng in kext.items()))

    min_k = _check_vertical_bounds(definition)
    for block in definition.computations:
        for ib in block.intervals:
            min_k = max(min_k, ib.interval.min_levels())

    return ir.StencilImplementation(
        name=definition.name,
        api_fields=api_fields,
        temporaries=live_temps,
        scalars=definition.scalars,
        multi_stages=tuple(multi_stages),
        field_extents=field_extents,
        k_extents=k_extents,
        externals=definition.externals,
        min_k_levels=min_k,
        zero_init_temps=tuple(t for t in zero_init if any(f.name == t for f in live_temps)),
    )
