"""Analysis pipeline: Definition IR → Implementation IR.

Mirrors the paper's §2.3 pipeline.  Passes, in order:

1. **interval validation** — intervals within a computation must be disjoint
   and are re-ordered to execution order (ascending for FORWARD/PARALLEL,
   descending for BACKWARD).
2. **race / offset checks** — the paper's compile-time access checks:
   in a PARALLEL computation a statement may not read its own target with a
   nonzero offset ("self assignment is forbidden ... if it has
   dependencies"); in FORWARD/BACKWARD computations reads of fields written
   in the same computation may not look *ahead* of the sweep direction, and
   may not use horizontal offsets within the defining statement.
3. **definition checks** — temporaries must be written before read;
   temporaries first defined inside a conditional are zero-initialized.
4. **liveness + extent analysis** — demand-driven reverse fixpoint
   computing, for every field, the region it must be available on
   (halo for API inputs, compute extent for temporaries); dead temporaries
   and the statements that only feed them are pruned.
5. **stage scheduling** — one stage per statement, grouped into
   multi-stages (one per computation block).

The result is the *unoptimized* Implementation IR — a verbatim lowering of
the definition.  Architecture-independent optimizations (multi-stage fusion,
temporary demotion, interval merging, constant folding) live in the
composable pass pipeline of ``passes.py``, which runs between this module
and the codegen backends.  ``recompute_implementation`` is the shared
fixpoint the passes use to refresh extents/liveness after IR rewrites.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from . import ir
from .gtscript import GTScriptSemanticError


# ---------------------------------------------------------------------------
# Pass 1: interval validation / normalization
# ---------------------------------------------------------------------------


def _validate_and_sort_intervals(block: ir.ComputationBlock, name: str) -> ir.ComputationBlock:
    ivs = list(block.intervals)
    # sort by start bound (large-domain ordering)
    ivs.sort(key=lambda ib: ib.interval.start.key())
    for a, b in zip(ivs, ivs[1:]):
        ka, kb = a.interval.end.key(), b.interval.start.key()
        # end of a must be <= start of b under large-domain ordering
        if ka > kb:
            raise GTScriptSemanticError(
                f"stencil {name}: overlapping vertical intervals "
                f"{a.interval} and {b.interval} in a {block.order.name} computation"
            )
    if block.order == ir.IterationOrder.BACKWARD:
        ivs.reverse()
    return ir.ComputationBlock(order=block.order, intervals=tuple(ivs))


# ---------------------------------------------------------------------------
# Pass 2: race / offset checks
# ---------------------------------------------------------------------------


def _check_stmt_offsets(
    stmt: ir.Stmt,
    order: ir.IterationOrder,
    block_writes: set,
    name: str,
) -> None:
    if isinstance(stmt, ir.If):
        for s in tuple(stmt.body) + tuple(stmt.orelse):
            _check_stmt_offsets(s, order, block_writes, name)
        return
    if not isinstance(stmt, ir.Assign):
        return
    target = stmt.target.name
    for rname, off in ir.stmt_reads(stmt):
        di, dj, dk = off
        if rname == target and off != (0, 0, 0):
            if order == ir.IterationOrder.PARALLEL:
                raise GTScriptSemanticError(
                    f"stencil {name}: statement writing {target!r} reads it at offset {off} "
                    "in a PARALLEL computation (self-assignment with dependencies, paper §2.2)"
                )
            if (di, dj) != (0, 0):
                raise GTScriptSemanticError(
                    f"stencil {name}: statement writing {target!r} reads it at horizontal offset "
                    f"{(di, dj)} — the horizontal plane executes in parallel"
                )
        if rname in block_writes and rname != target:
            # cross-statement reads of block-written fields: whole-plane stage
            # semantics make same-level / already-swept levels well defined;
            # looking ahead of the sweep is a compile-time error.
            pass
        if rname in block_writes:
            if order == ir.IterationOrder.FORWARD and dk > 0:
                raise GTScriptSemanticError(
                    f"stencil {name}: read of {rname}[{di},{dj},{dk}] looks ahead of a FORWARD sweep "
                    f"that writes {rname!r}"
                )
            if order == ir.IterationOrder.BACKWARD and dk < 0:
                raise GTScriptSemanticError(
                    f"stencil {name}: read of {rname}[{di},{dj},{dk}] looks behind a BACKWARD sweep "
                    f"that writes {rname!r}"
                )
            if order == ir.IterationOrder.PARALLEL and rname == target and dk != 0:
                raise GTScriptSemanticError(
                    f"stencil {name}: vertical self-dependency {rname}[{di},{dj},{dk}] "
                    "in a PARALLEL computation"
                )


def _check_races(definition: ir.StencilDefinition) -> None:
    for block in definition.computations:
        block_writes: set = set()
        for ib in block.intervals:
            for s in ib.body:
                block_writes.update(ir.stmt_writes(s))
        for ib in block.intervals:
            for s in ib.body:
                _check_stmt_offsets(s, block.order, block_writes, definition.name)


# ---------------------------------------------------------------------------
# Pass 3: definition checks (use-before-def, conditional first definitions)
# ---------------------------------------------------------------------------


def _definition_checks(definition: ir.StencilDefinition) -> Tuple[str, ...]:
    api = {f.name for f in definition.api_fields if f.is_api}
    temps = {f.name for f in definition.api_fields if not f.is_api}
    defined: set = set(api)
    zero_init: List[str] = []

    def _walk(stmts: Sequence[ir.Stmt], conditional: bool) -> None:
        for stmt in stmts:
            if isinstance(stmt, ir.Assign):
                for rname, _off in ir.stmt_reads(stmt):
                    if rname in temps and rname not in defined:
                        raise GTScriptSemanticError(
                            f"stencil {definition.name}: temporary {rname!r} read before definition"
                        )
                if conditional and stmt.target.name in temps and stmt.target.name not in defined:
                    if stmt.target.name not in zero_init:
                        zero_init.append(stmt.target.name)
                defined.add(stmt.target.name)
            elif isinstance(stmt, ir.If):
                for rname, _off in (
                    (e.name, e.offset) for e in ir.walk_exprs(stmt.cond) if isinstance(e, ir.FieldAccess)
                ):
                    if rname in temps and rname not in defined:
                        raise GTScriptSemanticError(
                            f"stencil {definition.name}: temporary {rname!r} read before definition"
                        )
                _walk(stmt.body, True)
                _walk(stmt.orelse, True)

    for block in definition.computations:
        for ib in block.intervals:
            _walk(ib.body, False)
    return tuple(zero_init)


# ---------------------------------------------------------------------------
# Pass 4: liveness + extent analysis (demand-driven reverse fixpoint)
# ---------------------------------------------------------------------------


_MAX_FIXPOINT_ITERS = 64

# A fixpoint "unit" is anything with an iteration order, writes, and reads:
# a Definition-IR statement during the initial lowering, an Implementation-IR
# stage when the pass pipeline re-analyzes after a rewrite.
_FixpointUnit = Tuple[ir.IterationOrder, int, List[str], List[Tuple[str, Tuple[int, int, int]]]]


def _extent_fixpoint(
    units: List[_FixpointUnit],
    api: set,
    error: str,
) -> Tuple[Dict[str, Optional[ir.Extent]], Dict[int, ir.Extent]]:
    """Demand-driven reverse fixpoint over ``units`` in program order.

    Returns (required extent per field | absent if dead, compute extent per
    unit key; units that never become live stay absent).  Shared by the
    statement-level lowering and the pass pipeline's stage-level re-analysis
    so the two can never drift apart.
    """
    required: Dict[str, Optional[ir.Extent]] = {}
    for _order, _key, writes, _reads in units:
        for w in writes:
            if w in api:
                required[w] = ir.Extent.zero()

    unit_extent: Dict[int, ir.Extent] = {}
    for _it in range(_MAX_FIXPOINT_ITERS):
        changed = False
        for order, key, writes, reads in reversed(units):
            if not any(required.get(w) is not None for w in writes):
                continue
            ext = ir.Extent.zero()
            for w in writes:
                r = required.get(w)
                if r is None:
                    continue
                # API fields are only ever written on the compute domain
                # (writes never touch the halo); temporaries are computed on
                # their full required extent.
                ext = ext.union(ir.Extent.zero() if w in api else r)
            prev = unit_extent.get(key)
            new_ext = ext if prev is None else prev.union(ext)
            if prev != new_ext:
                unit_extent[key] = new_ext
                changed = True
            ext = unit_extent[key]
            sequential = order != ir.IterationOrder.PARALLEL
            for rname, off in reads:
                if sequential:
                    # vertical offsets in FORWARD/BACKWARD sweeps read levels
                    # already computed inside the domain — they are loop-carried
                    # dependencies, not halo reads, and must not grow extents.
                    off = (off[0], off[1], 0)
                nreq = ext.add_offset(off)
                old = required.get(rname)
                new = nreq if old is None else old.union(nreq)
                if old != new:
                    required[rname] = new
                    changed = True
        if not changed:
            return required, unit_extent
    raise GTScriptSemanticError(error)


def _compute_extents(
    definition: ir.StencilDefinition,
) -> Tuple[Dict[str, Optional[ir.Extent]], Dict[int, ir.Extent]]:
    """Returns (required extent per field | None if dead, compute extent per stmt id)."""
    api = {f.name for f in definition.api_fields if f.is_api}

    units: List[_FixpointUnit] = []
    for block in definition.computations:
        for ib in block.intervals:
            for s in ib.body:
                units.append((block.order, id(s), list(ir.stmt_writes(s)), list(ir.stmt_reads(s))))

    required, stmt_extent = _extent_fixpoint(
        units,
        api,
        f"stencil {definition.name}: extent analysis did not converge — a field's halo "
        "grows with every vertical level (vertically-propagating horizontal dependency); "
        "this pattern is not supported",
    )
    for name in api:
        required.setdefault(name, None)
    return required, stmt_extent


# ---------------------------------------------------------------------------
# Pass 5: stage scheduling + fusion
# ---------------------------------------------------------------------------


def _build_stages(
    definition: ir.StencilDefinition,
    stmt_extent: Dict[int, ir.Extent],
) -> List[ir.MultiStage]:
    multi_stages: List[ir.MultiStage] = []
    for block in definition.computations:
        ms_intervals: List[ir.MultiStageInterval] = []
        for ib in block.intervals:
            stages: List[ir.Stage] = []
            for stmt in ib.body:
                ext = stmt_extent.get(id(stmt))
                if ext is None:
                    continue  # dead statement (feeds only unused temporaries)
                stages.append(
                    ir.Stage(
                        stmts=(stmt,),
                        compute_extent=ext,
                        writes=tuple(sorted(set(ir.stmt_writes(stmt)))),
                        reads=tuple(sorted({r for r, _ in ir.stmt_reads(stmt)})),
                    )
                )
            if stages:
                ms_intervals.append(ir.MultiStageInterval(interval=ib.interval, stages=tuple(stages)))
        if ms_intervals:
            multi_stages.append(ir.MultiStage(order=block.order, intervals=tuple(ms_intervals)))
    return multi_stages


# ---------------------------------------------------------------------------
# Vertical bounds (the paper's compile-time offset checks, K axis)
# ---------------------------------------------------------------------------


def _check_vertical_bounds(definition: ir.StencilDefinition) -> int:
    """Statically verify vertical reads stay inside [0, nk); returns the
    extra min-k-levels requirement implied by cross-boundary offsets."""
    temps = {f.name for f in definition.api_fields if not f.is_api}
    extra_min_k = 1
    for block in definition.computations:
        for ib in block.intervals:
            s, e = ib.interval.start, ib.interval.end
            for stmt in ib.body:
                for rname, off in ir.stmt_reads(stmt):
                    dk = off[2]
                    if dk == 0 or rname in temps:
                        continue  # temporaries are allocated k-extended
                    if dk < 0:
                        if s.level == ir.LevelMarker.START and s.offset + dk < 0:
                            raise GTScriptSemanticError(
                                f"stencil {definition.name}: read {rname}[k{dk:+d}] from interval "
                                f"starting at level {s.offset} reaches below the vertical domain"
                            )
                        if s.level == ir.LevelMarker.END:
                            extra_min_k = max(extra_min_k, -(s.offset + dk))
                    else:
                        if e.level == ir.LevelMarker.END and e.offset + dk > 0:
                            raise GTScriptSemanticError(
                                f"stencil {definition.name}: read {rname}[k+{dk}] from interval "
                                f"ending at level end{e.offset:+d} reaches above the vertical domain"
                            )
                        if e.level == ir.LevelMarker.START:
                            extra_min_k = max(extra_min_k, e.offset + dk)
    return extra_min_k


# ---------------------------------------------------------------------------
# K-extent bookkeeping
# ---------------------------------------------------------------------------


def _k_extents(definition: ir.StencilDefinition) -> Dict[str, Tuple[int, int]]:
    kext: Dict[str, Tuple[int, int]] = {}
    for block in definition.computations:
        for ib in block.intervals:
            for s in ib.body:
                for rname, off in ir.stmt_reads(s):
                    lo, hi = kext.get(rname, (0, 0))
                    kext[rname] = (min(lo, off[2]), max(hi, off[2]))
    return kext


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def analyze(definition: ir.StencilDefinition, fuse: bool = False) -> ir.StencilImplementation:
    """Lower a Definition IR to the (unoptimized) Implementation IR.

    ``fuse=True`` additionally applies the multi-stage fusion pass — kept for
    back-compatibility with callers that predate ``passes.py``; the build
    pipeline now runs fusion (and the other passes) itself."""
    # 1. intervals
    blocks = tuple(_validate_and_sort_intervals(b, definition.name) for b in definition.computations)
    definition = ir.StencilDefinition(
        name=definition.name,
        api_fields=definition.api_fields,
        scalars=definition.scalars,
        computations=blocks,
        externals=definition.externals,
        docstring=definition.docstring,
    )

    # 2. races / offsets
    _check_races(definition)

    # 3. definitions
    zero_init = _definition_checks(definition)

    # 4. liveness + extents
    required, stmt_extent = _compute_extents(definition)

    # 5. stages
    multi_stages = _build_stages(definition, stmt_extent)

    api_fields = tuple(f for f in definition.api_fields if f.is_api)
    live_temps = tuple(
        f for f in definition.api_fields if not f.is_api and required.get(f.name) is not None
    )

    field_extents = tuple(
        sorted((name, ext) for name, ext in required.items() if ext is not None)
    )
    kext = _k_extents(definition)
    k_extents = tuple(sorted((name, rng) for name, rng in kext.items()))

    min_k = _check_vertical_bounds(definition)
    for block in definition.computations:
        ordered = sorted(block.intervals, key=lambda ib: ib.interval.start.key())
        for ib in ordered:
            min_k = max(min_k, ib.interval.min_levels())
        for a, b in zip(ordered, ordered[1:]):
            ae, bs = a.interval.end, b.interval.start
            if ae.level == ir.LevelMarker.START and bs.level == ir.LevelMarker.END:
                # intervals validated under large-domain ordering: a START-
                # relative end [.., START+x) before an END-relative start
                # [END+y, ..) is only actually disjoint when nk + y >= x —
                # without this, e.g. interval(0, 1) + interval(-1, None)
                # silently execute the same level twice at nk == 1
                min_k = max(min_k, ae.offset - bs.offset)

    impl = ir.StencilImplementation(
        name=definition.name,
        api_fields=api_fields,
        temporaries=live_temps,
        scalars=definition.scalars,
        multi_stages=tuple(multi_stages),
        field_extents=field_extents,
        k_extents=k_extents,
        externals=definition.externals,
        min_k_levels=min_k,
        zero_init_temps=tuple(t for t in zero_init if any(f.name == t for f in live_temps)),
    )
    if fuse:
        from .passes import MultiStageFusion, PassContext

        impl = MultiStageFusion()(impl, PassContext(opt_level=1))
    return impl


# ---------------------------------------------------------------------------
# Sequential-sweep carry liveness (k-blocking plan for the jax/pallas loops)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepCarryPlan:
    """Which state one FORWARD/BACKWARD multi-stage must materialize.

    ``full``   — fields whose every written plane stays live: API outputs, and
                 temporaries some *other* multi-stage reads.  The loop carries
                 the whole (ni, nj, nk) array, as before.
    ``window`` — temporaries written only in this multi-stage and read only in
                 this multi-stage, at trailing vertical offsets.  Only the last
                 ``depth`` planes are live at any point of the sweep, so the
                 loop carries a rolling window of ``depth`` 2-D planes instead
                 of a full 3-D array (depth = max trailing-offset distance;
                 0 means the value never crosses an iteration).
    """

    full: Tuple[str, ...]
    window: Tuple[Tuple[str, int], ...]  # (name, depth), first-write order

    def carried_planes(self, nk: int) -> int:
        return len(self.full) * nk + sum(d for _, d in self.window)

    def baseline_planes(self, nk: int) -> int:
        return (len(self.full) + len(self.window)) * nk


def sequential_carry_plan(impl: ir.StencilImplementation) -> Dict[int, SweepCarryPlan]:
    """Per sequential multi-stage (by index), the liveness-proven carry plan.

    Legality of the window classification: a temporary written *only* inside
    multi-stage ``mi`` and read *only* inside ``mi`` can never be observed at
    a plane more than ``depth`` iterations behind the sweep — the race checks
    (`_check_stmt_offsets`) already reject reads ahead of the sweep, so every
    in-sweep read is a trailing read.  Planes the sweep never wrote read as
    the zero initialization either way (the rolling window starts zeroed and
    each iteration's plane starts zeroed, exactly like the zero-initialized
    3-D temporary it replaces).
    """
    api = {f.name for f in impl.api_fields}
    locals_ = {f.name for f in impl.local_decls}

    reads_by_ms: Dict[int, Dict[str, set]] = {}
    writes_by_ms: Dict[int, set] = {}
    for mi, ms in enumerate(impl.multi_stages):
        reads: Dict[str, set] = {}
        writes: set = set()
        for itv in ms.intervals:
            for st in itv.stages:
                for stmt in st.stmts:
                    for rname, off in ir.stmt_reads(stmt):
                        reads.setdefault(rname, set()).add(off)
                    writes.update(ir.stmt_writes(stmt))
        reads_by_ms[mi] = reads
        writes_by_ms[mi] = writes

    plans: Dict[int, SweepCarryPlan] = {}
    for mi, ms in enumerate(impl.multi_stages):
        if ms.order == ir.IterationOrder.PARALLEL:
            continue
        written: List[str] = []
        for itv in ms.intervals:
            for st in itv.stages:
                for w in st.writes:
                    if w not in written and w not in locals_:
                        written.append(w)
        full: List[str] = []
        window: List[Tuple[str, int]] = []
        for name in written:
            decl = impl.field(name)
            windowable = (
                name not in api
                and decl.axes == ir.AXES_IJK
                and not any(
                    name in reads_by_ms[mj] or name in writes_by_ms[mj]
                    for mj in reads_by_ms
                    if mj != mi
                )
            )
            if windowable:
                depth = max((abs(off[2]) for off in reads_by_ms[mi].get(name, ())), default=0)
                window.append((name, depth))
            else:
                full.append(name)
        plans[mi] = SweepCarryPlan(full=tuple(full), window=tuple(window))
    return plans


# ---------------------------------------------------------------------------
# Implementation-IR re-analysis (shared fixpoint for the pass pipeline)
# ---------------------------------------------------------------------------


def recompute_implementation(impl: ir.StencilImplementation) -> ir.StencilImplementation:
    """Recompute liveness, per-stage compute extents, field extents and
    k-extents of an Implementation IR after a pass rewrote its stages.

    The same demand-driven reverse fixpoint as ``_compute_extents``, run at
    stage granularity: dead stages (feeding only unread temporaries) are
    dropped, dead temporaries removed, and extents shrink to what the
    surviving statements actually require.
    """
    api = {f.name for f in impl.api_fields}

    units: List[_FixpointUnit] = []
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                reads = [r for stmt in st.stmts for r in ir.stmt_reads(stmt)]
                units.append((ms.order, id(st), list(st.writes), reads))

    required, stage_extent = _extent_fixpoint(
        units,
        api,
        f"stencil {impl.name}: extent re-analysis did not converge after an IR rewrite",
    )

    multi_stages: List[ir.MultiStage] = []
    for ms in impl.multi_stages:
        intervals: List[ir.MultiStageInterval] = []
        for itv in ms.intervals:
            stages: List[ir.Stage] = []
            for st in itv.stages:
                ext = stage_extent.get(id(st))
                if ext is None:
                    continue  # dead stage
                stages.append(ir.make_stage(st.stmts, ext))
            if stages:
                intervals.append(ir.MultiStageInterval(itv.interval, tuple(stages)))
        if intervals:
            multi_stages.append(ir.MultiStage(ms.order, tuple(intervals)))

    temporaries = tuple(f for f in impl.temporaries if required.get(f.name) is not None)
    local_decls = tuple(f for f in impl.local_decls if required.get(f.name) is not None)
    field_extents = tuple(sorted((n, e) for n, e in required.items() if e is not None))

    kext: Dict[str, Tuple[int, int]] = {}
    for ms in multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for stmt in st.stmts:
                    for rname, off in ir.stmt_reads(stmt):
                        lo, hi = kext.get(rname, (0, 0))
                        kext[rname] = (min(lo, off[2]), max(hi, off[2]))
    k_extents = tuple(sorted((name, rng) for name, rng in kext.items()))

    live = {f.name for f in temporaries}
    return dataclasses.replace(
        impl,
        multi_stages=tuple(multi_stages),
        temporaries=temporaries,
        local_decls=local_decls,
        field_extents=field_extents,
        k_extents=k_extents,
        zero_init_temps=tuple(t for t in impl.zero_init_temps if t in live),
    )
