"""Pallas TPU backend: the analogue of the paper's ``gtcuda`` code generator.

TPU adaptation of the GridTools GPU schedule (see DESIGN.md §2):

* The horizontal (i, j) plane is tiled over a 2-D Pallas grid; each grid cell
  DMAs its *tile + halo* from HBM (inputs live in ``ANY`` memory space) into
  VMEM scratch with ``pltpu.make_async_copy`` — TPU blocks cannot overlap, so
  the CUDA shared-memory halo load becomes an explicit strided DMA.
* **Software-prefetched halo DMAs**: every input tile's copy is issued up
  front on its own semaphore, and the ``wait`` is deferred to the first
  multi-stage that touches the field — inputs consumed by later multi-stages
  stream in *while earlier multi-stages compute* instead of serializing
  behind a start-all/wait-all barrier.
* All multi-stages of the stencil execute **fused** inside one kernel while
  the tile is VMEM-resident: intermediate stages (temporaries) never touch
  HBM.  This is the GridTools fusion argument restated for the TPU memory
  hierarchy — the memory-roofline win of the backend.
* PARALLEL multi-stages vectorize over the whole (tile_i, tile_j, k) block;
  FORWARD/BACKWARD multi-stages run **k-blocked** ``lax.fori_loop``s that
  carry only the liveness-proven state (``analysis.sequential_carry_plan``):
  API outputs and cross-multi-stage temporaries stay full 3-D, sweep-local
  recurrence temporaries collapse to a rolling window of 2-D planes — which
  is what frees VMEM headroom for larger tiles.
* Outputs are written back through regular non-overlapping BlockSpecs.
* The generated module exports ``SCHEDULE`` (DMA waits, carried planes,
  window depths) and ``_vmem_bytes`` (per-tile VMEM estimate) so the
  autotuner (``core/autotune.py``) can filter and time ``(BI, BJ)``
  candidates; ``run`` accepts ``block=`` to override ``_BLOCK_DEFAULT``.

Limitations (documented): written API fields may not be read at nonzero
horizontal offsets (allocate a temporary instead); TPU hardware wants
float32/bfloat16 — float64 kernels run under ``interpret=True`` only.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from . import analysis, ir
from .codegen_common import (
    ArrayExprPrinter,
    Emitter,
    _c,
    emit_helpers,
    emit_parallel_block,
    emit_sweep,
    multistage_plan,
)
from .gtscript import GTScriptSemanticError


def _reads_of(impl: ir.StencilImplementation) -> Dict[str, List[Tuple[int, int, int]]]:
    reads: Dict[str, List[Tuple[int, int, int]]] = {}
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for stmt in st.stmts:
                    for n, off in ir.stmt_reads(stmt):
                        reads.setdefault(n, []).append(off)
    return reads


def _writes_of(impl: ir.StencilImplementation) -> List[str]:
    out: List[str] = []
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for w in st.writes:
                    if w not in out:
                        out.append(w)
    return out


def _ms_touched(ms: ir.MultiStage) -> set:
    touched: set = set()
    for itv in ms.intervals:
        for st in itv.stages:
            touched.update(st.reads)
            touched.update(st.writes)
    return touched


def _masked_writes(impl: ir.StencilImplementation) -> set:
    """Fields only ever written under an ``If`` keep their old value on the
    false lanes — the kernel must start from the caller's data, not zeros."""
    masked: set = set()
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for stmt in st.stmts:
                    if isinstance(stmt, ir.If):
                        masked.update(ir.stmt_writes(stmt))
    return masked


def _written_k_coverage_full(impl: ir.StencilImplementation, name: str) -> bool:
    """True when the union of vertical intervals writing ``name`` provably
    covers the whole [START, END) axis (at representation level, so the
    answer is domain-size independent; gaps that only close for specific nk
    count as partial — conservative)."""
    intervals = [
        itv.interval
        for ms in impl.multi_stages
        for itv in ms.intervals
        if any(name in st.writes for st in itv.stages)
    ]
    if not intervals:
        return True
    ivs = sorted(intervals, key=lambda iv: iv.start.key())
    if ivs[0].start != ir.AxisBound(ir.LevelMarker.START, 0):
        return False
    end = ivs[0].end
    for iv in ivs[1:]:
        if iv.start.key() > end.key():
            return False  # gap under large-domain ordering
        if iv.end.key() > end.key():
            end = iv.end
    return end == ir.AxisBound(ir.LevelMarker.END, 0)


def generate_pallas_source(
    impl: ir.StencilImplementation,
    block: Tuple[int, int] = (8, 128),
) -> str:
    api_names = {f.name: f for f in impl.api_fields}
    reads = _reads_of(impl)
    writes = _writes_of(impl)
    written_api = [w for w in writes if w in api_names]
    read_api = [f.name for f in impl.api_fields if f.name in reads]
    # API fields that are both read and written need their tile DMA'd in as
    # the initial value of the functional in-kernel array.  So do outputs
    # whose writes don't provably cover the whole vertical axis, or that are
    # only written under a mask: every other backend preserves the caller's
    # values on unwritten planes / false lanes, and a zeros-initialized
    # kernel array would clobber them (a divergence the backend-differential
    # fuzzer caught on boundary-only outputs).
    masked = _masked_writes(impl)
    inout_api = [
        n
        for n in written_api
        if n in reads or n in masked or not _written_k_coverage_full(impl, n)
    ]
    input_api = [n for n in read_api if n not in written_api] + inout_api

    for n in written_api:
        for off in reads.get(n, []):
            if (off[0], off[1]) != (0, 0):
                raise GTScriptSemanticError(
                    f"pallas backend: written API field {n!r} is read at horizontal offset "
                    f"{off}; stage the value through a temporary instead"
                )

    # vertical reads stay in-domain (analysis._check_vertical_bounds) and the
    # DMA always carries the full column, so only the horizontal halo matters.
    H = max(impl.max_halo[0], impl.max_halo[1])

    axes_of = {f.name: f.axes for f in impl.all_fields}
    dtype_of = {f.name: f.dtype for f in impl.all_fields}
    for n in api_names:
        if axes_of[n] not in (("I", "J", "K"), ("I", "J"), ("K",)):
            raise GTScriptSemanticError(f"pallas backend: unsupported axes {axes_of[n]} for {n!r}")

    # the fields that arrive via an explicit halo DMA (K fields ride whole in VMEM)
    dma_inputs = [n for n in input_api if axes_of[n] != ("K",)]
    k_inputs = [n for n in input_api if axes_of[n] == ("K",)]

    # first multi-stage that touches each DMA'd input — the wait point
    first_use: Dict[str, int] = {}
    for mi, ms in enumerate(impl.multi_stages):
        touched = _ms_touched(ms)
        for n in dma_inputs:
            if n in touched:
                first_use.setdefault(n, mi)
    for n in dma_inputs:
        first_use.setdefault(n, 0)

    # k-blocked sweep plan: which sequential state is carried full vs windowed
    carry_plans = analysis.sequential_carry_plan(impl)
    windowed: Dict[str, int] = {}
    for plan in carry_plans.values():
        windowed.update(dict(plan.window))

    printer = ArrayExprPrinter(impl, "jnp", axes_of, dtype_of)

    # ---------------- kernel body ----------------
    kb = Emitter()
    kb.push()  # inside def _make_kernel
    kb.push()  # inside def _kernel
    kb.line("ni, nj = _BI, _BJ")
    kb.line("nk = _NK")
    kb.line("gi = pl.program_id(0)")
    kb.line("gj = pl.program_id(1)")
    # issue every halo DMA up front, each on its own semaphore; waits are
    # deferred to each field's first-use multi-stage (software prefetch)
    for i, n in enumerate(dma_inputs):
        if axes_of[n] == ("I", "J"):
            src = f"{n}_hbm.at[pl.ds(gi * _BI, _BI + 2 * _H), pl.ds(gj * _BJ, _BJ + 2 * _H)]"
        else:
            src = f"{n}_hbm.at[pl.ds(gi * _BI, _BI + 2 * _H), pl.ds(gj * _BJ, _BJ + 2 * _H), :]"
        kb.line(f"_cp_{n} = pltpu.make_async_copy({src}, _s_{n}, _dma_sems.at[{i}])")
        kb.line(f"_cp_{n}.start()")
    for s in impl.scalars:
        kb.line(f"{s.name} = {s.name}_smem[0]")
    # K fields arrive whole in VMEM — no DMA to wait on
    for n in k_inputs:
        kb.line(f"{n} = {n}_vmem[...]")
        kb.line(f"_oi_{n}, _oj_{n}, _ok_{n} = (0, 0, 0)")
    # pure outputs start as zeros (functional in-kernel arrays)
    for n in written_api:
        if n in inout_api:
            continue  # bound from the DMA'd scratch at first use
        axes = axes_of[n]
        if axes == ("I", "J", "K"):
            shape = "(ni, nj, nk)"
        elif axes == ("I", "J"):
            shape = "(ni, nj)"
        else:
            shape = "(nk,)"
        kb.line(f"{n} = jnp.zeros({shape}, dtype='{dtype_of[n]}')")
        kb.line(f"_oi_{n}, _oj_{n}, _ok_{n} = (0, 0, 0)")
    # temporaries (in-tile, VMEM-resident — the fusion payoff); sweep-window
    # temporaries materialize as rolling planes inside their sweep instead
    for t in impl.temporaries:
        if t.name in windowed:
            continue
        ext = impl.extent_of(t.name)
        (ilo, ihi), (jlo, jhi), (klo, khi) = ext.as_tuple()
        axes = axes_of[t.name]
        if axes == ("I", "J", "K"):
            shape = f"(ni{_c(ihi - ilo)}, nj{_c(jhi - jlo)}, nk{_c(khi - klo)})"
            origin = (-ilo, -jlo, -klo)
        elif axes == ("I", "J"):
            shape = f"(ni{_c(ihi - ilo)}, nj{_c(jhi - jlo)})"
            origin = (-ilo, -jlo, 0)
        else:
            shape = f"(nk{_c(khi - klo)},)"
            origin = (0, 0, -klo)
        kb.line(f"{t.name} = jnp.zeros({shape}, dtype='{t.dtype}')")
        kb.line(f"_oi_{t.name}, _oj_{t.name}, _ok_{t.name} = {origin}")

    # ----- fused multi-stages, with DMA waits at each input's first use
    for mi, ms in enumerate(impl.multi_stages):
        kb.line(f"# === multi-stage {mi}: {multistage_plan(ms)}")
        for n in dma_inputs:
            if first_use[n] != mi:
                continue
            kb.line(f"_cp_{n}.wait()")
            if n in inout_api:
                if axes_of[n] == ("I", "J"):
                    kb.line(f"{n} = _s_{n}[_H:_H + ni, _H:_H + nj]")
                else:
                    kb.line(f"{n} = _s_{n}[_H:_H + ni, _H:_H + nj, :]")
                kb.line(f"_oi_{n}, _oj_{n}, _ok_{n} = (0, 0, 0)")
            else:
                kb.line(f"{n} = _s_{n}[...]")
                kb.line(f"_oi_{n}, _oj_{n}, _ok_{n} = (_H, _H, 0)")
        if ms.order == ir.IterationOrder.PARALLEL:
            emit_parallel_block(impl, printer, kb, ms, mi, functional=True)
        else:
            emit_sweep(impl, printer, kb, ms, mi, carry_plans[mi], "jnp")

    for n in written_api:
        kb.line(f"{n}_out_ref[...] = {n}")

    # ---------------- static schedule / VMEM metadata ----------------
    schedule = {
        "halo": H,
        "block_default": tuple(block),
        "dma_inputs": list(dma_inputs),
        "dma_first_use_ms": dict(sorted(first_use.items())),
        "sweeps": {
            mi: {"full": list(plan.full), "window": dict(plan.window)}
            for mi, plan in sorted(carry_plans.items())
        },
        "full_carry_fields": sum(len(p.full) for p in carry_plans.values()),
        "window_fields": len(windowed),
        "window_planes": sum(windowed.values()),
    }

    # per-tile VMEM estimate terms: (extra_i, extra_j, k_planes | -1 for nk, itemsize)
    vmem_terms: List[Tuple[int, int, int, int]] = []
    k_bytes = 0
    for n in dma_inputs:
        isz = np.dtype(dtype_of[n]).itemsize
        vmem_terms.append((2 * H, 2 * H, -1 if axes_of[n] == ("I", "J", "K") else 1, isz))
    for n in k_inputs:
        k_bytes += np.dtype(dtype_of[n]).itemsize
    for n in written_api:
        isz = np.dtype(dtype_of[n]).itemsize
        vmem_terms.append((0, 0, -1 if axes_of[n] == ("I", "J", "K") else 1, isz))
    for t in impl.temporaries:
        isz = np.dtype(t.dtype).itemsize
        (ilo, ihi), (jlo, jhi), (klo, khi) = impl.extent_of(t.name).as_tuple()
        if t.name in windowed:
            vmem_terms.append((ihi - ilo, jhi - jlo, windowed[t.name] + 1, isz))
        elif axes_of[t.name] == ("I", "J", "K"):
            vmem_terms.append((ihi - ilo, jhi - jlo, -1, isz))
        elif axes_of[t.name] == ("I", "J"):
            vmem_terms.append((ihi - ilo, jhi - jlo, 1, isz))
        else:
            k_bytes += isz

    # ---------------- module assembly ----------------
    em = Emitter()
    em.line(f'"""Auto-generated by repro.core — stencil {impl.name!r}, backend \'pallas\'."""')
    em.line("import functools")
    em.line("import numpy as np")
    em.line("import jax")
    em.line("import jax.numpy as jnp")
    em.line("from jax import lax")
    em.line("from jax.experimental import pallas as pl")
    em.line("from jax.experimental.pallas import tpu as pltpu")
    emit_helpers(em, printer.used_helpers, "jnp")
    em.line()
    em.line("INTERPRET = jax.devices()[0].platform != 'tpu'")
    em.line(f"_H = {H}")
    em.line(f"_BLOCK_DEFAULT = {tuple(block)!r}")
    em.line(f"_SCALARS = {[s.name for s in impl.scalars]!r}")
    em.line(f"_INPUT_API = {input_api!r}")
    em.line(f"_WRITTEN_API = {written_api!r}")
    em.line(f"_K_FIELDS = {k_inputs!r}")
    em.line(f"_AXES = {dict(sorted((n, axes_of[n]) for n in api_names))!r}")
    em.line(f"_DTYPES = {dict(sorted((n, dtype_of[n]) for n in api_names))!r}")
    em.line(f"SCHEDULE = {schedule!r}")
    em.line(f"_VMEM_TERMS = {vmem_terms!r}")
    em.line(f"_VMEM_K_BYTES = {k_bytes!r}")
    em.line()
    em.line("def _vmem_bytes(bi, bj, nk):")
    em.push()
    em.line('"""Per-tile VMEM footprint estimate for (bi, bj) at nk levels."""')
    em.line("total = nk * _VMEM_K_BYTES")
    em.line("for di, dj, kfac, isz in _VMEM_TERMS:")
    em.push()
    em.line("total += (bi + di) * (bj + dj) * (nk if kfac < 0 else kfac) * isz")
    em.pop()
    em.line("return total")
    em.pop()
    em.line()
    em.line("def _make_kernel(_BI, _BJ, _NK):")
    em.push()
    em.line("def _kernel(" + ", ".join(
        [f"{s.name}_smem" for s in impl.scalars]
        + [f"{n}_vmem" if axes_of[n] == ("K",) else f"{n}_hbm" for n in input_api]
        + [f"{n}_out_ref" for n in written_api]
        + [f"_s_{n}" for n in dma_inputs]
        + (["_dma_sems"] if dma_inputs else [])
    ) + "):")
    em.pop()
    source = em.source() + kb.source()

    tail = Emitter()
    tail.push()
    tail.line("return _kernel")
    tail.pop()
    tail.line()
    tail.line("@functools.lru_cache(maxsize=None)")
    tail.line("def _build(domain, block):")
    tail.push()
    tail.line("ni, nj, nk = domain")
    tail.line("bi = min(block[0], ni)")
    tail.line("bj = min(block[1], nj)")
    tail.line("nti = -(-ni // bi)")
    tail.line("ntj = -(-nj // bj)")
    tail.line("kernel = _make_kernel(bi, bj, nk)")
    tail.line("in_specs = []")
    tail.line("for s in _SCALARS:")
    tail.push()
    tail.line("in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))")
    tail.pop()
    tail.line("for n in _INPUT_API:")
    tail.push()
    tail.line("if n in _K_FIELDS:")
    tail.push()
    tail.line("in_specs.append(pl.BlockSpec(memory_space=pltpu.VMEM))")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("in_specs.append(pl.BlockSpec(memory_space=pl.ANY))")
    tail.pop()
    tail.pop()
    tail.line("out_specs = []")
    tail.line("out_shapes = []")
    tail.line("for n in _WRITTEN_API:")
    tail.push()
    tail.line("if _AXES[n] == ('I', 'J', 'K'):")
    tail.push()
    tail.line("out_specs.append(pl.BlockSpec((bi, bj, nk), lambda i, j: (i, j, 0)))")
    tail.line("out_shapes.append(jax.ShapeDtypeStruct((nti * bi, ntj * bj, nk), _DTYPES[n]))")
    tail.pop()
    tail.line("elif _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("out_specs.append(pl.BlockSpec((bi, bj), lambda i, j: (i, j)))")
    tail.line("out_shapes.append(jax.ShapeDtypeStruct((nti * bi, ntj * bj), _DTYPES[n]))")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("raise NotImplementedError('K-field outputs in pallas backend')")
    tail.pop()
    tail.pop()
    tail.line("scratch = []")
    tail.line("n_dma = 0")
    tail.line("for n in _INPUT_API:")
    tail.push()
    tail.line("if n in _K_FIELDS:")
    tail.push()
    tail.line("continue")
    tail.pop()
    tail.line("n_dma += 1")
    tail.line("if _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("scratch.append(pltpu.VMEM((bi + 2 * _H, bj + 2 * _H), _DTYPES[n]))")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("scratch.append(pltpu.VMEM((bi + 2 * _H, bj + 2 * _H, nk), _DTYPES[n]))")
    tail.pop()
    tail.pop()
    tail.line("if n_dma:")
    tail.push()
    tail.line("# one DMA semaphore per prefetched input tile")
    tail.line("scratch.append(pltpu.SemaphoreType.DMA((n_dma,)))")
    tail.pop()
    tail.line("call = pl.pallas_call(kernel, grid=(nti, ntj), in_specs=in_specs, out_specs=out_specs,")
    tail.line("                      out_shape=out_shapes, scratch_shapes=scratch, interpret=INTERPRET)")
    tail.line("return jax.jit(call), (bi, bj, nti, ntj)")
    tail.pop()
    tail.line()
    tail.line("def run(fields, scalars, domain, origins, block=None):")
    tail.push()
    tail.line("ni, nj, nk = domain")
    tail.line("call, (bi, bj, nti, ntj) = _build(tuple(domain), tuple(block or _BLOCK_DEFAULT))")
    tail.line("args = []")
    tail.line("for s in _SCALARS:")
    tail.push()
    tail.line("args.append(jnp.asarray([scalars[s]], dtype=_DTYPES[_WRITTEN_API[0]]))")
    tail.pop()
    tail.line("pad_i = nti * bi - ni")
    tail.line("pad_j = ntj * bj - nj")
    tail.line("for n in _INPUT_API:")
    tail.push()
    tail.line("arr = fields[n]")
    tail.line("oi, oj, ok = origins[n]")
    tail.line("if n in _K_FIELDS:")
    tail.push()
    tail.line("args.append(jax.lax.dynamic_slice(arr, (ok,), (nk,)))")
    tail.line("continue")
    tail.pop()
    tail.line("if _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("region = arr[oi - _H:oi + ni + _H, oj - _H:oj + nj + _H]")
    tail.line("region = jnp.pad(region, ((0, pad_i), (0, pad_j)), mode='edge')")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("region = arr[oi - _H:oi + ni + _H, oj - _H:oj + nj + _H, ok:ok + nk]")
    tail.line("region = jnp.pad(region, ((0, pad_i), (0, pad_j), (0, 0)), mode='edge')")
    tail.pop()
    tail.line("args.append(region)")
    tail.pop()
    tail.line("outs = call(*args)")
    tail.line("updates = {}")
    tail.line("for n, new in zip(_WRITTEN_API, outs):")
    tail.push()
    tail.line("arr = fields[n]")
    tail.line("oi, oj, ok = origins[n]")
    tail.line("if _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("updates[n] = arr.at[oi:oi + ni, oj:oj + nj].set(new[:ni, :nj])")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("updates[n] = arr.at[oi:oi + ni, oj:oj + nj, ok:ok + nk].set(new[:ni, :nj, :])")
    tail.pop()
    tail.pop()
    tail.line("return updates")
    tail.pop()

    return source + tail.source()
