"""Pallas TPU backend: the analogue of the paper's ``gtcuda`` code generator.

TPU adaptation of the GridTools GPU schedule (see DESIGN.md §2):

* The horizontal (i, j) plane is tiled over a 2-D Pallas grid; each grid cell
  DMAs its *tile + halo* from HBM (inputs live in ``ANY`` memory space) into
  VMEM scratch with ``pltpu.make_async_copy`` — TPU blocks cannot overlap, so
  the CUDA shared-memory halo load becomes an explicit strided DMA.
* All multi-stages of the stencil execute **fused** inside one kernel while
  the tile is VMEM-resident: intermediate stages (temporaries) never touch
  HBM.  This is the GridTools fusion argument restated for the TPU memory
  hierarchy — the memory-roofline win of the backend.
* PARALLEL multi-stages vectorize over the whole (tile_i, tile_j, k) block;
  FORWARD/BACKWARD multi-stages run a ``lax.fori_loop`` over k carrying the
  written planes (thread-per-column on GPUs → plane-per-level on the 8×128
  VPU).
* Outputs are written back through regular non-overlapping BlockSpecs.

Limitations (documented): written API fields may not be read at nonzero
horizontal offsets (allocate a temporary instead); TPU hardware wants
float32/bfloat16 — float64 kernels run under ``interpret=True`` only.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from . import ir
from .codegen_common import (
    ArrayExprPrinter,
    ArrayStmtEmitter,
    Emitter,
    _c,
    bound_expr,
    emit_helpers,
    ms_written_fields,
    multistage_plan,
)
from .gtscript import GTScriptSemanticError


def _reads_of(impl: ir.StencilImplementation) -> Dict[str, List[Tuple[int, int, int]]]:
    reads: Dict[str, List[Tuple[int, int, int]]] = {}
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for stmt in st.stmts:
                    for n, off in ir.stmt_reads(stmt):
                        reads.setdefault(n, []).append(off)
    return reads


def _writes_of(impl: ir.StencilImplementation) -> List[str]:
    out: List[str] = []
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for w in st.writes:
                    if w not in out:
                        out.append(w)
    return out


def generate_pallas_source(
    impl: ir.StencilImplementation,
    block: Tuple[int, int] = (8, 128),
) -> str:
    api_names = {f.name: f for f in impl.api_fields}
    reads = _reads_of(impl)
    writes = _writes_of(impl)
    written_api = [w for w in writes if w in api_names]
    read_api = [f.name for f in impl.api_fields if f.name in reads]
    # API fields that are both read and written need their tile DMA'd in as
    # the initial value of the functional in-kernel array.
    inout_api = [n for n in written_api if n in reads]
    input_api = [n for n in read_api if n not in written_api] + inout_api

    for n in written_api:
        for off in reads.get(n, []):
            if (off[0], off[1]) != (0, 0):
                raise GTScriptSemanticError(
                    f"pallas backend: written API field {n!r} is read at horizontal offset "
                    f"{off}; stage the value through a temporary instead"
                )

    # vertical reads stay in-domain (analysis._check_vertical_bounds) and the
    # DMA always carries the full column, so only the horizontal halo matters.
    H = max(impl.max_halo[0], impl.max_halo[1])

    axes_of = {f.name: f.axes for f in impl.all_fields}
    dtype_of = {f.name: f.dtype for f in impl.all_fields}
    for n in list(api_names) :
        if axes_of[n] not in (("I", "J", "K"), ("I", "J"), ("K",)):
            raise GTScriptSemanticError(f"pallas backend: unsupported axes {axes_of[n]} for {n!r}")

    printer = ArrayExprPrinter(impl, "jnp", axes_of, dtype_of)

    # ---------------- kernel body ----------------
    kb = Emitter()
    kb.push()  # inside def _make_kernel
    kb.push()  # inside def _kernel
    kb.line("ni, nj = _BI, _BJ")
    kb.line("nk = _NK")
    kb.line("gi = pl.program_id(0)")
    kb.line("gj = pl.program_id(1)")
    # DMA input tiles (tile + halo) HBM→VMEM
    for n in input_api:
        axes = axes_of[n]
        if axes == ("K",):
            continue  # K fields arrive whole in VMEM
        if axes == ("I", "J"):
            src = f"{n}_hbm.at[pl.ds(gi * _BI, _BI + 2 * _H), pl.ds(gj * _BJ, _BJ + 2 * _H)]"
        else:
            src = f"{n}_hbm.at[pl.ds(gi * _BI, _BI + 2 * _H), pl.ds(gj * _BJ, _BJ + 2 * _H), :]"
        kb.line(f"_cp_{n} = pltpu.make_async_copy({src}, _s_{n}, _dma_sem)")
        kb.line(f"_cp_{n}.start()")
    for n in input_api:
        if axes_of[n] == ("K",):
            continue
        kb.line(f"_cp_{n}.wait()")
    # bind in-kernel arrays + origins
    for n in read_api + written_api:
        axes = axes_of[n]
        if n in written_api:
            if axes == ("I", "J", "K"):
                shape, origin = "(ni, nj, nk)", (0, 0, 0)
            elif axes == ("I", "J"):
                shape, origin = "(ni, nj)", (0, 0, 0)
            else:
                shape, origin = "(nk,)", (0, 0, 0)
            if n in inout_api:
                if axes == ("I", "J", "K"):
                    kb.line(f"{n} = _s_{n}[_H:_H + ni, _H:_H + nj, :]")
                elif axes == ("I", "J"):
                    kb.line(f"{n} = _s_{n}[_H:_H + ni, _H:_H + nj]")
                else:
                    kb.line(f"{n} = {n}_vmem[...]")
            else:
                kb.line(f"{n} = jnp.zeros({shape}, dtype='{dtype_of[n]}')")
            kb.line(f"_oi_{n}, _oj_{n}, _ok_{n} = {origin}")
        else:
            axes = axes_of[n]
            if axes == ("K",):
                kb.line(f"{n} = {n}_vmem[...]")
                kb.line(f"_oi_{n}, _oj_{n}, _ok_{n} = (0, 0, 0)")
            else:
                kb.line(f"{n} = _s_{n}[...]")
                kb.line(f"_oi_{n}, _oj_{n}, _ok_{n} = (_H, _H, 0)")
    for s in impl.scalars:
        kb.line(f"{s.name} = {s.name}_smem[0]")
    # temporaries (in-tile, VMEM-resident — the fusion payoff)
    for t in impl.temporaries:
        ext = impl.extent_of(t.name)
        (ilo, ihi), (jlo, jhi), (klo, khi) = ext.as_tuple()
        axes = axes_of[t.name]
        if axes == ("I", "J", "K"):
            shape = f"(ni{_c(ihi - ilo)}, nj{_c(jhi - jlo)}, nk{_c(khi - klo)})"
            origin = (-ilo, -jlo, -klo)
        elif axes == ("I", "J"):
            shape = f"(ni{_c(ihi - ilo)}, nj{_c(jhi - jlo)})"
            origin = (-ilo, -jlo, 0)
        else:
            shape = f"(nk{_c(khi - klo)},)"
            origin = (0, 0, -klo)
        kb.line(f"{t.name} = jnp.zeros({shape}, dtype='{t.dtype}')")
        kb.line(f"_oi_{t.name}, _oj_{t.name}, _ok_{t.name} = {origin}")

    # ----- fused multi-stages
    for mi, ms in enumerate(impl.multi_stages):
        kb.line(f"# === multi-stage {mi}: {multistage_plan(ms)}")
        backward = ms.order == ir.IterationOrder.BACKWARD
        for ii, itv in enumerate(ms.intervals):
            k0, k1 = f"_k0_{mi}_{ii}", f"_k1_{mi}_{ii}"
            kb.line(f"{k0} = {bound_expr(itv.interval.start)}")
            kb.line(f"{k1} = {bound_expr(itv.interval.end)}")
            if ms.order == ir.IterationOrder.PARALLEL:
                printer.mode = "block"
                printer.k0, printer.k1 = k0, k1
                emitter = ArrayStmtEmitter(printer, kb, functional=True)
                for st in itv.stages:
                    printer.extent = st.compute_extent
                    for stmt in st.stmts:
                        emitter.stmt(stmt)
            else:
                printer.mode = "plane"
                # carry every field written anywhere in this multi-stage so
                # intervals of the same sweep chain state consistently
                carried = ms_written_fields(ms, exclude=printer.locals_)
                carry = ", ".join(carried)
                trailing = "," if len(carried) == 1 else ""
                kb.line(f"def _body_{mi}_{ii}(_it, _carry):")
                kb.push()
                kb.line(f"({carry}{trailing}) = _carry")
                kb.line(f"k = {k1} - 1 - _it" if backward else f"k = {k0} + _it")
                emitter = ArrayStmtEmitter(printer, kb, functional=True)
                for st in itv.stages:
                    printer.extent = st.compute_extent
                    for stmt in st.stmts:
                        emitter.stmt(stmt)
                kb.line(f"return ({carry}{trailing})")
                kb.pop()
                kb.line(
                    f"({carry}{trailing}) = lax.fori_loop(0, {k1} - {k0}, _body_{mi}_{ii}, "
                    f"({carry}{trailing}))"
                )

    for n in written_api:
        kb.line(f"{n}_out_ref[...] = {n}")

    # ---------------- module assembly ----------------
    em = Emitter()
    em.line(f'"""Auto-generated by repro.core — stencil {impl.name!r}, backend \'pallas\'."""')
    em.line("import functools")
    em.line("import numpy as np")
    em.line("import jax")
    em.line("import jax.numpy as jnp")
    em.line("from jax import lax")
    em.line("from jax.experimental import pallas as pl")
    em.line("from jax.experimental.pallas import tpu as pltpu")
    emit_helpers(em, printer.used_helpers, "jnp")
    em.line()
    em.line("INTERPRET = jax.devices()[0].platform != 'tpu'")
    em.line(f"_H = {H}")
    em.line(f"_BLOCK_DEFAULT = {tuple(block)!r}")
    em.line(f"_SCALARS = {[s.name for s in impl.scalars]!r}")
    em.line(f"_INPUT_API = {input_api!r}")
    em.line(f"_WRITTEN_API = {written_api!r}")
    em.line(f"_K_FIELDS = {[n for n in read_api if axes_of[n] == ('K',)]!r}")
    em.line(f"_AXES = {dict(sorted((n, axes_of[n]) for n in api_names))!r}")
    em.line(f"_DTYPES = {dict(sorted((n, dtype_of[n]) for n in api_names))!r}")
    em.line()
    em.line("def _make_kernel(_BI, _BJ, _NK):")
    em.push()
    em.line("def _kernel(" + ", ".join(
        [f"{s.name}_smem" for s in impl.scalars]
        + [f"{n}_vmem" if axes_of[n] == ("K",) else f"{n}_hbm" for n in input_api]
        + [f"{n}_out_ref" for n in written_api]
        + [f"_s_{n}" for n in input_api if axes_of[n] != ("K",)]
        + ["_dma_sem"]
    ) + "):")
    em.pop()
    source = em.source() + kb.source()

    tail = Emitter()
    tail.push()
    tail.line("return _kernel")
    tail.pop()
    tail.line()
    tail.line("@functools.lru_cache(maxsize=None)")
    tail.line("def _build(domain, block):")
    tail.push()
    tail.line("ni, nj, nk = domain")
    tail.line("bi = min(block[0], ni)")
    tail.line("bj = min(block[1], nj)")
    tail.line("nti = -(-ni // bi)")
    tail.line("ntj = -(-nj // bj)")
    tail.line("kernel = _make_kernel(bi, bj, nk)")
    tail.line("in_specs = []")
    tail.line("for s in _SCALARS:")
    tail.push()
    tail.line("in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))")
    tail.pop()
    tail.line("for n in _INPUT_API:")
    tail.push()
    tail.line("if n in _K_FIELDS:")
    tail.push()
    tail.line("in_specs.append(pl.BlockSpec(memory_space=pltpu.VMEM))")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("in_specs.append(pl.BlockSpec(memory_space=pl.ANY))")
    tail.pop()
    tail.pop()
    tail.line("out_specs = []")
    tail.line("out_shapes = []")
    tail.line("for n in _WRITTEN_API:")
    tail.push()
    tail.line("if _AXES[n] == ('I', 'J', 'K'):")
    tail.push()
    tail.line("out_specs.append(pl.BlockSpec((bi, bj, nk), lambda i, j: (i, j, 0)))")
    tail.line("out_shapes.append(jax.ShapeDtypeStruct((nti * bi, ntj * bj, nk), _DTYPES[n]))")
    tail.pop()
    tail.line("elif _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("out_specs.append(pl.BlockSpec((bi, bj), lambda i, j: (i, j)))")
    tail.line("out_shapes.append(jax.ShapeDtypeStruct((nti * bi, ntj * bj), _DTYPES[n]))")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("raise NotImplementedError('K-field outputs in pallas backend')")
    tail.pop()
    tail.pop()
    tail.line("scratch = []")
    tail.line("for n in _INPUT_API:")
    tail.push()
    tail.line("if n in _K_FIELDS:")
    tail.push()
    tail.line("continue")
    tail.pop()
    tail.line("if _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("scratch.append(pltpu.VMEM((bi + 2 * _H, bj + 2 * _H), _DTYPES[n]))")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("scratch.append(pltpu.VMEM((bi + 2 * _H, bj + 2 * _H, nk), _DTYPES[n]))")
    tail.pop()
    tail.pop()
    tail.line("scratch.append(pltpu.SemaphoreType.DMA)")
    tail.line("call = pl.pallas_call(kernel, grid=(nti, ntj), in_specs=in_specs, out_specs=out_specs,")
    tail.line("                      out_shape=out_shapes, scratch_shapes=scratch, interpret=INTERPRET)")
    tail.line("return jax.jit(call), (bi, bj, nti, ntj)")
    tail.pop()
    tail.line()
    tail.line("def run(fields, scalars, domain, origins, block=None):")
    tail.push()
    tail.line("ni, nj, nk = domain")
    tail.line("call, (bi, bj, nti, ntj) = _build(tuple(domain), tuple(block or _BLOCK_DEFAULT))")
    tail.line("args = []")
    tail.line("for s in _SCALARS:")
    tail.push()
    tail.line("args.append(jnp.asarray([scalars[s]], dtype=_DTYPES[_WRITTEN_API[0]]))")
    tail.pop()
    tail.line("pad_i = nti * bi - ni")
    tail.line("pad_j = ntj * bj - nj")
    tail.line("for n in _INPUT_API:")
    tail.push()
    tail.line("arr = fields[n]")
    tail.line("oi, oj, ok = origins[n]")
    tail.line("if n in _K_FIELDS:")
    tail.push()
    tail.line("args.append(jax.lax.dynamic_slice(arr, (ok,), (nk,)))")
    tail.line("continue")
    tail.pop()
    tail.line("if _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("region = arr[oi - _H:oi + ni + _H, oj - _H:oj + nj + _H]")
    tail.line("region = jnp.pad(region, ((0, pad_i), (0, pad_j)), mode='edge')")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("region = arr[oi - _H:oi + ni + _H, oj - _H:oj + nj + _H, ok:ok + nk]")
    tail.line("region = jnp.pad(region, ((0, pad_i), (0, pad_j), (0, 0)), mode='edge')")
    tail.pop()
    tail.line("args.append(region)")
    tail.pop()
    tail.line("outs = call(*args)")
    tail.line("updates = {}")
    tail.line("for n, new in zip(_WRITTEN_API, outs):")
    tail.push()
    tail.line("arr = fields[n]")
    tail.line("oi, oj, ok = origins[n]")
    tail.line("if _AXES[n] == ('I', 'J'):")
    tail.push()
    tail.line("updates[n] = arr.at[oi:oi + ni, oj:oj + nj].set(new[:ni, :nj])")
    tail.pop()
    tail.line("else:")
    tail.push()
    tail.line("updates[n] = arr.at[oi:oi + ni, oj:oj + nj, ok:ok + nk].set(new[:ni, :nj, :])")
    tail.pop()
    tail.pop()
    tail.line("return updates")
    tail.pop()

    return source + tail.source()
