"""Shared source-generation machinery for the array backends (numpy / jax).

Backends generate *actual Python source* (inspectable via
``StencilObject.generated_source``, cached on disk by ``caching.py``), in the
spirit of the paper's code-generating toolchain.

Conventions of generated ``run()`` functions
--------------------------------------------
* ``fields``  : dict name → array, full storage *including halo*
* ``scalars`` : dict name → python/np scalar
* ``domain``  : (ni, nj, nk) compute-domain size (python ints → static)
* ``origins`` : dict name → (oi, oj, ok) offset of the compute-domain origin
  inside each field's storage

Field reads at relative offset (di, dj, dk) from a stage with compute extent
((ilo, ihi), (jlo, jhi)) over vertical interval [k0, k1) become slices::

    arr[o_i + ilo + di : o_i + ni + ihi + di,
        o_j + jlo + dj : o_j + nj + jhi + dj,
        o_k + k0 + dk  : o_k + k1 + dk]          # PARALLEL (3D block)

or, in sequential (FORWARD/BACKWARD) multi-stages, 2D planes at a loop-
carried level ``k``.  Temporaries are allocated inside ``run`` extended by
their required extents, with origins shifted accordingly.
"""

from __future__ import annotations

import io
from typing import Dict, Optional, Tuple

from . import ir


def _c(off: int) -> str:
    """Format '+ n' / '- n' / '' for a constant offset inside a slice."""
    if off == 0:
        return ""
    return f" + {off}" if off > 0 else f" - {-off}"


def bound_expr(b: ir.AxisBound) -> str:
    if b.level == ir.LevelMarker.START:
        return str(b.offset)
    return f"nk{_c(b.offset)}" if b.offset else "nk"


class Emitter:
    def __init__(self) -> None:
        self._buf = io.StringIO()
        self._indent = 0

    def line(self, s: str = "") -> None:
        self._buf.write(("    " * self._indent) + s + "\n" if s else "\n")

    def push(self) -> None:
        self._indent += 1

    def pop(self) -> None:
        self._indent -= 1

    def source(self) -> str:
        return self._buf.getvalue()


class ArrayExprPrinter:
    """Prints ir.Expr as vectorized numpy/jnp source.

    ``mode`` is "block" (PARALLEL: 3D region over [k0, k1)) or "plane"
    (sequential: 2D region at level variable ``k``).
    """

    def __init__(
        self,
        impl: ir.StencilImplementation,
        lib: str,  # 'np' | 'jnp'
        axes_of: Dict[str, Tuple[str, ...]],
        dtype_of: Dict[str, str],
    ):
        self.impl = impl
        self.lib = lib
        self.axes_of = axes_of
        self.dtype_of = dtype_of
        self.mode = "block"
        self.extent: ir.Extent = ir.Extent.zero()
        self.k0 = "_k0"
        self.k1 = "_k1"
        # horizontal sub-ranges of the compute domain: ("0", "ni") covers the
        # whole domain (the default); the numpy stage-tiling emitter rebinds
        # these to the current tile's bounds ("_t0", "_t1") so every slice is
        # evaluated tile-by-tile.
        self.irange: Tuple[str, str] = ("0", "ni")
        self.jrange: Tuple[str, str] = ("0", "nj")
        self.used_helpers: set = set()
        # demoted temporaries (ir.StencilImplementation.local_decls): bound as
        # plain block/plane variables — reads are the bare name (the demotion
        # pass guarantees zero offsets and shape-identical stage extents).
        self.locals_: set = {f.name for f in impl.local_decls}
        # k-blocked sweep temporaries (analysis.SweepCarryPlan.window): in
        # plane mode, dk=0 reads hit the current plane ``_wp_<name>`` and
        # trailing reads hit the rolling history ``_wh_<name>_<q>`` instead of
        # a full 3-D array.  Bound by emit_sweep for the active multi-stage.
        self.window: Dict[str, int] = {}

    # -- region slices ---------------------------------------------------------

    @staticmethod
    def _hbound(origin: str, bound: str, off: int) -> str:
        if bound == "0":
            return f"{origin}{_c(off)}"
        return f"{origin} + {bound}{_c(off)}"

    def _hslices(self, name: str, di: int, dj: int) -> Tuple[str, str]:
        (ilo, ihi), (jlo, jhi), _ = self.extent.as_tuple()
        i0, i1 = self.irange
        j0, j1 = self.jrange
        si = (
            f"{self._hbound(f'_oi_{name}', i0, ilo + di)}"
            f":{self._hbound(f'_oi_{name}', i1, ihi + di)}"
        )
        sj = (
            f"{self._hbound(f'_oj_{name}', j0, jlo + dj)}"
            f":{self._hbound(f'_oj_{name}', j1, jhi + dj)}"
        )
        return si, sj

    def _kslice(self, name: str, dk: int) -> str:
        if self.mode == "block":
            return f"_ok_{name} + {self.k0}{_c(dk)}:_ok_{name} + {self.k1}{_c(dk)}"
        return f"_ok_{name} + k{_c(dk)}"

    def read(self, fa: ir.FieldAccess) -> str:
        name = fa.name
        if name in self.locals_:
            return name
        di, dj, dk = fa.offset
        if self.mode == "plane" and name in self.window:
            si, sj = self._hslices(name, di, dj)
            if dk == 0:
                return f"_wp_{name}[{si}, {sj}]"
            return f"_wh_{name}_{abs(dk)}[{si}, {sj}]"
        axes = self.axes_of[name]
        if axes == ("I", "J", "K"):
            si, sj = self._hslices(name, di, dj)
            return f"{name}[{si}, {sj}, {self._kslice(name, dk)}]"
        if axes == ("I", "J"):
            si, sj = self._hslices(name, di, dj)
            if self.mode == "block":
                return f"{name}[{si}, {sj}, None]"
            return f"{name}[{si}, {sj}]"
        if axes == ("K",):
            if self.mode == "block":
                return f"{name}[None, None, {self._kslice(name, dk)}]"
            return f"{name}[{self._kslice(name, dk)}]"
        raise NotImplementedError(f"axes {axes}")

    def write_target(self, name: str) -> str:
        axes = self.axes_of[name]
        if axes == ("I", "J", "K"):
            si, sj = self._hslices(name, 0, 0)
            return f"{name}[{si}, {sj}, {self._kslice(name, 0)}]"
        if axes == ("I", "J"):
            si, sj = self._hslices(name, 0, 0)
            return f"{name}[{si}, {sj}]"
        if axes == ("K",):
            return f"{name}[{self._kslice(name, 0)}]"
        raise NotImplementedError(f"axes {axes}")

    def write_starts_shape(self, name: str) -> Tuple[str, str]:
        """(start-indices tuple expr, region shape tuple expr) for functional
        writes via lax.dynamic_update_slice (Pallas kernels may not capture
        the scatter constants `.at[].set()` would create)."""
        axes = self.axes_of[name]
        (ilo, ihi), (jlo, jhi), _ = self.extent.as_tuple()
        si = f"_oi_{name}{_c(ilo)}"
        sj = f"_oj_{name}{_c(jlo)}"
        di = f"ni{_c(ihi - ilo)}"
        dj = f"nj{_c(jhi - jlo)}"
        if self.mode == "block":
            sk = f"_ok_{name} + {self.k0}"
            dk = f"{self.k1} - {self.k0}"
        else:
            sk = f"_ok_{name} + k"
            dk = "1"
        if axes == ("I", "J", "K"):
            return f"({si}, {sj}, {sk})", f"({di}, {dj}, {dk})"
        if axes == ("I", "J"):
            return f"({si}, {sj})", f"({di}, {dj})"
        if axes == ("K",):
            return f"({sk},)", f"({dk},)"
        raise NotImplementedError(f"axes {axes}")

    def plane_write_starts_shape(self, name: str) -> Tuple[str, str]:
        """2-D (starts, shape) for writing a windowed temporary's current
        plane ``_wp_<name>`` in a sequential sweep."""
        (ilo, ihi), (jlo, jhi), _ = self.extent.as_tuple()
        si = f"_oi_{name}{_c(ilo)}"
        sj = f"_oj_{name}{_c(jlo)}"
        di = f"ni{_c(ihi - ilo)}"
        dj = f"nj{_c(jhi - jlo)}"
        return f"({si}, {sj})", f"({di}, {dj})"

    # -- expressions -----------------------------------------------------------

    def expr(self, e: ir.Expr) -> str:
        lib = self.lib
        if isinstance(e, ir.Literal):
            if e.dtype == "bool":
                return "True" if e.value else "False"
            return repr(e.value)
        if isinstance(e, ir.ScalarRef):
            return e.name
        if isinstance(e, ir.FieldAccess):
            return self.read(e)
        if isinstance(e, ir.UnaryOp):
            if e.op == "not":
                return f"{lib}.logical_not({self.expr(e.operand)})"
            return f"({e.op}{self.expr(e.operand)})"
        if isinstance(e, ir.BinOp):
            if e.op == "and":
                return f"{lib}.logical_and({self.expr(e.left)}, {self.expr(e.right)})"
            if e.op == "or":
                return f"{lib}.logical_or({self.expr(e.left)}, {self.expr(e.right)})"
            return f"({self.expr(e.left)} {e.op} {self.expr(e.right)})"
        if isinstance(e, ir.TernaryOp):
            return f"{lib}.where({self.expr(e.cond)}, {self.expr(e.true_expr)}, {self.expr(e.false_expr)})"
        if isinstance(e, ir.NativeCall):
            return self._native(e)
        if isinstance(e, ir.Cast):
            self.used_helpers.add("cast")
            return f"_cast({self.expr(e.expr)}, '{e.dtype}')"
        raise NotImplementedError(f"expr {type(e)}")

    def _native(self, e: ir.NativeCall) -> str:
        lib = self.lib
        args = ", ".join(self.expr(a) for a in e.args)
        fn = e.func
        if fn == "min":
            return f"{lib}.minimum({args})"
        if fn == "max":
            return f"{lib}.maximum({args})"
        if fn == "abs":
            return f"{lib}.abs({args})"
        if fn == "mod":
            return f"{lib}.mod({args})"
        if fn == "pow":
            return f"{lib}.power({args})"
        if fn == "sigmoid":
            self.used_helpers.add("sigmoid")
            return f"_sigmoid({args})"
        if fn in ("erf", "erfc"):
            self.used_helpers.add(fn)
            return f"_{fn}({args})"
        if fn == "gamma":
            self.used_helpers.add("gamma")
            return f"_gamma({args})"
        return f"{lib}.{fn}({args})"


class ArrayStmtEmitter:
    """Emits statements for one (multi-stage, interval) context."""

    def __init__(self, printer: ArrayExprPrinter, em: Emitter, functional: bool):
        self.p = printer
        self.em = em
        # functional=True (jax): writes rebind names via .at[].set();
        # functional=False (numpy): writes mutate slices in place.
        self.functional = functional
        self._mask_counter = 0

    def assign(self, stmt: ir.Assign, mask: Optional[str]) -> None:
        p = self.p
        name = stmt.target.name
        value = p.expr(stmt.value)
        if mask is not None:
            old = p.read(ir.FieldAccess(name, (0, 0, 0)))
            value = f"{p.lib}.where({mask}, {value}, {old})"
        if name in p.locals_:
            # demoted temporary: direct variable binding, no field write
            self.em.line(f"{name} = {value}")
        elif p.mode == "plane" and name in p.window:
            # k-blocked sweep temporary: write the current 2-D plane
            p.used_helpers.add("dus")
            starts, shape = p.plane_write_starts_shape(name)
            self.em.line(f"_wp_{name} = _dus(_wp_{name}, {value}, {starts}, {shape})")
        elif self.functional:
            p.used_helpers.add("dus")
            starts, shape = p.write_starts_shape(name)
            self.em.line(f"{name} = _dus({name}, {value}, {starts}, {shape})")
        else:
            tgt = p.write_target(name)
            self.em.line(f"{tgt} = {value}")

    def if_stmt(self, stmt: ir.If, mask: Optional[str]) -> None:
        p = self.p
        self._mask_counter += 1
        mv = f"_mask_{self._mask_counter}"
        cond = p.expr(stmt.cond)
        self.em.line(f"{mv} = {cond}")
        then_mask = mv if mask is None else f"{p.lib}.logical_and({mask}, {mv})"
        if mask is not None:
            then_v = f"_mask_{self._mask_counter}_t"
            self.em.line(f"{then_v} = {then_mask}")
            then_mask = then_v
        for s in stmt.body:
            self.stmt(s, then_mask)
        if stmt.orelse:
            else_mask = f"{p.lib}.logical_not({mv})"
            if mask is not None:
                else_mask = f"{p.lib}.logical_and({mask}, {else_mask})"
            else_v = f"_mask_{self._mask_counter}_e"
            self.em.line(f"{else_v} = {else_mask}")
            for s in stmt.orelse:
                self.stmt(s, else_v)

    def stmt(self, stmt: ir.Stmt, mask: Optional[str] = None) -> None:
        if isinstance(stmt, ir.Assign):
            self.assign(stmt, mask)
        elif isinstance(stmt, ir.If):
            self.if_stmt(stmt, mask)
        else:
            raise NotImplementedError(type(stmt))


# ---------------------------------------------------------------------------
# Shared preamble / allocation helpers
# ---------------------------------------------------------------------------


def temp_alloc_shape(impl: ir.StencilImplementation, name: str) -> Tuple[str, Tuple[int, int, int]]:
    """Returns (shape_expr, origin) for a temporary field."""
    ext = impl.extent_of(name)
    (ilo, ihi), (jlo, jhi), (klo, khi) = ext.as_tuple()
    axes = impl.field(name).axes
    oi, oj, ok = -ilo, -jlo, -klo
    if axes == ("I", "J", "K"):
        shape = f"(ni{_c(ihi - ilo)}, nj{_c(jhi - jlo)}, nk{_c(khi - klo)})"
        return shape, (oi, oj, ok)
    if axes == ("I", "J"):
        shape = f"(ni{_c(ihi - ilo)}, nj{_c(jhi - jlo)})"
        return shape, (oi, oj, 0)
    if axes == ("K",):
        shape = f"(nk{_c(khi - klo)},)"
        return shape, (0, 0, ok)
    raise NotImplementedError(axes)


def emit_helpers(em: Emitter, used: set, lib: str) -> None:
    if "dus" in used:
        em.line("def _dus(arr, val, starts, shape):")
        em.push()
        em.line("val = jnp.asarray(val, dtype=arr.dtype)")
        em.line("if val.ndim == len(shape) - 1:")
        em.push()
        em.line("val = val[..., None]")
        em.pop()
        em.line("val = jnp.broadcast_to(val, shape)")
        em.line("return lax.dynamic_update_slice(arr, val, starts)")
        em.pop()
    if "cast" in used:
        em.line("def _cast(x, dt):")
        em.push()
        em.line(f"return {lib}.asarray(x).astype(dt)")
        em.pop()
    if "sigmoid" in used:
        em.line("def _sigmoid(x):")
        em.push()
        em.line(f"return 1.0 / (1.0 + {lib}.exp(-x))")
        em.pop()
    if "erf" in used or "erfc" in used:
        if lib == "np":
            em.line("import math as _math")
            em.line("_erf = _np_vectorize_erf = __import__('numpy').vectorize(_math.erf)")
            em.line("def _erfc(x):")
            em.push()
            em.line("return 1.0 - _erf(x)")
            em.pop()
        else:
            em.line("from jax.scipy.special import erf as _erf")
            em.line("def _erfc(x):")
            em.push()
            em.line("return 1.0 - _erf(x)")
            em.pop()


def emit_parallel_block(
    impl: ir.StencilImplementation,
    printer: ArrayExprPrinter,
    em: Emitter,
    ms: ir.MultiStage,
    mi: int,
    functional: bool,
) -> None:
    """Emit a PARALLEL multi-stage: every statement fully vectorized over its
    3-D region, interval by interval (shared by numpy / jax / pallas)."""
    for ii, itv in enumerate(ms.intervals):
        k0, k1 = f"_k0_{mi}_{ii}", f"_k1_{mi}_{ii}"
        em.line(f"{k0} = {bound_expr(itv.interval.start)}")
        em.line(f"{k1} = {bound_expr(itv.interval.end)}")
        printer.mode = "block"
        printer.k0, printer.k1 = k0, k1
        emitter = ArrayStmtEmitter(printer, em, functional)
        for st in itv.stages:
            printer.extent = st.compute_extent
            for stmt in st.stmts:
                emitter.stmt(stmt)


def emit_sweep(
    impl: ir.StencilImplementation,
    printer: ArrayExprPrinter,
    em: Emitter,
    ms: ir.MultiStage,
    mi: int,
    plan,  # analysis.SweepCarryPlan
    lib: str,
) -> None:
    """Emit a FORWARD/BACKWARD multi-stage as ``lax.fori_loop``s carrying only
    the liveness-proven state (shared by the jax and pallas backends).

    Full fields are carried as whole arrays, exactly as before.  Window
    fields carry ``depth`` rolling 2-D history planes (``_wh_<name>_<q>`` is
    the plane ``q`` iterations behind the sweep) plus a per-iteration current
    plane ``_wp_<name>`` — the k-blocking that keeps a sweep's VMEM live set
    bounded by its true vertical dependency depth instead of nk.

    The history planes thread through *every* interval of the multi-stage so
    state chains across interval boundaries; planes the sweep never wrote
    read as zeros, matching the zero-initialized 3-D temporary they replace.
    """
    backward = ms.order == ir.IterationOrder.BACKWARD

    def plane_shape(name: str) -> str:
        (ilo, ihi), (jlo, jhi), _ = impl.extent_of(name).as_tuple()
        return f"(ni{_c(ihi - ilo)}, nj{_c(jhi - jlo)})"

    for name, depth in plan.window:
        (ilo, ihi), (jlo, jhi), _ = impl.extent_of(name).as_tuple()
        em.line(f"_oi_{name}, _oj_{name}, _ok_{name} = ({-ilo}, {-jlo}, 0)")
        dt = impl.field(name).dtype
        for q in range(1, depth + 1):
            em.line(f"_wh_{name}_{q} = {lib}.zeros({plane_shape(name)}, dtype='{dt}')")
    printer.window = dict(plan.window)
    carried = list(plan.full) + [
        f"_wh_{n}_{q}" for n, d in plan.window for q in range(1, d + 1)
    ]
    carry = ", ".join(carried)
    trailing = "," if len(carried) == 1 else ""

    for ii, itv in enumerate(ms.intervals):
        k0, k1 = f"_k0_{mi}_{ii}", f"_k1_{mi}_{ii}"
        em.line(f"{k0} = {bound_expr(itv.interval.start)}")
        em.line(f"{k1} = {bound_expr(itv.interval.end)}")
        printer.mode = "plane"
        em.line(f"def _body_{mi}_{ii}(_it, _carry):")
        em.push()
        if carried:
            em.line(f"({carry}{trailing}) = _carry")
        em.line(f"k = {k1} - 1 - _it" if backward else f"k = {k0} + _it")
        for name, _depth in plan.window:
            dt = impl.field(name).dtype
            em.line(f"_wp_{name} = {lib}.zeros({plane_shape(name)}, dtype='{dt}')")
        emitter = ArrayStmtEmitter(printer, em, functional=True)
        for st in itv.stages:
            printer.extent = st.compute_extent
            for stmt in st.stmts:
                emitter.stmt(stmt)
        for name, depth in plan.window:
            for q in range(depth, 1, -1):
                em.line(f"_wh_{name}_{q} = _wh_{name}_{q - 1}")
            if depth >= 1:
                em.line(f"_wh_{name}_1 = _wp_{name}")
        em.line(f"return ({carry}{trailing})" if carried else "return ()")
        em.pop()
        loop = f"lax.fori_loop(0, {k1} - {k0}, _body_{mi}_{ii}, ({carry}{trailing}))"
        em.line(f"({carry}{trailing}) = {loop}" if carried else loop)
    printer.window = {}


def multistage_plan(ms: ir.MultiStage) -> str:
    """Human-readable schedule line for the generated source header."""
    parts = []
    for itv in ms.intervals:
        parts.append(
            f"[{bound_expr(itv.interval.start)}, {bound_expr(itv.interval.end)}) × {len(itv.stages)} stages"
        )
    return f"{ms.order.name}: " + "; ".join(parts)
