"""GTScript frontend: parse a definition function into the Definition IR.

Per the paper (§2.1–2.2): GTScript is a *strict subset* of Python syntax, so
the stock ``ast`` module is the lexer/parser; semantics differ from Python
(offsets are relative to the evaluation point, iteration is implicit,
assignments are whole-domain).  ``@gtscript.function`` calls are inlined here
with additive offset composition.
"""

from __future__ import annotations

import ast
import collections
import inspect
import numbers
import textwrap
import weakref
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from . import ir
from .gtscript import (
    GTScriptFunction,
    GTScriptSemanticError,
    GTScriptSyntaxError,
    _FieldType,
)

_BINOPS = {
    ast.Add: "+",
    ast.Sub: "-",
    ast.Mult: "*",
    ast.Div: "/",
    ast.FloorDiv: "//",
    ast.Mod: "%",
    ast.Pow: "**",
}

_CMPOPS = {
    ast.Lt: "<",
    ast.Gt: ">",
    ast.LtE: "<=",
    ast.GtE: ">=",
    ast.Eq: "==",
    ast.NotEq: "!=",
}

_BOOLOPS = {ast.And: "and", ast.Or: "or"}

_UNARYOPS = {ast.USub: "-", ast.UAdd: "+", ast.Not: "not"}

_ORDERS = {
    "PARALLEL": ir.IterationOrder.PARALLEL,
    "FORWARD": ir.IterationOrder.FORWARD,
    "BACKWARD": ir.IterationOrder.BACKWARD,
}

# names that collide with generated-code locals
_RESERVED_NAMES = {
    "ni", "nj", "nk", "k", "i", "j", "domain", "fields", "scalars", "origins",
    "np", "jnp", "jax", "lax", "pl", "pltpu", "math", "run",
    "True", "False", "None",
}


def _check_symbol_name(name: str, kind: str, stencil: str) -> None:
    if name in _RESERVED_NAMES or name.startswith("_"):
        raise GTScriptSyntaxError(
            f"stencil {stencil}: {kind} name {name!r} is reserved (generated-code local)"
        )

# aliases accepted in GTScript source for native math calls
_NATIVE_ALIASES = {
    "fabs": "abs",
    "fmin": "min",
    "fmax": "max",
    "asin": "arcsin",
    "acos": "arccos",
    "atan": "arctan",
}


def _dtype_name(dtype: Any) -> str:
    return np.dtype(dtype).name


def _function_namespace(func) -> Mapping[str, Any]:
    """Module globals + closure cells of ``func`` (so gtscript.functions and
    constants defined in enclosing local scopes resolve, e.g. in tests).

    Returns a *live view* over the module dict rather than a copy: a snapshot
    would strongly capture every module global — including the parsed
    function object itself, which keeps ``_function_cache``'s weak keys alive
    forever (value → key cycle)."""
    closure = getattr(func, "__closure__", None)
    extras: Dict[str, Any] = {}
    if closure:
        for name, cell in zip(func.__code__.co_freevars, closure):
            try:
                extras[name] = cell.cell_contents
            except ValueError:  # unfilled cell
                pass
    if not extras:
        return func.__globals__
    return collections.ChainMap(extras, func.__globals__)


def _syntax_error(node: ast.AST, msg: str, source_name: str = "<stencil>") -> GTScriptSyntaxError:
    err = GTScriptSyntaxError(f"{msg} (line {getattr(node, 'lineno', '?')} of {source_name})")
    return err


# ---------------------------------------------------------------------------
# Parsed @gtscript.function representation
# ---------------------------------------------------------------------------


@dataclass
class ParsedFunction:
    name: str
    params: List[str]
    body: List[Tuple[str, ast.expr]]  # sequential local assignments (name, rhs AST)
    returns: List[ast.expr]  # one or more return expressions (AST)
    globals: Mapping[str, Any]
    source_name: str


# keyed weakly by the function object itself (identity hash): an id()-keyed
# cache collides when the interpreter reuses the address of a collected
# function, and a strong-ref dict would pin every parsed function forever
_function_cache = weakref.WeakKeyDictionary()


def parse_gts_function(func: GTScriptFunction) -> ParsedFunction:
    key = func
    if key in _function_cache:
        return _function_cache[key]
    tree = ast.parse(func.source)
    fdef = tree.body[0]
    if not isinstance(fdef, ast.FunctionDef):
        raise GTScriptSyntaxError(f"cannot parse gtscript.function {func.__name__}")
    params = [a.arg for a in fdef.args.args] + [a.arg for a in fdef.args.kwonlyargs]
    body: List[Tuple[str, ast.expr]] = []
    returns: Optional[List[ast.expr]] = None
    for stmt in fdef.body:
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant) and isinstance(stmt.value.value, str):
            continue  # docstring
        if isinstance(stmt, ast.Assign):
            if len(stmt.targets) != 1:
                raise _syntax_error(stmt, "chained assignment not supported in gtscript.function")
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                body.append((tgt.id, stmt.value))
            elif isinstance(tgt, ast.Tuple) and all(isinstance(e, ast.Name) for e in tgt.elts):
                if not isinstance(stmt.value, ast.Tuple) or len(stmt.value.elts) != len(tgt.elts):
                    raise _syntax_error(stmt, "tuple assignment in functions requires a literal tuple rhs")
                for t, v in zip(tgt.elts, stmt.value.elts):
                    body.append((t.id, v))
            else:
                raise _syntax_error(stmt, "unsupported assignment target in gtscript.function")
        elif isinstance(stmt, ast.Return):
            if stmt.value is None:
                raise _syntax_error(stmt, "gtscript.function must return a value")
            if isinstance(stmt.value, ast.Tuple):
                returns = list(stmt.value.elts)
            else:
                returns = [stmt.value]
            break
        else:
            raise _syntax_error(stmt, f"statement {type(stmt).__name__} not allowed in gtscript.function")
    if returns is None:
        raise GTScriptSyntaxError(f"gtscript.function {func.__name__} has no return statement")
    parsed = ParsedFunction(
        name=func.__name__,
        params=params,
        body=body,
        returns=returns,
        globals=_function_namespace(func.definition),
        source_name=func.__name__,
    )
    _function_cache[key] = parsed
    return parsed


# ---------------------------------------------------------------------------
# Expression parsing
# ---------------------------------------------------------------------------


class ExprParser:
    """Parses a Python ``ast.expr`` into an ``ir.Expr`` within a symbol context.

    ``env`` maps names to IR expressions (function params / locals during
    inlining).  Field/scalar/external resolution falls back to the stencil
    context when a name is not in ``env``.
    """

    def __init__(self, ctx: "StencilContext", env: Optional[Dict[str, ir.Expr]] = None,
                 globals_ns: Optional[Dict[str, Any]] = None, source_name: str = "<stencil>"):
        self.ctx = ctx
        self.env = env if env is not None else {}
        self.globals_ns = globals_ns if globals_ns is not None else ctx.globals_ns
        self.source_name = source_name

    # -- helpers ------------------------------------------------------------

    def _resolve_name(self, node: ast.Name) -> ir.Expr:
        name = node.id
        if name in self.env:
            return self.env[name]
        return self.ctx.resolve_symbol(name, node, self.globals_ns)

    def _const_offset(self, node: ast.expr) -> int:
        try:
            val = ast.literal_eval(node)
        except Exception:
            raise _syntax_error(node, "field offsets must be integer literals", self.source_name)
        if not isinstance(val, int) or isinstance(val, bool):
            raise _syntax_error(node, f"field offset must be an int, got {val!r}", self.source_name)
        return val

    def _parse_offsets(self, node: ast.expr) -> Tuple[int, ...]:
        if isinstance(node, ast.Tuple):
            return tuple(self._const_offset(e) for e in node.elts)
        return (self._const_offset(node),)

    def _subscript(self, base: ir.Expr, offsets: Tuple[int, ...], node: ast.AST) -> ir.Expr:
        """Apply relative offsets to an expression (shifting all its accesses)."""
        if len(offsets) == 1:
            off3 = (0, 0, offsets[0])  # K-field style single offset
        elif len(offsets) == 2:
            off3 = (offsets[0], offsets[1], 0)
        elif len(offsets) == 3:
            off3 = tuple(offsets)  # type: ignore[assignment]
        else:
            raise _syntax_error(node, f"expected 1-3 offsets, got {len(offsets)}", self.source_name)
        if isinstance(base, ir.FieldAccess):
            return ir.FieldAccess(
                base.name,
                (base.offset[0] + off3[0], base.offset[1] + off3[1], base.offset[2] + off3[2]),
            )
        if off3 == (0, 0, 0):
            return base
        return ir.shift_accesses(base, off3)

    # -- main dispatch -------------------------------------------------------

    def parse(self, node: ast.expr) -> ir.Expr:
        m = getattr(self, f"_p_{type(node).__name__}", None)
        if m is None:
            raise _syntax_error(node, f"expression {type(node).__name__} is outside the GTScript subset",
                                self.source_name)
        return m(node)

    def parse_multi(self, node: ast.expr) -> List[ir.Expr]:
        """Parse an expression that may yield a tuple (function call returns)."""
        if isinstance(node, ast.Tuple):
            return [self.parse(e) for e in node.elts]
        if isinstance(node, ast.Call):
            result = self._p_Call(node, allow_multi=True)
            return result if isinstance(result, list) else [result]
        return [self.parse(node)]

    # -- node handlers --------------------------------------------------------

    def _p_Constant(self, node: ast.Constant) -> ir.Expr:
        v = node.value
        if isinstance(v, bool):
            return ir.Literal(v, "bool")
        if isinstance(v, int):
            return ir.Literal(v, "int")
        if isinstance(v, float):
            return ir.Literal(v, "float")
        raise _syntax_error(node, f"constant {v!r} not allowed", self.source_name)

    def _p_Name(self, node: ast.Name) -> ir.Expr:
        return self._resolve_name(node)

    def _p_Subscript(self, node: ast.Subscript) -> ir.Expr:
        if not isinstance(node.value, (ast.Name, ast.Subscript)):
            raise _syntax_error(node, "only names can be subscripted with offsets", self.source_name)
        base = self.parse(node.value)
        offsets = self._parse_offsets(node.slice)
        return self._subscript(base, offsets, node)

    def _p_UnaryOp(self, node: ast.UnaryOp) -> ir.Expr:
        op = _UNARYOPS.get(type(node.op))
        if op is None:
            raise _syntax_error(node, f"unary operator {type(node.op).__name__} not supported", self.source_name)
        operand = self.parse(node.operand)
        if op == "+":
            return operand
        if op == "-" and isinstance(operand, ir.Literal) and operand.dtype in ("int", "float"):
            return ir.Literal(-operand.value, operand.dtype)
        return ir.UnaryOp(op, operand)

    def _p_BinOp(self, node: ast.BinOp) -> ir.Expr:
        op = _BINOPS.get(type(node.op))
        if op is None:
            raise _syntax_error(node, f"operator {type(node.op).__name__} not supported", self.source_name)
        return ir.BinOp(op, self.parse(node.left), self.parse(node.right))

    def _p_Compare(self, node: ast.Compare) -> ir.Expr:
        if len(node.ops) != 1 or len(node.comparators) != 1:
            # a < b < c  → (a < b) and (b < c)
            result: Optional[ir.Expr] = None
            left = node.left
            for op_node, comp in zip(node.ops, node.comparators):
                op = _CMPOPS.get(type(op_node))
                if op is None:
                    raise _syntax_error(node, "comparison operator not supported", self.source_name)
                piece = ir.BinOp(op, self.parse(left), self.parse(comp))
                result = piece if result is None else ir.BinOp("and", result, piece)
                left = comp
            assert result is not None
            return result
        op = _CMPOPS.get(type(node.ops[0]))
        if op is None:
            raise _syntax_error(node, "comparison operator not supported", self.source_name)
        return ir.BinOp(op, self.parse(node.left), self.parse(node.comparators[0]))

    def _p_BoolOp(self, node: ast.BoolOp) -> ir.Expr:
        op = _BOOLOPS[type(node.op)]
        exprs = [self.parse(v) for v in node.values]
        result = exprs[0]
        for e in exprs[1:]:
            result = ir.BinOp(op, result, e)
        return result

    def _p_IfExp(self, node: ast.IfExp) -> ir.Expr:
        return ir.TernaryOp(self.parse(node.test), self.parse(node.body), self.parse(node.orelse))

    def _p_Attribute(self, node: ast.Attribute) -> ir.Expr:
        # allow things like np.pi / math.pi resolved from globals
        try:
            expr_src = ast.unparse(node)
            val = eval(expr_src, {"__builtins__": {}}, self.globals_ns)  # noqa: S307
        except Exception:
            raise _syntax_error(node, f"cannot resolve attribute {ast.unparse(node)!r}", self.source_name)
        if isinstance(val, numbers.Number):
            return _literal_from_value(val)
        raise _syntax_error(node, f"attribute {ast.unparse(node)!r} is not a numeric constant", self.source_name)

    def _p_Call(self, node: ast.Call, allow_multi: bool = False):
        fname = None
        if isinstance(node.func, ast.Name):
            fname = node.func.id
        elif isinstance(node.func, ast.Attribute):
            fname = node.func.attr  # np.sqrt → sqrt
        if fname is None:
            raise _syntax_error(node, "unsupported call", self.source_name)
        fname = _NATIVE_ALIASES.get(fname, fname)

        # cast "functions"
        if fname in ("float", "float64", "float32", "bfloat16"):
            (arg,) = [self.parse(a) for a in node.args]
            return ir.Cast("float64" if fname == "float" else fname, arg)
        if fname in ("int", "int32", "int64"):
            (arg,) = [self.parse(a) for a in node.args]
            return ir.Cast("int32" if fname == "int" else fname, arg)

        # gtscript.function inlining?
        target = self.env.get(fname) or self.globals_ns.get(fname) or self.ctx.globals_ns.get(fname)
        if isinstance(target, GTScriptFunction):
            if node.keywords:
                kw = {k.arg: self.parse(k.value) for k in node.keywords}
            else:
                kw = {}
            args = [self.parse(a) for a in node.args]
            results = self.ctx.inline_function(target, args, kw, node)
            if len(results) == 1:
                return results[0]
            if allow_multi:
                return results
            raise _syntax_error(node, f"function {fname} returns {len(results)} values here; "
                                      "use tuple assignment", self.source_name)

        if fname in ir.NATIVE_FUNCTIONS:
            args = [self.parse(a) for a in node.args]
            if fname in ("min", "max") and len(args) > 2:  # fold n-ary
                result = args[0]
                for a in args[1:]:
                    result = ir.NativeCall(fname, (result, a))
                return result
            if len(args) != ir.NATIVE_FUNCTIONS[fname]:
                raise _syntax_error(node, f"{fname}() takes {ir.NATIVE_FUNCTIONS[fname]} args", self.source_name)
            return ir.NativeCall(fname, tuple(args))

        raise _syntax_error(node, f"call to unknown function {fname!r}", self.source_name)


def _literal_from_value(v: Any) -> ir.Literal:
    if isinstance(v, bool):
        return ir.Literal(bool(v), "bool")
    if isinstance(v, (int, np.integer)):
        return ir.Literal(int(v), "int")
    if isinstance(v, (float, np.floating)):
        return ir.Literal(float(v), "float")
    raise TypeError(f"external value {v!r} is not a scalar constant")


# ---------------------------------------------------------------------------
# Stencil body parsing
# ---------------------------------------------------------------------------


class StencilContext:
    """Symbol tables + function inliner shared by the whole definition."""

    def __init__(
        self,
        name: str,
        fields: Dict[str, ir.FieldDecl],
        scalars: Dict[str, ir.ScalarDecl],
        externals: Dict[str, Any],
        globals_ns: Dict[str, Any],
        default_dtype: str,
    ):
        self.name = name
        self.fields = fields
        self.scalars = scalars
        self.externals = externals
        self.imported_externals: set = set()
        self.globals_ns = globals_ns
        self.default_dtype = default_dtype
        self.temps: Dict[str, ir.FieldDecl] = {}
        self._tmp_counter = 0
        self._inline_depth = 0

    # -- symbols --------------------------------------------------------------

    def resolve_symbol(self, name: str, node: ast.AST, globals_ns: Dict[str, Any]) -> ir.Expr:
        if name in self.fields or name in self.temps:
            return ir.FieldAccess(name, (0, 0, 0))
        if name in self.scalars:
            return ir.ScalarRef(name)
        if name in self.imported_externals:
            return _literal_from_value(self.externals[name])
        if name in ("True", "False"):
            return ir.Literal(name == "True", "bool")
        val = globals_ns.get(name, self.globals_ns.get(name))
        if isinstance(val, numbers.Number):
            return _literal_from_value(val)
        raise _syntax_error(
            node,
            f"unknown symbol {name!r} (not a field, scalar parameter, imported external, "
            "or numeric module constant)",
            self.name,
        )

    def declare_temp(self, name: str, internal: bool = False) -> None:
        if name not in self.temps:
            if not internal:
                _check_symbol_name(name, "temporary", self.name)
            self.temps[name] = ir.FieldDecl(name=name, dtype=self.default_dtype, is_api=False)

    def fresh_temp(self, hint: str = "tmp") -> str:
        self._tmp_counter += 1
        name = f"gt__{hint}_{self._tmp_counter}"
        self.declare_temp(name, internal=True)
        return name

    # -- function inlining ------------------------------------------------------

    def inline_function(
        self,
        func: GTScriptFunction,
        args: List[ir.Expr],
        kwargs: Dict[str, ir.Expr],
        node: ast.AST,
    ) -> List[ir.Expr]:
        self._inline_depth += 1
        if self._inline_depth > 32:
            raise GTScriptSemanticError(f"gtscript.function inlining too deep (recursion?) at {func.__name__}")
        try:
            parsed = parse_gts_function(func)
            if len(args) > len(parsed.params):
                raise _syntax_error(node, f"{func.__name__}() takes {len(parsed.params)} args", self.name)
            env: Dict[str, ir.Expr] = {}
            for pname, arg in zip(parsed.params, args):
                env[pname] = arg
            for k, v in kwargs.items():
                if k not in parsed.params:
                    raise _syntax_error(node, f"{func.__name__}() got unexpected kwarg {k!r}", self.name)
                env[k] = v
            missing = [p for p in parsed.params if p not in env]
            if missing:
                raise _syntax_error(node, f"{func.__name__}() missing args {missing}", self.name)
            parser = ExprParser(self, env=env, globals_ns=parsed.globals, source_name=parsed.name)
            for lname, rhs in parsed.body:
                env[lname] = parser.parse(rhs)
            return [parser.parse(r) for r in parsed.returns]
        finally:
            self._inline_depth -= 1


class StmtParser:
    """Parses interval-body statements into ``ir.Stmt`` sequences."""

    def __init__(self, ctx: StencilContext):
        self.ctx = ctx
        self.expr_parser = ExprParser(ctx, env={}, globals_ns=ctx.globals_ns, source_name=ctx.name)

    def parse_body(self, stmts: Sequence[ast.stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            out.extend(self.parse_stmt(s))
        return out

    def parse_stmt(self, node: ast.stmt) -> List[ir.Stmt]:
        if isinstance(node, ast.Assign):
            return self._assign(node)
        if isinstance(node, ast.AugAssign):
            return self._aug_assign(node)
        if isinstance(node, ast.If):
            return self._if(node)
        if isinstance(node, ast.Expr):
            if isinstance(node.value, ast.Constant) and isinstance(node.value.value, str):
                return []  # docstring / comment string
            raise _syntax_error(node, "bare expressions have no effect in GTScript", self.ctx.name)
        if isinstance(node, ast.Pass):
            return []
        raise _syntax_error(node, f"statement {type(node).__name__} is outside the GTScript subset", self.ctx.name)

    # -- assignment ---------------------------------------------------------------

    def _target_access(self, tgt: ast.expr) -> ir.FieldAccess:
        if isinstance(tgt, ast.Name):
            name = tgt.id
        elif isinstance(tgt, ast.Subscript) and isinstance(tgt.value, ast.Name):
            name = tgt.value.id
            offs = self.expr_parser._parse_offsets(tgt.slice)
            if any(o != 0 for o in offs):
                raise _syntax_error(tgt, "assignment offset must be zero (writes are at the evaluation point)",
                                    self.ctx.name)
        else:
            raise _syntax_error(tgt, "unsupported assignment target", self.ctx.name)
        if name in self.ctx.scalars:
            raise _syntax_error(tgt, f"cannot assign to scalar parameter {name!r}", self.ctx.name)
        if name in self.ctx.imported_externals:
            raise _syntax_error(tgt, f"cannot assign to external {name!r}", self.ctx.name)
        if name not in self.ctx.fields:
            self.ctx.declare_temp(name)
        return ir.FieldAccess(name, (0, 0, 0))

    def _assign(self, node: ast.Assign) -> List[ir.Stmt]:
        if len(node.targets) != 1:
            raise _syntax_error(node, "chained assignment not supported", self.ctx.name)
        tgt = node.targets[0]
        if isinstance(tgt, ast.Tuple):
            return self._tuple_assign(tgt, node)
        values = self.expr_parser.parse_multi(node.value)
        if len(values) != 1:
            raise _syntax_error(node, "multi-value rhs needs a tuple assignment target", self.ctx.name)
        target = self._target_access(tgt)
        return [ir.Assign(target, values[0])]

    def _tuple_assign(self, tgt: ast.Tuple, node: ast.Assign) -> List[ir.Stmt]:
        values = self.expr_parser.parse_multi(node.value)
        if len(values) != len(tgt.elts):
            raise _syntax_error(node, f"cannot unpack {len(values)} values into {len(tgt.elts)} targets",
                                self.ctx.name)
        targets = [self._target_access(t) for t in tgt.elts]
        target_names = {t.name for t in targets}
        # preserve simultaneous-assignment semantics: if any rhs reads a target,
        # stage through fresh temporaries.
        needs_temps = any(
            isinstance(e, ir.FieldAccess) and e.name in target_names
            for v in values
            for e in ir.walk_exprs(v)
        )
        stmts: List[ir.Stmt] = []
        if needs_temps:
            staged: List[ir.FieldAccess] = []
            for v in values:
                tname = self.ctx.fresh_temp("unpack")
                staged.append(ir.FieldAccess(tname, (0, 0, 0)))
                stmts.append(ir.Assign(ir.FieldAccess(tname, (0, 0, 0)), v))
            for t, s in zip(targets, staged):
                stmts.append(ir.Assign(t, s))
        else:
            for t, v in zip(targets, values):
                stmts.append(ir.Assign(t, v))
        return stmts

    def _aug_assign(self, node: ast.AugAssign) -> List[ir.Stmt]:
        op = _BINOPS.get(type(node.op))
        if op is None:
            raise _syntax_error(node, "augmented operator not supported", self.ctx.name)
        target = self._target_access(node.target)
        if target.name in self.ctx.temps and target.name not in self._assigned_names():
            raise _syntax_error(node, f"augmented assignment to undefined temporary {target.name!r}", self.ctx.name)
        value = self.expr_parser.parse(node.value)
        return [ir.Assign(target, ir.BinOp(op, ir.FieldAccess(target.name, (0, 0, 0)), value))]

    def _assigned_names(self) -> set:
        return set(self.ctx.temps)  # conservative; refined by analysis

    # -- control flow ----------------------------------------------------------------

    def _if(self, node: ast.If) -> List[ir.Stmt]:
        cond = self.expr_parser.parse(node.test)
        body = tuple(self.parse_body(node.body))
        orelse = tuple(self.parse_body(node.orelse)) if node.orelse else ()
        # compile-time pruning for literal conditions (externals specialization)
        if isinstance(cond, ir.Literal):
            return list(body) if cond.value else list(orelse)
        return [ir.If(cond, body, orelse)]


# ---------------------------------------------------------------------------
# Top-level definition parsing
# ---------------------------------------------------------------------------


def _axis_bound_from_arg(node: ast.expr, is_start: bool, source_name: str) -> ir.AxisBound:
    try:
        val = ast.literal_eval(node)
    except Exception:
        raise _syntax_error(node, "interval bounds must be integer literals or None", source_name)
    if val is None:
        return ir.AxisBound(ir.LevelMarker.START, 0) if is_start else ir.AxisBound(ir.LevelMarker.END, 0)
    if not isinstance(val, int) or isinstance(val, bool):
        raise _syntax_error(node, f"interval bound must be int or None, got {val!r}", source_name)
    if is_start:
        return ir.AxisBound(ir.LevelMarker.START, val) if val >= 0 else ir.AxisBound(ir.LevelMarker.END, val)
    if val > 0:
        return ir.AxisBound(ir.LevelMarker.START, val)
    if val == 0:
        raise _syntax_error(node, "interval end of 0 would be empty; use None for the full axis", source_name)
    return ir.AxisBound(ir.LevelMarker.END, val)


def _parse_interval_call(call: ast.Call, source_name: str) -> ir.VerticalInterval:
    if len(call.args) == 1:
        if isinstance(call.args[0], ast.Constant) and call.args[0].value is Ellipsis:
            return ir.VerticalInterval.full()
        raise _syntax_error(call, "interval() takes (start, end) or (...)", source_name)
    if len(call.args) != 2:
        raise _syntax_error(call, "interval() takes (start, end) or (...)", source_name)
    start = _axis_bound_from_arg(call.args[0], True, source_name)
    end = _axis_bound_from_arg(call.args[1], False, source_name)
    return ir.VerticalInterval(start, end)


def _parse_order(node: ast.expr, source_name: str) -> ir.IterationOrder:
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    if name is None or name not in _ORDERS:
        raise _syntax_error(node, "computation() takes PARALLEL, FORWARD or BACKWARD", source_name)
    return _ORDERS[name]


def _classify_with_items(node: ast.With, source_name: str):
    """Return (order|None, interval|None) from a With's context items."""
    order = None
    itv = None
    for item in node.items:
        call = item.context_expr
        if not isinstance(call, ast.Call) or not isinstance(call.func, (ast.Name, ast.Attribute)):
            raise _syntax_error(node, "with items must be computation(...) / interval(...)", source_name)
        fname = call.func.id if isinstance(call.func, ast.Name) else call.func.attr
        if fname == "computation":
            if len(call.args) != 1:
                raise _syntax_error(call, "computation() takes exactly one iteration order", source_name)
            order = _parse_order(call.args[0], source_name)
        elif fname == "interval":
            itv = _parse_interval_call(call, source_name)
        else:
            raise _syntax_error(call, f"unknown context {fname!r}", source_name)
    return order, itv


def parse_stencil_definition(
    definition,
    *,
    externals: Dict[str, Any],
    name: Optional[str] = None,
    default_dtype: Optional[str] = None,
) -> ir.StencilDefinition:
    """Parse a stencil definition function into the Definition IR."""

    source = textwrap.dedent(inspect.getsource(definition))
    tree = ast.parse(source)
    fdef = next((n for n in tree.body if isinstance(n, ast.FunctionDef)), None)
    if fdef is None:
        raise GTScriptSyntaxError("could not find stencil definition function")
    stencil_name = name or definition.__name__

    # ---- signature → fields & scalars
    annotations = dict(getattr(definition, "__annotations__", {}))
    globals_ns = _function_namespace(definition)

    fields: Dict[str, ir.FieldDecl] = {}
    scalars: Dict[str, ir.ScalarDecl] = {}

    def _resolve_annotation(pname: str):
        ann = annotations.get(pname)
        if isinstance(ann, str):
            ann = eval(ann, globals_ns)  # noqa: S307  (from __future__ import annotations)
        return ann

    for arg in fdef.args.args:
        _check_symbol_name(arg.arg, "field/parameter", stencil_name)
        ann = _resolve_annotation(arg.arg)
        if isinstance(ann, _FieldType):
            fields[arg.arg] = ir.FieldDecl(
                name=arg.arg, dtype=_dtype_name(ann.dtype), axes=ann.axes, is_api=True
            )
        elif ann is None:
            raise GTScriptSyntaxError(
                f"field parameter {arg.arg!r} of {stencil_name} needs a Field[...] annotation"
            )
        else:  # positional scalar (allowed as an extension)
            scalars[arg.arg] = ir.ScalarDecl(name=arg.arg, dtype=_dtype_name(np.dtype(ann)))
    for arg in fdef.args.kwonlyargs:
        _check_symbol_name(arg.arg, "field/parameter", stencil_name)
        ann = _resolve_annotation(arg.arg)
        if isinstance(ann, _FieldType):
            fields[arg.arg] = ir.FieldDecl(
                name=arg.arg, dtype=_dtype_name(ann.dtype), axes=ann.axes, is_api=True
            )
        else:
            dt = _dtype_name(np.dtype(ann)) if ann is not None else "float64"
            scalars[arg.arg] = ir.ScalarDecl(name=arg.arg, dtype=dt)

    if not fields:
        raise GTScriptSyntaxError(f"stencil {stencil_name} has no field parameters")

    if default_dtype is None:
        default_dtype = next(iter(fields.values())).dtype

    ctx = StencilContext(
        name=stencil_name,
        fields=fields,
        scalars=scalars,
        externals=externals,
        globals_ns=globals_ns,
        default_dtype=default_dtype,
    )

    # ---- body
    docstring = ""
    computations: List[ir.ComputationBlock] = []
    stmt_parser = StmtParser(ctx)

    # hoist temporary declarations: every assigned name that is not an API
    # field/scalar is a temporary field, visible from anywhere in the body
    # (use-before-definition is then caught semantically by the analysis)
    for node in ast.walk(fdef):
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for tgt in targets:
            elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
            for e in elts:
                tname = None
                if isinstance(e, ast.Name):
                    tname = e.id
                elif isinstance(e, ast.Subscript) and isinstance(e.value, ast.Name):
                    tname = e.value.id
                if tname and tname not in fields and tname not in scalars:
                    ctx.declare_temp(tname)

    body = list(fdef.body)
    if body and isinstance(body[0], ast.Expr) and isinstance(body[0].value, ast.Constant) \
            and isinstance(body[0].value.value, str):
        docstring = body[0].value.value
        body = body[1:]

    for node in body:
        if isinstance(node, ast.ImportFrom):
            if node.module != "__externals__":
                raise _syntax_error(node, "only 'from __externals__ import ...' is allowed", stencil_name)
            for alias in node.names:
                if alias.name not in externals:
                    raise GTScriptSemanticError(
                        f"stencil {stencil_name}: external {alias.name!r} imported but not provided "
                        f"(externals={sorted(externals)})"
                    )
                ctx.imported_externals.add(alias.asname or alias.name)
                if alias.asname:
                    ctx.externals[alias.asname] = externals[alias.name]
            continue
        if not isinstance(node, ast.With):
            raise _syntax_error(
                node, "stencil body must be 'with computation(...)' blocks", stencil_name
            )
        order, itv = _classify_with_items(node, stencil_name)
        if order is None:
            raise _syntax_error(node, "top-level with must include computation(...)", stencil_name)

        interval_blocks: List[ir.IntervalBlock] = []
        if itv is not None:
            # single combined 'with computation(...), interval(...):'
            stmts = stmt_parser.parse_body(node.body)
            interval_blocks.append(ir.IntervalBlock(itv, tuple(stmts)))
        else:
            # nested 'with interval(...):' blocks (or raw statements → full interval)
            raw: List[ast.stmt] = []
            for inner in node.body:
                if isinstance(inner, ast.With):
                    o2, itv2 = _classify_with_items(inner, stencil_name)
                    if o2 is not None:
                        raise _syntax_error(inner, "nested computation() not allowed", stencil_name)
                    if itv2 is None:
                        raise _syntax_error(inner, "nested with must be interval(...)", stencil_name)
                    stmts = stmt_parser.parse_body(inner.body)
                    interval_blocks.append(ir.IntervalBlock(itv2, tuple(stmts)))
                else:
                    raw.append(inner)
            if raw:
                if interval_blocks:
                    raise _syntax_error(node, "mix of raw statements and interval blocks", stencil_name)
                stmts = stmt_parser.parse_body(raw)
                interval_blocks.append(ir.IntervalBlock(ir.VerticalInterval.full(), tuple(stmts)))

        computations.append(ir.ComputationBlock(order=order, intervals=tuple(interval_blocks)))

    if not computations:
        raise GTScriptSyntaxError(f"stencil {stencil_name} has no computation blocks")

    externals_used = tuple(sorted((k, _literal_from_value(v).value) for k, v in externals.items()))

    return ir.StencilDefinition(
        name=stencil_name,
        api_fields=tuple(fields.values()) + tuple(ctx.temps.values()),
        scalars=tuple(scalars.values()),
        computations=tuple(computations),
        externals=externals_used,
        docstring=docstring,
    )
