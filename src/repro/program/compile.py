"""Program compiler: dataflow graph → one fused, jit-cached step.

The compilation pipeline (every stage reuses the single-stencil toolchain —
the merged groups go through ``analysis.analyze`` + the ``passes.py``
pipeline + the normal backends, so cross-stencil fusion, CSE and temporary
demotion all fire on the *merged* IR for free):

1. dead-store elimination + grouping (``program.passes``);
2. each group's stencil definitions are **spliced** into one merged
   ``StencilDefinition``: field params rename to program buffer names,
   per-stencil temporaries get a ``_p<node>_`` prefix, scalars rename to
   program scalar names (or ``_c<node>_<param>`` runtime-bound constants),
   and program-internal buffers demote to stencil temporaries
   (``is_api=False``) — the *eliminated temporaries*;
3. an orchestration module is generated (real, inspectable Python source,
   cached by ``core.caching`` under the program fingerprint) that threads
   the buffer dict through the group ``run`` functions and applies the
   output binding — double-buffer rotation is a dict re-wiring, not a copy;
4. for the jax family the orchestrator is wrapped in a single ``jax.jit``.

Fusing never changes values: spliced statements keep their order, crossing
buffers that any later node reads off-center stay API fields of the merged
stencil (so their stale-halo semantics — reads of points no stencil wrote —
are byte-for-byte those of the eager call sequence).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import caching, ir
from repro.core import stencil as stencil_mod
from repro.core.storage import Storage
from repro.obs import trace as otrace

from . import halo as halo_planning
from .graph import ProgramGraph
from .passes import (
    Group,
    check_not_empty,
    eliminate_dead_stores,
    plan_groups,
    rotation_plan,
    validate_iterable,
)
from .trace import ProgramError, Trace, tracing


class ProgramCompileError(ProgramError):
    """The traced graph cannot be compiled as requested."""


# ---------------------------------------------------------------------------
# Definition splicing
# ---------------------------------------------------------------------------


def _map_stmt_scalars(stmt: ir.Stmt, smap: Dict[str, str]) -> ir.Stmt:
    def fn(e: ir.Expr) -> ir.Expr:
        if isinstance(e, ir.ScalarRef) and e.name in smap:
            return ir.ScalarRef(smap[e.name])
        return e

    return ir.map_stmt_exprs(stmt, fn)


def splice_group_definition(
    name: str,
    graph: ProgramGraph,
    group: Group,
    node_index: Dict[int, int],
    internals: set,
) -> Tuple[ir.StencilDefinition, Dict[str, Any]]:
    """Merge the group's stencil definitions into one; returns the merged
    definition and the runtime values of its ``_c*`` constant scalars."""
    field_decls: Dict[str, ir.FieldDecl] = {}
    temp_decls: List[ir.FieldDecl] = []
    scalar_decls: Dict[str, ir.ScalarDecl] = {}
    const_values: Dict[str, Any] = {}
    computations: List[ir.ComputationBlock] = []
    externals: List[Tuple[str, Any]] = []

    for node in group.nodes:
        idx = node_index[id(node)]
        defn = node.stencil.definition_ir
        fmap: Dict[str, str] = {}
        for decl in defn.api_fields:
            if decl.is_api:
                buf = node.field_bind[decl.name]
                fmap[decl.name] = buf
                if buf not in field_decls:
                    field_decls[buf] = ir.FieldDecl(buf, decl.dtype, decl.axes, is_api=buf not in internals)
            else:
                new = f"_p{idx}_{decl.name}"
                fmap[decl.name] = new
                temp_decls.append(ir.FieldDecl(new, decl.dtype, decl.axes, is_api=False))
        smap: Dict[str, str] = {}
        for sdecl in defn.scalars:
            kind, ref = node.scalar_bind[sdecl.name]
            if kind == "scalar":
                smap[sdecl.name] = ref
                prev = scalar_decls.get(ref)
                if prev is not None and prev.dtype != sdecl.dtype:
                    raise ProgramCompileError(
                        f"program scalar {ref!r} bound with conflicting dtypes "
                        f"{prev.dtype} / {sdecl.dtype}"
                    )
                scalar_decls[ref] = ir.ScalarDecl(ref, sdecl.dtype)
            else:
                cname = f"_c{idx}_{sdecl.name}"
                smap[sdecl.name] = cname
                scalar_decls[cname] = ir.ScalarDecl(cname, sdecl.dtype)
                const_values[cname] = ref
        for block in defn.computations:
            intervals = tuple(
                ir.IntervalBlock(
                    ib.interval,
                    tuple(_map_stmt_scalars(ir.rename_fields(s, fmap), smap) for s in ib.body),
                )
                for ib in block.intervals
            )
            computations.append(ir.ComputationBlock(block.order, intervals))
        externals.extend((f"_n{idx}_{k}", v) for k, v in defn.externals)

    merged = ir.StencilDefinition(
        name=name,
        api_fields=tuple(field_decls.values()) + tuple(temp_decls),
        scalars=tuple(scalar_decls.values()),
        computations=tuple(computations),
        externals=tuple(externals),
        docstring=f"spliced from {[n.stencil.name for n in group.nodes]}",
    )
    return merged, const_values


# ---------------------------------------------------------------------------
# Orchestrator source generation
# ---------------------------------------------------------------------------


def _generate_orchestrator(
    name: str,
    backend: str,
    group_domains: List[Tuple[int, int, int]],
    group_fields: List[List[str]],
    group_origins: List[Dict[str, Tuple[int, int, int]]],
    alloc_internal: Dict[str, Tuple[Tuple[int, ...], str]],  # name -> (shape, dtype)
    outputs: Dict[str, str],  # output name -> buffer to return
    written_buffers: List[str],  # written program buffers (not temporaries)
) -> str:
    functional = backend in ("jax", "pallas")
    lines: List[str] = [
        f'"""Auto-generated by repro.program — program {name!r}, backend {backend!r}."""',
    ]
    if functional:
        lines.append("import jax.numpy as jnp")
        _zeros = "jnp.zeros"
    else:
        lines.append("import numpy as np")
        _zeros = "np.zeros"
    lines.append("")
    lines.append("def run(fields, scalars, group_runs):")
    lines.append("    vals = dict(fields)")
    for b, (shape, dtype) in sorted(alloc_internal.items()):
        lines.append(
            f"    vals[{b!r}] = {_zeros}({tuple(shape)!r}, dtype={dtype!r})"
            "  # cross-group program temporary"
        )
    for gi, fields in enumerate(group_fields):
        origins = {b: tuple(group_origins[gi][b]) for b in fields}
        dom = tuple(group_domains[gi])
        if functional:
            lines.append(f"    vals.update(group_runs[{gi}](vals, scalars, {dom!r}, {origins!r}))")
        else:
            lines.append(f"    group_runs[{gi}](vals, scalars, {dom!r}, {origins!r})")
    ret = ", ".join(f"{o!r}: vals[{b!r}]" for o, b in outputs.items())
    # written (non-temporary) buffers come back alongside the output binding
    # so every backend persists them into the caller's storages — matching
    # the eager per-stencil path, where each call writes its fields back
    wrt = ", ".join(f"{b!r}: vals[{b!r}]" for b in written_buffers)
    lines.append(f"    return {{{ret}}}, {{{wrt}}}")
    lines.append("")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Shared planning (single-device AND distributed compilers)
# ---------------------------------------------------------------------------


class ProgramPlan:
    """The shared front half of program compilation: dead-store elimination,
    grouping, buffer internalization, and the spliced+built group stencils.
    Both compilers consume this one object so their planning can never
    drift; they differ only in what they *execute* (a generated orchestrator
    vs. a shard_map body with halo exchanges)."""

    def __init__(
        self,
        name: str,
        graph: ProgramGraph,
        backend: str,
        backend_opts,
        validate_args: bool,
        *,
        distributed: bool,
    ):
        nodes, dropped = eliminate_dead_stores(graph)
        check_not_empty(nodes)
        graph.nodes = nodes  # classification and grouping see live nodes only
        self.nodes = nodes
        self.dropped = dropped
        self.stencil_nodes = graph.stencil_nodes()
        self.node_index = {id(n): i for i, n in enumerate(self.stencil_nodes)}
        self.groups, self.markers = plan_groups(
            graph,
            nodes,
            distributed=distributed,
            split_halo_crossing=distributed or backend == "pallas",
        )
        _inputs, _out_buffers, internals = graph.classify()
        if not distributed:
            # internalizing a buffer is only value-preserving when every
            # access agrees on geometry (same compute domain, same buffer
            # origin): the eager path addresses one shared allocation, and
            # positional agreement is what lets a bare domain-sized temporary
            # replace it.  On a mesh geometry is planner-controlled (uniform
            # local domain, per-field padding), so the filter does not apply.
            geo: Dict[str, set] = {}
            for n in self.stencil_nodes:
                for b in set(n.field_bind.values()):
                    geo.setdefault(b, set()).add((n.domain, n.origins[b]))
            internals = [b for b in internals if len(geo.get(b, set())) <= 1]
        # a buffer only becomes a stencil temporary when one group owns every
        # access; internals crossing groups are materialized by the runtime
        # instead (they still never escape the program)
        touching: Dict[str, set] = {}
        for gi, g in enumerate(self.groups):
            for b in g.buffers():
                touching.setdefault(b, set()).add(gi)
        self.temp_internals = sorted(b for b in internals if len(touching.get(b, ())) <= 1)
        self.alloc_internals = sorted(b for b in internals if len(touching.get(b, ())) > 1)
        self.outputs = {o: b for o, (b, _v) in graph.outputs.items()}
        self.const_scalars: Dict[str, Any] = {}
        self.group_objects: List[stencil_mod.StencilObject] = []
        temp_set = set(self.temp_internals)
        for gi, g in enumerate(self.groups):
            merged, consts = splice_group_definition(f"{name}_g{gi}", graph, g, self.node_index, temp_set)
            self.const_scalars.update(consts)
            obj = stencil_mod.build_from_definition(
                merged, backend, validate_args=validate_args, backend_opts=dict(backend_opts or {})
            )
            self.group_objects.append(obj)

    def base_report(self) -> Dict[str, Any]:
        return {
            "nodes": len(self.stencil_nodes),
            "groups": len(self.groups),
            "fused_stencils": len(self.stencil_nodes) - len(self.groups),
            "group_stencils": [[n.stencil.name for n in g.nodes] for g in self.groups],
            "dead_stores_eliminated": self.dropped,
            "eliminated_temporaries": self.temp_internals + self.alloc_internals,
        }


# ---------------------------------------------------------------------------
# Compiled program (single device)
# ---------------------------------------------------------------------------


class CompiledProgram:
    """One traced+compiled specialization of a program (per shapes/origins)."""

    def __init__(self, name: str, graph: ProgramGraph, backend: str, backend_opts, validate_args: bool):
        self.name = name
        self.graph = graph
        self.backend = backend
        t0 = time.perf_counter()
        plan = ProgramPlan(name, graph, backend, backend_opts, validate_args, distributed=False)
        self.nodes = plan.nodes
        self._node_index = plan.node_index
        groups = plan.groups
        self.temp_internals = plan.temp_internals
        self.alloc_internals = plan.alloc_internals
        self.rotation = rotation_plan(graph, plan.nodes)
        self.iterable_reason = validate_iterable(graph)

        self.domain = groups[0].domain
        self.groups = groups
        self.const_scalars = plan.const_scalars
        self.group_objects = plan.group_objects
        self.outputs = plan.outputs
        temp_set = set(self.temp_internals)
        group_fields = [
            [b for b in g.buffers() if b not in temp_set] for g in groups
        ]
        alloc_set = set(self.alloc_internals)
        group_origins = []
        for gi, g in enumerate(groups):
            org = {b: o for b, o in g.origins().items() if b not in temp_set}
            for b in group_fields[gi]:
                org.setdefault(b, (0, 0, 0))
            # orchestrator-allocated temporaries are bare domain-sized arrays
            for b in alloc_set:
                if b in org:
                    org[b] = (0, 0, 0)
            group_origins.append(org)
        alloc = {}
        for b in self.alloc_internals:
            bi = graph.buffers[b]
            dom = next(g.domain for g in groups if b in g.buffers())
            alloc[b] = (_domain_shape(dom, bi.axes), bi.dtype)
        self.written_buffers = [
            b
            for g in groups
            for n in g.nodes
            for b in graph.node_writes(n)
            if b not in temp_set and b not in alloc_set
        ]
        self.written_buffers = list(dict.fromkeys(self.written_buffers))
        source = _generate_orchestrator(
            name,
            backend,
            [g.domain for g in groups],
            group_fields,
            group_origins,
            alloc,
            self.outputs,
            self.written_buffers,
        )
        self.fingerprint = caching.program_fingerprint(
            name,
            graph.structural_repr(),
            [o.fingerprint for o in self.group_objects],
            backend,
            dict(backend_opts or {}),
        )
        self.generated_source = source
        self._module = caching.load_generated_module(f"{name}_prog", self.fingerprint, source)
        self._group_runs = [
            self._bind_group_run(o, g.domain) for o, g in zip(self.group_objects, groups)
        ]
        self._jitted: Optional[Callable] = None
        self._iter_cache: Dict[int, Callable] = {}
        self.report = {
            **plan.base_report(),
            "backend": backend,
            "fingerprint": self.fingerprint,
            "group_multi_stages": [
                len(o.implementation_ir.multi_stages) for o in self.group_objects
            ],
            "rotation": dict(self.rotation),
            "elided_exchanges": len(plan.markers),
            "compile_seconds": 0.0,
        }
        self.report["compile_seconds"] = time.perf_counter() - t0
        otrace.current_tracer().add_span(
            "program.compile",
            t0,
            time.perf_counter(),
            category="compile",
            program=name,
            backend=backend,
            groups=len(groups),
            fused_stencils=self.report["fused_stencils"],
            fingerprint=self.fingerprint,
        )

    # -- execution ---------------------------------------------------------

    def _bind_group_run(self, obj: stencil_mod.StencilObject, domain) -> Callable:
        run = obj._run
        if obj.backend != "pallas":
            return run
        block, _rec = obj._resolve_block(tuple(domain))
        if block is None:
            return run

        def _with_block(fields, scalars, domain, origins):
            return run(fields, scalars, domain, origins, block=tuple(block))

        return _with_block

    def _jit(self) -> Callable:
        if self._jitted is None:
            import jax

            module_run, group_runs = self._module.run, self._group_runs

            def _pure(fields, scalars):
                return module_run(fields, scalars, group_runs)

            self._jitted = jax.jit(_pure)
        return self._jitted

    def runtime_scalars(self, scalar_values: Dict[str, Any]) -> Dict[str, Any]:
        merged = dict(self.const_scalars)
        merged.update(scalar_values)
        return merged

    def execute(
        self,
        raw_fields: Dict[str, Any],
        scalar_values: Dict[str, Any],
        exec_info: Optional[dict] = None,
    ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Returns (output binding, written program buffers) — the latter so
        the caller can persist every written field's storage, matching the
        eager per-stencil path on all backends."""
        scalars = self.runtime_scalars(scalar_values)
        if exec_info is not None:
            exec_info["program_report"] = dict(self.report)
            exec_info["run_start_time"] = time.perf_counter()
            out = self._execute_profiled(raw_fields, scalars, exec_info)
            exec_info["run_end_time"] = time.perf_counter()
            return out
        if self.backend in ("jax", "pallas"):
            return self._jit()(raw_fields, scalars)
        return self._module.run(raw_fields, scalars, self._group_runs)

    def _execute_profiled(self, raw_fields, scalars, exec_info) -> Dict[str, Any]:
        """Same generated orchestrator, with each group run timed (eager for
        the jax family so per-group walls are real device times)."""
        functional = self.backend in ("jax", "pallas")
        timings: List[Dict[str, Any]] = []

        def timed(gi: int, fn: Callable) -> Callable:
            def _run(fields, scalars, domain, origins):
                t0 = time.perf_counter()
                out = fn(fields, scalars, domain, origins)
                if functional:
                    for v in out.values():
                        v.block_until_ready()
                timings.append(
                    {
                        "group": gi,
                        "stencils": self.report["group_stencils"][gi],
                        "seconds": time.perf_counter() - t0,
                    }
                )
                return out

            return _run

        runs = [timed(gi, fn) for gi, fn in enumerate(self._group_runs)]
        out = self._module.run(raw_fields, scalars, runs)
        exec_info["program_report"]["node_timings"] = timings
        return out


def _domain_shape(domain: Tuple[int, int, int], axes: Tuple[str, ...]) -> Tuple[int, ...]:
    m = dict(zip(("I", "J", "K"), domain))
    return tuple(m[a] for a in axes)


# ---------------------------------------------------------------------------
# The user-facing @program object
# ---------------------------------------------------------------------------


class ProgramObject:
    """A traced, compiled multi-stencil step function.

    Calling mirrors the stencil convention: fields positional-or-keyword,
    scalars keyword-only.  The first call per argument geometry traces the
    step function and compiles the fused program; later calls dispatch the
    cached jitted step directly.  Outputs follow the step function's return
    binding; ``Storage`` arguments named by an output are rebound in place,
    so a driver loop is just ``for _ in range(nt): prog(phi, ...)``.
    """

    def __init__(
        self,
        definition: Callable,
        backend: str = "numpy",
        *,
        name: Optional[str] = None,
        validate_args: bool = True,
        **backend_opts: Any,
    ):
        import inspect

        self.definition = definition
        self.backend = backend
        self.name = name or definition.__name__
        self.validate_args = validate_args
        self.backend_opts = dict(backend_opts)
        self._cache: Dict[Any, CompiledProgram] = {}
        self.field_params: List[str] = []
        self.scalar_params: List[str] = []
        for p in inspect.signature(definition).parameters.values():
            if p.kind == p.POSITIONAL_OR_KEYWORD:
                self.field_params.append(p.name)
            elif p.kind == p.KEYWORD_ONLY:
                self.scalar_params.append(p.name)
            else:
                raise ProgramError(
                    f"program {self.name!r}: unsupported parameter kind for {p.name!r} "
                    "(fields are positional-or-keyword, scalars keyword-only)"
                )

    # -- binding -----------------------------------------------------------

    def _bind(self, args, kwargs) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        fields: Dict[str, Any] = {}
        if len(args) > len(self.field_params):
            raise TypeError(f"{self.name}() takes {len(self.field_params)} field arguments, got {len(args)}")
        for pname, val in zip(self.field_params, args):
            fields[pname] = val
        scalars: Dict[str, Any] = {}
        for key, val in kwargs.items():
            if key in self.field_params:
                if key in fields:
                    raise TypeError(f"{self.name}() got duplicate field argument {key!r}")
                fields[key] = val
            elif key in self.scalar_params:
                scalars[key] = val
            else:
                raise TypeError(f"{self.name}() got unexpected argument {key!r}")
        missing = [p for p in self.field_params if p not in fields]
        if missing:
            raise TypeError(f"{self.name}() missing field arguments: {missing}")
        missing_s = [p for p in self.scalar_params if p not in scalars]
        if missing_s:
            raise TypeError(f"{self.name}() missing scalar arguments: {missing_s}")
        return fields, scalars

    @staticmethod
    def _raw(value):
        return value.data if isinstance(value, Storage) else value

    def _key(self, fields: Dict[str, Any]):
        parts = []
        for name in self.field_params:  # canonical order: kwargs order must not re-key
            v = fields[name]
            origin = tuple(v.default_origin) if isinstance(v, Storage) else None
            parts.append((name, tuple(v.shape), str(v.dtype), origin))
        return tuple(parts)

    # -- tracing / compiling ------------------------------------------------

    def trace(self, fields: Dict[str, Any], scalars: Dict[str, Any]) -> Trace:
        with otrace.span("program.trace", category="compile", program=self.name) as tsp:
            t = Trace(self.name)
            handles = [t.add_field(n, fields[n]) for n in self.field_params]
            scalar_handles = {n: t.add_scalar(n, scalars[n]) for n in self.scalar_params}
            with tracing(t):
                result = self.definition(*handles, **scalar_handles)
            t.finish(result)
            tsp.set("nodes", len(t.nodes))
        return t

    def compiled(self, fields: Dict[str, Any], scalars: Dict[str, Any]) -> CompiledProgram:
        key = self._key(fields)
        cp = self._cache.get(key)
        if cp is None:
            graph = ProgramGraph(self.trace(fields, scalars))
            cp = CompiledProgram(self.name, graph, self.backend, self.backend_opts, self.validate_args)
            self._validate_fields(cp, fields)
            self._cache[key] = cp
        return cp

    def _validate_fields(self, cp: CompiledProgram, fields: Dict[str, Any]) -> None:
        if not self.validate_args:
            return
        for obj, group in zip(cp.group_objects, cp.groups):
            sub = {n: fields[n] for n in obj.field_info if n in fields}
            origins = obj._resolve_origins(sub, None)
            obj._validate(sub, {}, group.domain, origins)

    # -- execution ----------------------------------------------------------

    def __call__(self, *args, exec_info: Optional[dict] = None, **kwargs):
        fields, scalars = self._bind(args, kwargs)
        cp = self.compiled(fields, scalars)
        raw = {n: self._raw(v) for n, v in fields.items()}
        with otrace.span(
            "program.run", category="program", program=self.name, backend=self.backend
        ):
            outs, writes = cp.execute(raw, dict(scalars), exec_info)
        # every written program buffer persists into its storage (eager
        # parity on all backends), then the output binding rebinds — so a
        # rotation like {"phi": phi_new} wins over phi_new's own write
        self._writeback(fields, writes)
        self._writeback(fields, outs)
        return outs

    @staticmethod
    def _writeback(fields, updates) -> None:
        for name, arr in updates.items():
            store = fields.get(name)
            if isinstance(store, Storage) and store.data is not arr:
                store.data = arr

    def iterate(self, n: int, *args, exec_info: Optional[dict] = None, **kwargs):
        """Run ``n`` fused steps as one ``lax.fori_loop`` dispatch.

        Requires the jax-family backends and a *rotation-closed* output
        binding: every output name rebinds a program field of identical
        geometry, so the step composes with itself.
        """
        if self.backend not in ("jax", "pallas"):
            raise ProgramError(f"iterate() requires the jax/pallas backends, not {self.backend!r}")
        fields, scalars = self._bind(args, kwargs)
        cp = self.compiled(fields, scalars)
        if cp.iterable_reason is not None:
            raise ProgramError(f"program {self.name!r} cannot iterate: {cp.iterable_reason}")
        raw = {n: self._raw(v) for n, v in fields.items()}
        values = cp.runtime_scalars(dict(scalars))
        steps = cp._iter_cache.get(int(n))
        if steps is None:
            import jax
            from jax import lax

            module_run, group_runs = cp._module.run, cp._group_runs

            def _steps(vals, scalars):
                def body(_i, vals):
                    outs, writes = module_run(vals, scalars, group_runs)
                    # per-step state: written buffers update, then the
                    # output binding rebinds (rotation wins over the write)
                    return {**vals, **writes, **outs}

                return lax.fori_loop(0, n, body, vals)

            steps = jax.jit(_steps)
            cp._iter_cache[int(n)] = steps
        with otrace.span(
            "program.iterate", category="program", program=self.name,
            backend=self.backend, steps=int(n),
        ):
            final = steps(raw, values)
        if exec_info is not None:
            exec_info["program_report"] = dict(cp.report)
            exec_info["program_report"]["iterated_steps"] = n
        self._writeback(fields, {b: final[b] for b in fields if b in final})
        return {o: final[o] for o in cp.outputs}

    def distribute(self, mesh, **kwargs) -> "DistributedProgram":
        return DistributedProgram(self, mesh, **kwargs)

    def ensemble(self, members: int, **kwargs):
        """An :class:`repro.ensemble.Ensemble` of this program: ``members``
        perturbed copies advanced in one ``jax.vmap``-batched jit dispatch."""
        from repro.ensemble import Ensemble

        return Ensemble(self, members, **kwargs)

    def __repr__(self) -> str:
        return f"ProgramObject({self.name!r}, backend={self.backend!r})"


def program(
    backend: str = "numpy",
    definition: Optional[Callable] = None,
    *,
    name: Optional[str] = None,
    validate_args: bool = True,
    **backend_opts: Any,
):
    """Decorator: trace a multi-stencil step function into a fused program.

    Mirrors ``gtscript.stencil``'s surface::

        @program(backend="jax")
        def step(phi, u, v, adv, phi_new, *, dt, dx, dy):
            advect(phi, u, v, adv, dx=dx, dy=dy)
            euler(phi, adv, phi_new, dt=dt)
            return {"phi": phi_new, "phi_new": phi}

    ``backend_opts`` pass through to the merged stencils' build (the whole
    pass pipeline / codegen option surface of ``build_from_definition``).
    """

    def _impl(func: Callable) -> ProgramObject:
        return ProgramObject(func, backend, name=name, validate_args=validate_args, **backend_opts)

    if definition is not None:
        return _impl(definition)
    return _impl


# ---------------------------------------------------------------------------
# Distributed programs (mesh-sharded execution with planned halo exchanges)
# ---------------------------------------------------------------------------


class DistributedProgram:
    """A traced program compiled for a 2-D device mesh.

    The horizontal plane is block-decomposed exactly like
    ``stencils.distributed.DistributedStencil``, but the whole step runs as
    *one* ``shard_map``-wrapped jit with the minimal halo-exchange schedule
    computed by ``program.halo`` — a field is exchanged only before the
    first group that reads it off-center since its last write, at exactly
    the depth demanded.
    """

    def __init__(
        self,
        prog: ProgramObject,
        mesh,
        *,
        i_axis: str = "data",
        j_axis: str = "model",
        periodic: Tuple[bool, bool] = (False, False),
    ):
        if prog.backend not in ("jax", "pallas"):
            raise ProgramError("DistributedProgram requires a jax/pallas-backend program")
        self.prog = prog
        self.mesh = mesh
        self.i_axis, self.j_axis = i_axis, j_axis
        self.i_size = int(mesh.shape[i_axis])
        self.j_size = int(mesh.shape[j_axis])
        self.periodic = tuple(periodic)
        self._plans: Dict[Any, "DistributedStepPlan"] = {}
        self._cache: Dict[Any, Callable] = {}
        self._iter_cache: Dict[Any, Callable] = {}

    # -- compilation -------------------------------------------------------

    def _plan_local(
        self, fields: Dict[str, Any], scalars: Dict[str, Any], local_domain
    ) -> "DistributedStepPlan":
        """The per-shard step as a pure function — the shared core of
        ``__call__``, ``iterate`` and the ensemble layer's member-batched
        (``vmap``-wrapped) distributed execution."""
        graph = ProgramGraph(self.prog.trace(fields, scalars))
        pplan = ProgramPlan(
            f"{self.prog.name}_dist",
            graph,
            self.prog.backend,
            self.prog.backend_opts,
            False,  # geometry is planner-controlled; per-shard validation is meaningless
            distributed=True,
        )
        groups = pplan.groups
        plan = halo_planning.plan_halo_exchanges(graph, groups, pplan.markers)
        temp_internals = set(pplan.temp_internals)
        alloc_internals = pplan.alloc_internals
        group_objects = pplan.group_objects
        const_scalars = pplan.const_scalars
        outputs = pplan.outputs
        report = {
            **pplan.base_report(),
            "backend": self.prog.backend,
            "mesh": dict(self.mesh.shape),
            "halo_plan": plan.summary(),
        }

        ni, nj, nk = local_domain
        i_axis, j_axis = self.i_axis, self.j_axis
        i_size, j_size, periodic = self.i_size, self.j_size, self.periodic
        group_buffers = [[b for b in g.buffers() if b not in temp_internals] for g in groups]
        buffers = graph.buffers
        group_runs = [obj._run for obj in group_objects]
        used_inputs = sorted(
            n
            for n in fields
            if n in buffers and n not in temp_internals and n not in set(alloc_internals)
        )

        from repro.parallel.halo import exchange_halo_2d

        def run_groups(local_fields: Dict[str, Any], scalar_vals: Dict[str, Any]):
            """One per-shard step: planned exchanges + group runs.  Returns
            ``(state, outs)`` — the updated values of every used input, and
            the output binding."""
            import jax.numpy as jnp

            scal = dict(const_scalars)
            scal.update(scalar_vals)
            vals = dict(local_fields)
            for b in alloc_internals:
                bi = buffers[b]
                vals[b] = jnp.zeros(_domain_shape(local_domain, bi.axes), dtype=bi.dtype)
            padded: Dict[str, Any] = {}
            depth: Dict[str, int] = {}
            for gi in range(len(groups)):
                for op in plan.before_group(gi):
                    padded[op.buffer] = exchange_halo_2d(
                        vals[op.buffer], op.halo, i_axis, j_axis, i_size, j_size, periodic
                    )
                    depth[op.buffer] = op.halo
                read_padded = plan.read_depth[gi]
                gf: Dict[str, Any] = {}
                origins: Dict[str, Tuple[int, int, int]] = {}
                for b in group_buffers[gi]:
                    if b in read_padded:
                        d = depth[b]
                        gf[b] = padded[b]
                        origins[b] = (d, d, 0)
                    else:
                        gf[b] = vals[b]
                        origins[b] = (0, 0, 0)
                upd = group_runs[gi](gf, scal, local_domain, origins)
                for b, arr in upd.items():
                    if b in read_padded:
                        d = depth[b]
                        vals[b] = arr[d : d + ni, d : d + nj]
                    else:
                        vals[b] = arr
                    padded.pop(b, None)
                    depth.pop(b, None)
            state = {n: vals[n] for n in used_inputs}
            outs = {o: vals[b] for o, b in outputs.items()}
            return state, outs

        return DistributedStepPlan(
            run_groups=run_groups,
            used_inputs=used_inputs,
            outputs=dict(outputs),
            buffers=buffers,
            report=report,
            iterable_reason=validate_iterable(graph),
        )

    def _spec_for(self, plan: "DistributedStepPlan", name: str, member_axis: Optional[str] = None):
        from jax.sharding import PartitionSpec as P

        axes = plan.buffers[name].axes
        m = (member_axis,) if member_axis is not None else ()
        if axes and axes[0] == "N":
            axes = axes[1:]
        if axes == ("K",):
            return P(*m, None)
        if len(axes) == 2:
            return P(*m, self.i_axis, self.j_axis)
        return P(*m, self.i_axis, self.j_axis, None)

    def _plan_for(self, fields, scalars, local, key) -> "DistributedStepPlan":
        if key not in self._plans:
            self._plans[key] = self._plan_local(fields, scalars, local)
        return self._plans[key]

    def _geometry(self, fields: Dict[str, Any]):
        """(local_domain, cache key) for GLOBAL interior-only field arrays."""
        # the vertical extent must come from a 3-D field — a 2-D (I, J)
        # buffer that happens to be listed first must not collapse nk to 1
        sample = next(
            (v for v in fields.values() if len(v.shape) == 3),
            next(v for v in fields.values() if len(v.shape) >= 2),
        )
        gi, gj = int(sample.shape[0]), int(sample.shape[1])
        if gi % self.i_size or gj % self.j_size:
            raise ProgramError(
                f"global domain ({gi}, {gj}) must tile over the ({self.i_size}, {self.j_size}) mesh"
            )
        nk = int(sample.shape[2]) if len(sample.shape) == 3 else 1
        local = (gi // self.i_size, gj // self.j_size, nk)
        key = (tuple(sorted((n, tuple(v.shape), str(v.dtype)) for n, v in fields.items())), local)
        return local, key

    def _compile(self, plan: "DistributedStepPlan") -> Callable:
        from repro.stencils.distributed import shard_map
        from jax.sharding import PartitionSpec as P
        import jax

        def body(local_fields: Dict[str, Any], scalar_vals: Dict[str, Any]):
            _state, outs = plan.run_groups(local_fields, scalar_vals)
            return outs

        in_specs = ({n: self._spec_for(plan, n) for n in plan.used_inputs}, P())
        out_specs = {o: self._spec_for(plan, b) for o, b in plan.outputs.items()}
        shard_fn = jax.jit(shard_map(body, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs))

        def fn(all_fields, scalar_vals):
            return shard_fn({n: all_fields[n] for n in plan.used_inputs}, scalar_vals)

        return fn

    def _compile_iterate(self, plan: "DistributedStepPlan", n: int) -> Callable:
        from repro.stencils.distributed import shard_map
        from jax.sharding import PartitionSpec as P
        import jax
        from jax import lax

        run_groups, used, outputs = plan.run_groups, plan.used_inputs, plan.outputs

        def body(local_fields: Dict[str, Any], scalar_vals: Dict[str, Any]):
            def step(_i, st):
                # per-step state: written buffers update, then the output
                # binding rebinds — the 2-exchange/step plan runs inside
                # run_groups on every iteration
                state, outs = run_groups(st, scalar_vals)
                return {**state, **outs}

            final = lax.fori_loop(0, n, step, {k: local_fields[k] for k in used})
            return {o: final[o] for o in outputs}

        in_specs = ({n: self._spec_for(plan, n) for n in used}, P())
        out_specs = {o: self._spec_for(plan, b) for o, b in outputs.items()}
        shard_fn = jax.jit(shard_map(body, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs))

        def fn(all_fields, scalar_vals):
            return shard_fn({n: all_fields[n] for n in used}, scalar_vals)

        return fn

    # -- execution ---------------------------------------------------------

    def __call__(
        self,
        fields: Dict[str, Any],
        scalars: Optional[Dict[str, Any]] = None,
        *,
        exec_info: Optional[dict] = None,
    ) -> Dict[str, Any]:
        """``fields``: GLOBAL (interior-only) arrays keyed by program field
        name.  Returns the output binding as global arrays."""
        scalars = dict(scalars or {})
        local, key = self._geometry(fields)
        plan = self._plan_for(fields, scalars, local, key)
        if key not in self._cache:
            self._cache[key] = self._compile(plan)
        fn = self._cache[key]
        if exec_info is not None:
            exec_info["program_report"] = dict(plan.report)
            exec_info["run_start_time"] = time.perf_counter()
        out = fn(fields, scalars)
        if exec_info is not None:
            for v in out.values():
                v.block_until_ready()
            exec_info["run_end_time"] = time.perf_counter()
        return out

    def iterate(
        self,
        n: int,
        fields: Dict[str, Any],
        scalars: Optional[Dict[str, Any]] = None,
        *,
        exec_info: Optional[dict] = None,
    ) -> Dict[str, Any]:
        """Run ``n`` sharded steps in ONE ``shard_map``-wrapped ``fori_loop``
        dispatch, the minimal halo-exchange plan applied on every iteration.

        Requires a rotation-closed output binding (same contract as
        ``ProgramObject.iterate``): every output name rebinds a program field
        of identical geometry, so the sharded step composes with itself.
        Returns the output binding as global arrays after step ``n``.
        """
        scalars = dict(scalars or {})
        local, key = self._geometry(fields)
        plan = self._plan_for(fields, scalars, local, key)
        if plan.iterable_reason is not None:
            raise ProgramError(f"distributed program {self.prog.name!r} cannot iterate: {plan.iterable_reason}")
        ikey = (key, int(n))
        if ikey not in self._iter_cache:
            self._iter_cache[ikey] = self._compile_iterate(plan, int(n))
        fn = self._iter_cache[ikey]
        if exec_info is not None:
            exec_info["program_report"] = dict(plan.report)
            exec_info["program_report"]["iterated_steps"] = int(n)
            exec_info["run_start_time"] = time.perf_counter()
        out = fn(fields, scalars)
        if exec_info is not None:
            for v in out.values():
                v.block_until_ready()
            exec_info["run_end_time"] = time.perf_counter()
        return out


class DistributedStepPlan:
    """The compiled-but-unwrapped per-shard step of a distributed program:
    everything ``shard_map`` wrappers (single-step, iterated, member-batched)
    need, with the planning done exactly once per argument geometry."""

    def __init__(self, *, run_groups, used_inputs, outputs, buffers, report, iterable_reason):
        self.run_groups = run_groups
        self.used_inputs = list(used_inputs)
        self.outputs = dict(outputs)
        self.buffers = buffers
        self.report = report
        self.iterable_reason = iterable_reason
