"""Program-level planning passes over the dataflow graph.

Three decisions are made here, each recorded in the program report that
``exec_info["program_report"]`` surfaces (mirroring the stencil-level
``pass_report``):

* **dead-store elimination** — nodes whose writes reach neither a later
  read nor the output binding are dropped.  Writes are modelled as
  read-modify-writes (a stencil writes only the compute domain, so the
  incoming halo of a written buffer still flows through), which makes the
  elimination conservative and therefore unconditionally safe.
* **grouping** — maximal runs of adjacent stencil nodes that one merged
  stencil can implement.  A node joins the open group when backends and
  domains match and every shared buffer keeps a consistent origin.  Under
  ``distributed=True`` a write→offset-read edge also closes the group: the
  reader needs a halo exchange of the crossing field, and exchanges can
  only happen between groups.
* **rotation detection** — output bindings that are untouched input
  versions (``{"phi": phi_new, "phi_new": phi}``) are pure buffer renames;
  the compiler implements them as in-graph aliasing (and they are what
  makes ``ProgramObject.iterate`` a single fused ``fori_loop``).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .graph import ProgramGraph
from .trace import ExchangeNode, ProgramTraceError, StencilNode


# ---------------------------------------------------------------------------
# Dead-store elimination
# ---------------------------------------------------------------------------


def eliminate_dead_stores(graph: ProgramGraph) -> Tuple[List, List[str]]:
    """Returns (live nodes in order, names of dropped stencil calls)."""
    live_versions = {tuple(bv) for bv in graph.outputs.values()}
    keep: List = []
    dropped: List[str] = []
    for node in reversed(graph.nodes):
        if isinstance(node, ExchangeNode):
            # an exchange refreshes (buffer, version): keep it only while that
            # version is still wanted downstream
            if (node.buffer, node.version) in live_versions:
                keep.append(node)
            else:
                dropped.append(f"exchange({node.buffer})")
            continue
        wanted = any((b, v) in live_versions for b, v in node.write_versions.items())
        if not wanted:
            dropped.append(node.stencil.name)
            continue
        keep.append(node)
        for b, v in node.read_versions.items():
            live_versions.add((b, v))
    keep.reverse()
    dropped.reverse()
    return keep, dropped


# ---------------------------------------------------------------------------
# Grouping (cross-stencil fusion planning)
# ---------------------------------------------------------------------------


class Group:
    """A maximal fusable run of stencil nodes (indices into the node list)."""

    def __init__(self, nodes: List[StencilNode]):
        self.nodes = list(nodes)

    @property
    def domain(self) -> Tuple[int, int, int]:
        return self.nodes[0].domain

    def buffers(self) -> List[str]:
        seen: List[str] = []
        for n in self.nodes:
            for b in n.field_bind.values():
                if b not in seen:
                    seen.append(b)
        return seen

    def origins(self) -> Dict[str, Tuple[int, int, int]]:
        out: Dict[str, Tuple[int, int, int]] = {}
        for n in self.nodes:
            out.update(n.origins)
        return out

    def __repr__(self) -> str:
        return f"Group({[n.stencil.name for n in self.nodes]})"


def _joinable(
    graph: ProgramGraph,
    group: List[StencilNode],
    written: set,
    node: StencilNode,
    distributed: bool,
    split_halo_crossing: bool,
) -> bool:
    if split_halo_crossing:
        # a crossing write→halo-read edge closes the group: distributed, the
        # reader needs a halo exchange first; on pallas, the kernel cannot
        # serve halo reads of fields it writes (written API fields live in
        # output VMEM tiles without halo rings)
        for buf, (ext, _k) in graph.node_reads(node).items():
            (ilo, ihi), (jlo, jhi), _ = ext.as_tuple()
            if buf in written and (ilo, ihi, jlo, jhi) != (0, 0, 0, 0):
                return False
    if distributed:
        # geometry is planner-controlled on the mesh (per-field padding and a
        # uniform local domain): no further constraints
        return True
    head = group[0]
    if node.domain != head.domain:
        return False
    origins: Dict[str, Tuple[int, int, int]] = {}
    for n in group:
        origins.update(n.origins)
    for buf, org in node.origins.items():
        if buf in origins and origins[buf] != org:
            return False
    return True


def plan_groups(
    graph: ProgramGraph,
    nodes: List,
    *,
    distributed: bool = False,
    split_halo_crossing: Optional[bool] = None,
) -> Tuple[List[Group], List[ExchangeNode]]:
    """Partition live nodes into fusable groups.

    Returns (groups in execution order, the explicit exchange markers in
    order — each remembered with the index of the group it precedes via
    ``marker.before_group``)."""
    if split_halo_crossing is None:
        split_halo_crossing = distributed
    groups: List[Group] = []
    markers: List[ExchangeNode] = []
    current: List[StencilNode] = []
    written: set = set()

    def close():
        nonlocal current, written
        if current:
            groups.append(Group(current))
            current, written = [], set()

    for node in nodes:
        if isinstance(node, ExchangeNode):
            # an exchange is a real barrier only where exchanges execute
            # (distributed / halo-splitting backends); the single-device
            # compiler elides the marker, so splitting a fusable run on it
            # would cost fusion for no semantic reason
            if split_halo_crossing or distributed:
                close()
            node.before_group = len(groups) + (1 if current else 0)  # type: ignore[attr-defined]
            markers.append(node)
            continue
        if current and not _joinable(graph, current, written, node, distributed, split_halo_crossing):
            close()
        current.append(node)
        written.update(graph.node_writes(node))
    close()
    return groups, markers


# ---------------------------------------------------------------------------
# Rotation detection
# ---------------------------------------------------------------------------


def rotation_plan(graph: ProgramGraph, nodes: List) -> Dict[str, str]:
    """Output bindings that are pure renames of *untouched* program inputs:
    ``{output_name: source_buffer}`` where the source buffer's version at
    return time is its input version (0).  These never need a copy — the
    compiled step returns the input array under the new name."""
    out: Dict[str, str] = {}
    final_version: Dict[str, int] = {}
    for node in nodes:
        if isinstance(node, StencilNode):
            final_version.update(node.write_versions)
    for out_name, (buf, version) in graph.outputs.items():
        if version == 0 and final_version.get(buf, 0) == 0 and out_name != buf:
            out[out_name] = buf
    return out


def validate_iterable(graph: ProgramGraph) -> Optional[str]:
    """None when the program can be self-composed (``iterate``): every output
    name must be an input buffer of identical shape/dtype/axes.  Returns a
    human-readable reason otherwise."""
    for out_name, (buf, _v) in graph.outputs.items():
        if out_name not in graph.buffers:
            return (
                f"output {out_name!r} is not a program field argument — iterate() needs "
                "outputs that rebind the next step's inputs"
            )
        a, b = graph.buffers[out_name], graph.buffers[buf]
        if (a.shape, a.dtype, a.axes) != (b.shape, b.dtype, b.axes):
            return f"output {out_name!r} has a different shape/dtype than the buffer it rebinds"
    return None


def check_not_empty(nodes: List) -> None:
    if not any(isinstance(n, StencilNode) for n in nodes):
        raise ProgramTraceError("program records no live stencil calls after dead-store elimination")
