"""Halo planning: minimal exchange insertion for mesh-sharded programs.

The eager distributed path (``repro.stencils.distributed``) exchanges every
field of every stencil call at the stencil's maximum halo depth.  At program
scope that is wasteful twice over: fields the stencil never reads off-center
need no exchange at all, and a field exchanged for one stencil is still
valid for the next unless something wrote it in between.

This module computes the minimal plan statically from the dataflow graph: a
halo-*validity* walk over the planned groups.  Validity is per buffer — the
depth up to which the current padded copy of the buffer agrees with the
neighbours.  A group that reads buffer ``b`` with access extent ``e > 0``
demands validity ``≥ e``; if the walk cannot prove it, an exchange of depth
exactly ``e`` (the union over the group's readers) is inserted *before* the
group.  Writes reset validity to zero (the neighbour's copy changed).
Explicit ``request_exchange`` markers force an exchange at the marked point
regardless of validity (an escape hatch for boundary-condition code).

Bit-identity with the eager chain follows from SPMD synchrony: if no shard
wrote ``b`` since its last exchange, no neighbour did either, so re-shipping
the stripes would reproduce the bytes already cached.
"""

from __future__ import annotations

from typing import Dict, List

from .graph import ProgramGraph
from .passes import Group
from .trace import ExchangeNode, ProgramTraceError


class ExchangeOp:
    """One planned halo exchange: pad ``buffer`` to depth ``halo`` before
    group ``before_group`` runs."""

    def __init__(self, buffer: str, halo: int, before_group: int, forced: bool = False):
        self.buffer = buffer
        self.halo = int(halo)
        self.before_group = int(before_group)
        self.forced = forced

    def __repr__(self) -> str:
        kind = "forced" if self.forced else "auto"
        return f"ExchangeOp({self.buffer}, halo={self.halo}, before_group={self.before_group}, {kind})"


class HaloPlan:
    def __init__(
        self,
        exchanges: List[ExchangeOp],
        read_depth: List[Dict[str, int]],  # per group: buffer -> padded depth to read at
        baseline_exchanges: int,
    ):
        self.exchanges = list(exchanges)
        self.read_depth = [dict(d) for d in read_depth]
        self.baseline_exchanges = int(baseline_exchanges)

    def before_group(self, gi: int) -> List[ExchangeOp]:
        return [e for e in self.exchanges if e.before_group == gi]

    def summary(self) -> Dict[str, object]:
        return {
            "inserted": len(self.exchanges),
            "baseline_per_step": self.baseline_exchanges,
            "ops": [
                {"buffer": e.buffer, "halo": e.halo, "before_group": e.before_group, "forced": e.forced}
                for e in self.exchanges
            ],
        }


def _group_read_halos(graph: ProgramGraph, group: Group) -> Dict[str, int]:
    """Max horizontal read depth per buffer for one group, counting only
    reads of the *incoming* version (grouping already guarantees no
    write→offset-read edge stays inside a distributed group)."""
    out: Dict[str, int] = {}
    for node in group.nodes:
        for buf, (ext, _k) in graph.node_reads(node).items():
            h = max(ext.halo[0], ext.halo[1])
            if h > 0:
                out[buf] = max(out.get(buf, 0), h)
    return out


def plan_halo_exchanges(
    graph: ProgramGraph,
    groups: List[Group],
    markers: List[ExchangeNode],
) -> HaloPlan:
    """The minimal exchange schedule for the grouped program."""
    validity: Dict[str, int] = {}
    exchanges: List[ExchangeOp] = []
    read_depth: List[Dict[str, int]] = []

    forced_by_group: Dict[int, List[ExchangeNode]] = {}
    for m in markers:
        forced_by_group.setdefault(getattr(m, "before_group", 0), []).append(m)

    for gi, group in enumerate(groups):
        needs = _group_read_halos(graph, group)
        for m in forced_by_group.get(gi, ()):
            bi = graph.buffers.get(m.buffer)
            if bi is None or "I" not in bi.axes:
                raise ProgramTraceError(
                    f"request_exchange({m.buffer!r}): only horizontally decomposed fields "
                    "can be exchanged"
                )
            depth = m.halo if m.halo is not None else max(needs.get(m.buffer, 1), 1)
            exchanges.append(ExchangeOp(m.buffer, depth, gi, forced=True))
            validity[m.buffer] = depth
        for buf in sorted(needs):
            need = needs[buf]
            if validity.get(buf, 0) < need:
                exchanges.append(ExchangeOp(buf, need, gi))
                validity[buf] = need
        read_depth.append({b: validity[b] for b in needs})
        for buf in group.buffers():
            if buf in _written(graph, group):
                validity.pop(buf, None)

    # markers trailing the last group have no reader inside the program; the
    # runtime drops them (the outputs are interiors — padding would be lost)

    baseline = _eager_baseline(graph)
    return HaloPlan(exchanges, read_depth, baseline)


def _written(graph: ProgramGraph, group: Group) -> set:
    w: set = set()
    for node in group.nodes:
        w.update(graph.node_writes(node))
    return w


def _eager_baseline(graph: ProgramGraph) -> int:
    """Exchanges the eager per-stencil distributed path would issue per step:
    one per horizontally-decomposed field per stencil call with a nonzero
    stencil halo (``DistributedStencil`` pads every field it is given)."""
    count = 0
    for node in graph.stencil_nodes():
        impl = node.stencil.implementation_ir
        h = max(impl.max_halo[0], impl.max_halo[1])
        if h == 0:
            continue
        for param in node.field_bind:
            if "I" in node.stencil.field_info[param].axes:
                count += 1
    return count
