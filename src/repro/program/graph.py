"""Inter-stencil dataflow graph over program buffers.

Built from a finished :class:`repro.program.trace.Trace`, this layer answers
the structural questions the program passes and the compiler ask:

* per-node field *access extents* (pulled from each stencil's analyzed
  ``StencilImplementation`` — the same extents the single-stencil toolchain
  computed, reused unchanged at program scope);
* per-buffer classification into **inputs** (the incoming array is
  observable: first access is a read, or some read touches a halo/adjacent
  k-plane the in-program writes never define), **outputs** (named in the
  step function's return binding) and **internals** (write-before-read,
  zero-offset reads, full-K write coverage — the buffers the compiler may
  demote to stencil temporaries, i.e. the program-level *eliminated
  temporaries*);
* a stable structural hash for the program-level cache key.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core import ir

from .trace import ExchangeNode, ProgramTraceError, StencilNode, Trace


# ---------------------------------------------------------------------------
# Stencil-level access summaries
# ---------------------------------------------------------------------------


def stencil_read_extents(impl: ir.StencilImplementation) -> Dict[str, Tuple[ir.Extent, Tuple[int, int]]]:
    """API fields the stencil reads, with their access extent and k-offsets."""
    api = {f.name for f in impl.api_fields}
    read: set = set()
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for stmt in st.stmts:
                    for rname, _off in ir.stmt_reads(stmt):
                        if rname in api:
                            read.add(rname)
    kext = dict(impl.k_extents)
    return {
        name: (impl.extent_of(name), kext.get(name, (0, 0)))
        for name in sorted(read)
    }


def stencil_written_fields(impl: ir.StencilImplementation) -> List[str]:
    return list(impl.written_api_fields())


def _write_intervals(impl: ir.StencilImplementation, field: str) -> List[ir.VerticalInterval]:
    out: List[ir.VerticalInterval] = []
    for ms in impl.multi_stages:
        for itv in ms.intervals:
            if any(field in st.writes for st in itv.stages):
                out.append(itv.interval)
    return out


def intervals_cover_full_k(intervals: List[ir.VerticalInterval]) -> bool:
    """True when the union of ``intervals`` is exactly the full vertical domain
    (checked structurally on axis bounds, so it is domain-size independent)."""
    if not intervals:
        return False
    ivs = sorted(intervals, key=lambda iv: iv.start.key())
    if ivs[0].start != ir.AxisBound(ir.LevelMarker.START, 0):
        return False
    cur = ivs[0]
    for nxt in ivs[1:]:
        if nxt.start.key() < cur.end.key():
            cur = ir.VerticalInterval(cur.start, max(cur.end, nxt.end, key=lambda b: b.key()))
            continue
        if not ir.intervals_adjacent(cur, nxt):
            return False
        cur = ir.interval_span(cur, nxt)
    return cur.end == ir.AxisBound(ir.LevelMarker.END, 0)


# ---------------------------------------------------------------------------
# Program graph
# ---------------------------------------------------------------------------


class BufferInfo:
    def __init__(self, name: str, shape, dtype, axes, origin=None):
        self.name = name
        self.shape = tuple(shape)
        self.dtype = str(dtype)
        self.axes = tuple(axes)
        self.origin = tuple(origin) if origin is not None else None

    def __repr__(self) -> str:
        return f"BufferInfo({self.name}, shape={self.shape}, dtype={self.dtype}, axes={self.axes})"


class ProgramGraph:
    """The traced program as an explicit dataflow structure."""

    def __init__(self, trace: Trace):
        self.name = trace.name
        self.nodes: List = list(trace.nodes)
        self.outputs: Dict[str, Tuple[str, int]] = dict(trace.outputs)
        self.scalar_params: Dict[str, str] = {n: s.dtype for n, s in trace.scalars.items()}
        self.buffers: Dict[str, BufferInfo] = {}
        accessed = set()
        for node in self.nodes:
            if isinstance(node, StencilNode):
                accessed.update(node.field_bind.values())
            else:
                accessed.add(node.buffer)
        accessed.update(b for b, _v in self.outputs.values())
        for name, h in trace.fields.items():
            if name in accessed:
                self.buffers[name] = BufferInfo(name, h.shape, h.dtype, h.axes)
        self._check_consistency()

    # -- validation --------------------------------------------------------

    def _check_consistency(self) -> None:
        backends = sorted({n.stencil.backend for n in self.stencil_nodes()})
        if len(backends) > 1:
            raise ProgramTraceError(
                f"program {self.name!r} mixes stencil backends {backends}: all stencils "
                "inside one program must share a backend (compile per-backend programs "
                "and compose them on the host instead)."
            )
        for node in self.stencil_nodes():
            for param, buf in node.field_bind.items():
                info = node.stencil.field_info[param]
                bi = self.buffers[buf]
                if tuple(info.axes) != bi.axes:
                    raise ProgramTraceError(
                        f"program {self.name!r}: buffer {buf!r} (axes {bi.axes}) bound to "
                        f"field {param!r} of {node.stencil.name!r} with axes {tuple(info.axes)}"
                    )
                if str(info.dtype) != bi.dtype:
                    raise ProgramTraceError(
                        f"program {self.name!r}: buffer {buf!r} (dtype {bi.dtype}) bound to "
                        f"field {param!r} of {node.stencil.name!r} expecting {info.dtype}"
                    )

    # -- simple accessors --------------------------------------------------

    def stencil_nodes(self) -> List[StencilNode]:
        return [n for n in self.nodes if isinstance(n, StencilNode)]

    @property
    def backend(self) -> str:
        nodes = self.stencil_nodes()
        if not nodes:
            raise ProgramTraceError(f"program {self.name!r} recorded no stencil calls")
        return nodes[0].stencil.backend

    def node_reads(self, node: StencilNode) -> Dict[str, Tuple[ir.Extent, Tuple[int, int]]]:
        """buffer -> (access extent, k-offsets) for one node."""
        per_param = stencil_read_extents(node.stencil.implementation_ir)
        out: Dict[str, Tuple[ir.Extent, Tuple[int, int]]] = {}
        for param, (ext, krange) in per_param.items():
            buf = node.field_bind[param]
            if buf in out:  # aliased params: union
                pe, pk = out[buf]
                out[buf] = (pe.union(ext), (min(pk[0], krange[0]), max(pk[1], krange[1])))
            else:
                out[buf] = (ext, krange)
        return out

    def node_writes(self, node: StencilNode) -> List[str]:
        seen: List[str] = []
        for param in stencil_written_fields(node.stencil.implementation_ir):
            buf = node.field_bind[param]
            if buf not in seen:
                seen.append(buf)
        return seen

    def node_write_intervals(self, node: StencilNode, buf: str) -> List[ir.VerticalInterval]:
        out: List[ir.VerticalInterval] = []
        for param, b in node.field_bind.items():
            if b == buf:
                out.extend(_write_intervals(node.stencil.implementation_ir, param))
        return out

    # -- classification ----------------------------------------------------

    def classify(self) -> Tuple[List[str], List[str], List[str]]:
        """Returns (inputs, output buffers, internals).

        A buffer is **internal** — a program-level temporary the compiler may
        stop materializing — only when the incoming array is provably never
        observed: its first access is a write, every read is at zero offset
        (extent zero in I/J *and* no vertical offsets), and before every read
        the in-program writes cover the full vertical domain.  Everything
        else that is read, plus anything read before written, is an input.
        Output buffers are whatever the return binding names.
        """
        out_buffers = sorted({b for b, _v in self.outputs.values()})
        first_access: Dict[str, str] = {}
        offset_read: Dict[str, bool] = {}
        covered: Dict[str, List[ir.VerticalInterval]] = {}
        uncovered_read: Dict[str, bool] = {}
        for node in self.nodes:
            if isinstance(node, ExchangeNode):
                # an explicit exchange consumes the incoming halo
                first_access.setdefault(node.buffer, "read")
                offset_read[node.buffer] = True
                continue
            reads = self.node_reads(node)
            for buf, (ext, krange) in reads.items():
                first_access.setdefault(buf, "read")
                (ilo, ihi), (jlo, jhi), _k = ext.as_tuple()
                if (ilo, ihi, jlo, jhi) != (0, 0, 0, 0) or krange != (0, 0):
                    offset_read[buf] = True
                bi = self.buffers[buf]
                if "K" in bi.axes and not intervals_cover_full_k(covered.get(buf, [])):
                    uncovered_read[buf] = True
            for buf in self.node_writes(node):
                first_access.setdefault(buf, "write")
                covered.setdefault(buf, []).extend(self.node_write_intervals(node, buf))
        internals: List[str] = []
        for name in self.buffers:
            if (
                first_access.get(name) == "write"
                and name not in out_buffers
                and not offset_read.get(name, False)
                and not uncovered_read.get(name, False)
            ):
                internals.append(name)
        inputs = sorted(n for n in self.buffers if n not in internals)
        return inputs, out_buffers, sorted(internals)

    # -- hashing -----------------------------------------------------------

    def structural_repr(self) -> str:
        parts = [f"program|{self.name}"]
        for name in sorted(self.buffers):
            bi = self.buffers[name]
            parts.append(f"buffer|{name}|{bi.shape}|{bi.dtype}|{bi.axes}")
        for name in sorted(self.scalar_params):
            parts.append(f"scalar|{name}|{self.scalar_params[name]}")
        parts.extend(n.structural_repr() for n in self.nodes)
        parts.append(repr(sorted(self.outputs.items())))
        return "\n".join(parts)
