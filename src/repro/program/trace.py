"""Program tracer: record the stencil calls of a Python step function.

The ``@program`` decorator (``repro.program``) runs the user's step function
*once* with :class:`TracedField` handles in place of its field arguments.
Every :class:`~repro.core.stencil.StencilObject` call made on those handles
is intercepted through the ``core.stencil`` trace hook and recorded as a
:class:`StencilNode` in an inter-stencil dataflow trace instead of being
executed; explicit halo-exchange requests (``repro.parallel.halo
.request_exchange``) become :class:`ExchangeNode` markers.  The trace is the
input of ``repro.program.graph`` / ``compile``.

Field handles carry *versions* (bumped on every write) so the graph layer
can reason about dataflow SSA-style while the user code keeps the eager,
mutating call convention of the paper's API — ``advect(phi, u, v, adv,
...)`` reads ``phi@0`` and produces ``adv@1``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import stencil as _stencil_mod
from repro.core.stencil import NOT_TRACED, StencilObject
from repro.core.storage import Storage


class ProgramError(Exception):
    """Base class for program-orchestration errors."""


class ProgramTraceError(ProgramError):
    """The step function did something the tracer cannot record."""


# ---------------------------------------------------------------------------
# Traced handles
# ---------------------------------------------------------------------------


def _blocked(op: str):
    def _fn(self, *_a, **_k):
        raise ProgramTraceError(
            f"cannot apply {op!r} to traced program field {self.name!r}: inside a @program "
            "step function fields may only be passed to compiled stencils (or to "
            "parallel.halo.request_exchange); do array math in a stencil, or outside "
            "the program."
        )

    return _fn


class TracedField:
    """A placeholder for one program field argument during tracing."""

    def __init__(self, name: str, value: Any):
        self.name = name
        self.value = value  # the concrete Storage / array the user passed
        self.version = 0

    @property
    def storage(self) -> Optional[Storage]:
        return self.value if isinstance(self.value, Storage) else None

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.value.shape)

    @property
    def dtype(self):
        return np.dtype(str(self.value.dtype))

    @property
    def axes(self) -> Tuple[str, ...]:
        if isinstance(self.value, Storage):
            return tuple(self.value.axes)
        return ("I", "J", "K")[: self.value.ndim]

    def __repr__(self) -> str:
        return f"TracedField({self.name}@{self.version}, shape={self.shape}, dtype={self.dtype})"

    __add__ = __radd__ = __sub__ = __rsub__ = _blocked("+/-")
    __mul__ = __rmul__ = __truediv__ = __rtruediv__ = _blocked("*//")
    __neg__ = __pos__ = __abs__ = _blocked("unary op")
    __getitem__ = __setitem__ = _blocked("indexing")

    def __array__(self, dtype=None):
        raise ProgramTraceError(
            f"traced program field {self.name!r} has no concrete values during tracing; "
            "convert to an array outside the @program step function."
        )


class TracedScalar:
    """A placeholder for one program scalar (keyword-only) argument."""

    def __init__(self, name: str, value: Any):
        self.name = name
        self.value = value

    @property
    def dtype(self) -> str:
        return str(np.dtype(type(self.value)) if not hasattr(self.value, "dtype") else self.value.dtype)

    def __repr__(self) -> str:
        return f"TracedScalar({self.name}={self.value!r})"

    def _no_math(self, *_a, **_k):
        raise ProgramTraceError(
            f"arithmetic on traced program scalar {self.name!r} is not recordable; "
            "precompute derived scalars outside the @program step function and pass "
            "them as their own keyword arguments."
        )

    __add__ = __radd__ = __sub__ = __rsub__ = _no_math
    __mul__ = __rmul__ = __truediv__ = __rtruediv__ = _no_math
    __neg__ = __float__ = __int__ = _no_math


# ---------------------------------------------------------------------------
# Trace nodes
# ---------------------------------------------------------------------------


class StencilNode:
    """One recorded stencil call: bindings of stencil params to program buffers."""

    def __init__(
        self,
        stencil: StencilObject,
        field_bind: Dict[str, str],  # stencil field param -> program buffer
        read_versions: Dict[str, int],  # buffer -> version consumed
        write_versions: Dict[str, int],  # buffer -> version produced
        scalar_bind: Dict[str, Tuple[str, Any]],  # param -> ('scalar', name) | ('const', value)
        domain: Tuple[int, int, int],
        origins: Dict[str, Tuple[int, int, int]],  # buffer -> resolved origin
    ):
        self.stencil = stencil
        self.field_bind = dict(field_bind)
        self.read_versions = dict(read_versions)
        self.write_versions = dict(write_versions)
        self.scalar_bind = dict(scalar_bind)
        self.domain = tuple(domain)
        self.origins = dict(origins)

    def __repr__(self) -> str:
        return (
            f"StencilNode({self.stencil.name}, bind={self.field_bind}, "
            f"writes={self.write_versions}, domain={self.domain})"
        )

    def structural_repr(self) -> str:
        """Stable description for the program fingerprint."""
        return "|".join(
            [
                self.stencil.name,
                self.stencil.fingerprint,
                repr(sorted(self.field_bind.items())),
                repr(sorted(self.read_versions.items())),
                repr(sorted(self.write_versions.items())),
                # const *values* are runtime-bound (never baked into generated
                # source), so only the binding kind participates in the hash
                repr(sorted((k, v[0], "" if v[0] == "const" else v[1]) for k, v in self.scalar_bind.items())),
                repr(self.domain),
                repr(sorted(self.origins.items())),
            ]
        )


class ExchangeNode:
    """An explicit halo-exchange request recorded mid-trace."""

    def __init__(self, buffer: str, version: int, halo: Optional[int]):
        self.buffer = buffer
        self.version = version
        self.halo = halo

    def __repr__(self) -> str:
        return f"ExchangeNode({self.buffer}@{self.version}, halo={self.halo})"

    def structural_repr(self) -> str:
        return f"exchange|{self.buffer}|{self.version}|{self.halo}"


# ---------------------------------------------------------------------------
# The trace itself
# ---------------------------------------------------------------------------


class Trace:
    def __init__(self, name: str):
        self.name = name
        self.fields: Dict[str, TracedField] = {}
        self.scalars: Dict[str, TracedScalar] = {}
        self.nodes: List[Any] = []
        # set by finish(): output name -> (buffer, version)
        self.outputs: Dict[str, Tuple[str, int]] = {}

    # -- handle creation ---------------------------------------------------

    def add_field(self, name: str, value: Any) -> TracedField:
        if name in self.fields or name in self.scalars:
            raise ProgramTraceError(f"duplicate program argument {name!r}")
        h = TracedField(name, value)
        self.fields[name] = h
        return h

    def add_scalar(self, name: str, value: Any) -> TracedScalar:
        if name in self.fields or name in self.scalars:
            raise ProgramTraceError(f"duplicate program argument {name!r}")
        s = TracedScalar(name, value)
        self.scalars[name] = s
        return s

    # -- recording ---------------------------------------------------------

    def record_stencil_call(self, st: StencilObject, args, kwargs, domain, origin) -> None:
        fields, scalars = st._bind(args, kwargs)
        field_bind: Dict[str, str] = {}
        read_versions: Dict[str, int] = {}
        concrete_values: Dict[str, Any] = {}
        for param, val in fields.items():
            if not isinstance(val, TracedField):
                raise ProgramTraceError(
                    f"stencil {st.name!r} called inside program {self.name!r} with a "
                    f"non-traced value for field {param!r} ({type(val).__name__}); every "
                    "field passed to a stencil inside a @program step must be one of the "
                    "program's field arguments."
                )
            if val is not self.fields.get(val.name):
                raise ProgramTraceError(
                    f"field handle {val.name!r} does not belong to program {self.name!r}"
                )
            field_bind[param] = val.name
            read_versions[val.name] = val.version
            concrete_values[param] = val.value
        scalar_bind: Dict[str, Tuple[str, Any]] = {}
        for param, val in scalars.items():
            if isinstance(val, TracedScalar):
                if val is not self.scalars.get(val.name):
                    raise ProgramTraceError(
                        f"scalar handle {val.name!r} does not belong to program {self.name!r}"
                    )
                scalar_bind[param] = ("scalar", val.name)
            elif isinstance(val, TracedField):
                raise ProgramTraceError(
                    f"program field {val.name!r} passed as scalar parameter {param!r} "
                    f"of stencil {st.name!r}"
                )
            else:
                scalar_bind[param] = ("const", val)
        # resolve geometry now (no validation — that happens per compiled key):
        # concrete sample values give shapes; Storage origins are honoured
        # exactly like the eager call path.
        origins3 = st._resolve_origins(concrete_values, origin)
        if domain is None:
            domain = st._deduce_domain(concrete_values, origins3)
        domain = tuple(int(d) for d in domain)
        buffer_origins = {field_bind[p]: o for p, o in origins3.items()}
        write_versions: Dict[str, int] = {}
        for param in _written_params(st):
            buf = field_bind[param]
            handle = self.fields[buf]
            handle.version += 1
            write_versions[buf] = handle.version
        self.nodes.append(
            StencilNode(st, field_bind, read_versions, write_versions, scalar_bind, domain, buffer_origins)
        )

    def record_exchange(self, field: TracedField, halo: Optional[int]) -> None:
        if field is not self.fields.get(field.name):
            raise ProgramTraceError(
                f"field handle {field.name!r} does not belong to program {self.name!r}"
            )
        self.nodes.append(ExchangeNode(field.name, field.version, halo))

    # -- finishing ---------------------------------------------------------

    def finish(self, result: Any) -> None:
        """Interpret the step function's return value as the output binding."""
        if result is None:
            raise ProgramTraceError(
                f"program {self.name!r} returned None: a @program step function must "
                "return its outputs (a field handle, a tuple of handles, or a dict "
                "mapping next-step argument names to handles for buffer rotation)."
            )
        if isinstance(result, TracedField):
            result = (result,)
        if isinstance(result, (tuple, list)):
            mapping = {}
            for h in result:
                if not isinstance(h, TracedField):
                    raise ProgramTraceError(
                        f"program {self.name!r} returned a non-field value {type(h).__name__}"
                    )
                mapping[h.name] = h
            result = mapping
        if not isinstance(result, dict):
            raise ProgramTraceError(
                f"program {self.name!r} returned {type(result).__name__}; expected field "
                "handle(s) or a dict of them"
            )
        outputs: Dict[str, Tuple[str, int]] = {}
        for out_name, h in result.items():
            if not isinstance(h, TracedField):
                raise ProgramTraceError(
                    f"program {self.name!r} output {out_name!r} is not a field handle"
                )
            if h is not self.fields.get(h.name):
                raise ProgramTraceError(
                    f"program {self.name!r} output {out_name!r} is a foreign field handle"
                )
            outputs[out_name] = (h.name, h.version)
        if not outputs:
            raise ProgramTraceError(f"program {self.name!r} returned no outputs")
        self.outputs = outputs

    def structural_repr(self) -> str:
        parts = [self.name]
        for name, h in sorted(self.fields.items()):
            parts.append(f"field|{name}|{h.shape}|{h.dtype}|{h.axes}")
        for name, s in sorted(self.scalars.items()):
            parts.append(f"scalar|{name}|{s.dtype}")
        parts.extend(n.structural_repr() for n in self.nodes)
        parts.append(repr(sorted(self.outputs.items())))
        return "\n".join(parts)


def _written_params(st: StencilObject) -> List[str]:
    """Stencil field params written by the stencil, in declaration order."""
    written = set(st.implementation_ir.written_api_fields())
    return [n for n in st.field_info if n in written]


# ---------------------------------------------------------------------------
# Hook plumbing (installed into repro.core.stencil on import of this module)
# ---------------------------------------------------------------------------

_active: List[Trace] = []


def active_trace() -> Optional[Trace]:
    return _active[-1] if _active else None


def _call_hook(st: StencilObject, args, kwargs, *, domain, origin):
    t = active_trace()
    if t is None:
        return NOT_TRACED
    if not any(isinstance(a, (TracedField, TracedScalar)) for a in (*args, *kwargs.values())):
        return NOT_TRACED  # fully concrete call made inside a trace: run eagerly
    # any traced value routes the call into the recorder — a mix of traced
    # scalars with concrete fields then gets the tracer's diagnostic instead
    # of a confusing validation error deep inside the eager path
    t.record_stencil_call(st, args, kwargs, domain, origin)
    return None


_stencil_mod.set_trace_hook(_call_hook)


def request_exchange(field: Any, halo: Optional[int] = None) -> Any:
    """Record an explicit halo exchange for ``field`` inside a @program trace.

    Outside a trace (or on a concrete array) this is a no-op returning the
    value unchanged — single-device eager semantics.  The distributed
    compiler honours the marker as a forced exchange point; the single-device
    compiler elides it.
    """
    t = active_trace()
    if t is not None and isinstance(field, TracedField):
        t.record_exchange(field, halo)
    return field


class tracing:
    """Context manager activating ``trace`` for the dynamic extent of a call."""

    def __init__(self, trace: Trace):
        self.trace = trace

    def __enter__(self) -> Trace:
        _active.append(self.trace)
        return self.trace

    def __exit__(self, *exc) -> None:
        _active.pop()
