"""Program orchestration: trace multi-stencil steps into one fused program.

BEYOND PAPER.  The paper's separation of concerns stops at the stencil
boundary — a time step composed of several compiled stencils still pays
Python dispatch, argument handling and device sync per call.  This package
lifts the toolchain one level: a ``@program``-decorated step function is
traced once (``trace``), its stencil calls become an inter-stencil dataflow
graph (``graph``), program-level passes eliminate dead stores, demote
step-local buffers to stencil temporaries and plan cross-stencil fusion
(``passes``), mesh-sharded execution gets a minimal halo-exchange schedule
(``halo``), and the result compiles to a single functionally-pure jitted
step cached under a graph fingerprint (``compile``)::

    from repro.program import program

    @program(backend="jax")
    def step(phi, u, v, adv, phi_new, *, dt, dx, dy):
        advect(phi, u, v, adv, dx=dx, dy=dy)
        euler(phi, adv, phi_new, dt=dt)
        return {"phi": phi_new, "phi_new": phi}   # double-buffer rotation

    step(phi, u, v, adv, phi_new, dt=..., dx=..., dy=...)   # one dispatch
    step.iterate(100, ...)                                   # one dispatch, 100 steps
    step.distribute(mesh)(global_fields, scalars)            # sharded, fused
"""

from .compile import (
    CompiledProgram,
    DistributedProgram,
    ProgramCompileError,
    ProgramObject,
    program,
)
from .trace import ProgramError, ProgramTraceError, request_exchange

__all__ = [
    "program",
    "ProgramObject",
    "CompiledProgram",
    "DistributedProgram",
    "ProgramError",
    "ProgramTraceError",
    "ProgramCompileError",
    "request_exchange",
]
