"""Distribution substrate: logical axis rules, sharding helpers, pipeline
parallelism, halo exchange for distributed stencils, gradient compression."""

from .sharding import (
    LogicalAxisRules,
    axis_rules,
    current_rules,
    logical_sharding,
    logical_spec,
    with_logical_constraint,
)

__all__ = [
    "LogicalAxisRules",
    "axis_rules",
    "current_rules",
    "logical_sharding",
    "logical_spec",
    "with_logical_constraint",
]
