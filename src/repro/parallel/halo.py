"""Halo exchange on the device mesh (shard_map + lax.ppermute).

The paper's multi-node story (GHEX, listed as future work) implemented
natively: the horizontal (i, j) plane is block-decomposed over two mesh
axes; each step exchanges H-deep stripes with the 4 (8 with corners)
neighbours, lowering to `collective-permute` on the ICI torus.

Non-periodic boundaries fall out of `ppermute` semantics for free: devices
with no sender receive zeros.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _perm_up(n: int, periodic: bool):
    """sender r → receiver r+1 (shifting data toward higher indices)."""
    pairs = [(r, r + 1) for r in range(n - 1)]
    if periodic and n > 1:
        pairs.append((n - 1, 0))
    return pairs


def _perm_down(n: int, periodic: bool):
    pairs = [(r + 1, r) for r in range(n - 1)]
    if periodic and n > 1:
        pairs.append((0, n - 1))
    return pairs


def request_exchange(field, halo: int = None):
    """Mark a halo-exchange point for ``field`` inside a ``@program`` trace.

    Inside a traced step function this records an explicit exchange the
    distributed program compiler must honour (``repro.program.halo``); on
    concrete data / outside a trace it is a no-op returning ``field``, so
    step functions run unchanged in eager single-device mode.
    """
    from repro.program.trace import request_exchange as _impl

    return _impl(field, halo)


def exchange_halo_2d(
    x: jax.Array,
    halo: int,
    i_axis: str,
    j_axis: str,
    i_size: int,
    j_size: int,
    periodic: Tuple[bool, bool] = (False, False),
) -> jax.Array:
    """Local block (ni, nj, ...) → haloed block (ni+2H, nj+2H, ...).

    Must run inside shard_map with ``i_axis``/``j_axis`` mesh axes.
    Corners are correct because the j-exchange ships already-i-padded
    stripes.
    """
    h = halo
    if h == 0:
        return x

    # ---- i-direction stripes
    lo_stripe = x[:h]  # goes to previous rank's high halo
    hi_stripe = x[-h:]  # goes to next rank's low halo
    from_prev = lax.ppermute(hi_stripe, i_axis, _perm_up(i_size, periodic[0]))
    from_next = lax.ppermute(lo_stripe, i_axis, _perm_down(i_size, periodic[0]))
    x = jnp.concatenate([from_prev, x, from_next], axis=0)

    # ---- j-direction stripes (includes i-halo rows → corners)
    lo_stripe = x[:, :h]
    hi_stripe = x[:, -h:]
    from_prev = lax.ppermute(hi_stripe, j_axis, _perm_up(j_size, periodic[1]))
    from_next = lax.ppermute(lo_stripe, j_axis, _perm_down(j_size, periodic[1]))
    return jnp.concatenate([from_prev, x, from_next], axis=1)
