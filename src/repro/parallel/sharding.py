"""Logical-axis sharding rules (MaxText/T5X-style).

Model code annotates activations and parameters with *logical* axis names
('batch', 'heads', 'embed', ...).  A :class:`LogicalAxisRules` context maps
those to physical mesh axes ('pod', 'data', 'model') per deployment, so the
same model definition runs on a laptop (no mesh), one pod (16×16) or the
multi-pod production mesh (2×16×16) without edits — the separation-of-
concerns argument of the paper applied to distribution.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, None, Tuple[str, ...]]


class LogicalAxisRules:
    """Ordered mapping logical-axis-name → mesh axis (or tuple of axes, or None)."""

    def __init__(self, rules: Sequence[Tuple[str, AxisName]]):
        self.rules: Dict[str, AxisName] = dict(rules)

    def mesh_axes(
        self,
        logical: Sequence[Optional[str]],
        mesh: Optional[Mesh] = None,
        shape: Optional[Sequence[int]] = None,
    ) -> P:
        """Translate logical axes to a PartitionSpec.

        Rules applied left-to-right with three safeguards that make one rule
        set serve every architecture (DESIGN.md §5):
        * axes not present in the mesh are dropped,
        * one mesh axis is never used for two tensor dims,
        * if ``shape`` is given, a mapping whose dim is not divisible by the
          mesh-axis size is dropped — e.g. 56 query heads or 8 kv heads on a
          16-way model axis fall through, letting a later dim (head_dim)
          pick the axis up instead.
        """
        used: set = set()
        out = []
        mesh_axis_names = set(mesh.axis_names) if mesh is not None else None
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}

        def _divides(dim_size: Optional[int], axes: Tuple[str, ...]) -> bool:
            if dim_size is None or mesh is None:
                return True
            total = 1
            for a in axes:
                total *= sizes.get(a, 1)
            return total > 0 and dim_size % total == 0

        for i, name in enumerate(logical):
            dim = None if shape is None else int(shape[i])
            if name is None:
                out.append(None)
                continue
            axis = self.rules.get(name)
            if axis is None:
                out.append(None)
                continue
            if isinstance(axis, tuple):
                ax = tuple(a for a in axis if a not in used and (mesh_axis_names is None or a in mesh_axis_names))
                if ax and _divides(dim, ax):
                    used.update(ax)
                    out.append(ax)
                else:
                    out.append(None)
            else:
                if (
                    axis in used
                    or (mesh_axis_names is not None and axis not in mesh_axis_names)
                    or not _divides(dim, (axis,))
                ):
                    out.append(None)
                else:
                    used.add(axis)
                    out.append(axis)
        # PartitionSpec trims trailing Nones automatically
        return P(*out)


# Default production rules: batch over (pod, data); model-parallel dims over
# model; sequence parallelism over data for batch-starved decode shapes.
DEFAULT_RULES = LogicalAxisRules(
    [
        ("batch", ("pod", "data")),
        ("seq", None),  # sequence usually replicated (activations)
        # context-parallel attention: q sequence over the model axis when
        # head counts don't divide it (beyond-paper optimization, §Perf)
        ("attn_seq", "model"),
        # decode KV caches: sequence-parallel over model (flash-decode style)
        ("kv_seq", "model"),
        ("embed", None),
        ("heads", "model"),
        ("kv_heads", "model"),
        # fallback TP axis: picks up 'model' when a head count does not
        # divide it (56H / 8KV / 14H archs) — contraction-dim sharding
        ("head_dim", "model"),
        ("mlp", "model"),
        ("experts", "model"),
        ("vocab", "model"),
        ("conv_io", None),
        ("ssm_heads", "model"),
        ("ssm_state", None),
        ("stage", "pipe"),
        # distributed stencils: horizontal plane decomposed over the mesh
        ("field_i", ("pod", "data")),
        ("field_j", "model"),
        # ensemble member axis (repro.ensemble): members shard over the pod
        # axis when present, composing with the field_i/field_j plane
        # decomposition — member x domain co-sharding; on meshes without a
        # pod axis the rule drops out and members stay vmap-batched locally
        ("member", "pod"),
    ]
)

_local = threading.local()


def current_rules() -> LogicalAxisRules:
    return getattr(_local, "rules", DEFAULT_RULES)


def current_mesh() -> Optional[Mesh]:
    return getattr(_local, "mesh", None)


@contextmanager
def axis_rules(rules: LogicalAxisRules, mesh: Optional[Mesh] = None):
    prev_rules = getattr(_local, "rules", None)
    prev_mesh = getattr(_local, "mesh", None)
    _local.rules = rules
    _local.mesh = mesh
    try:
        yield
    finally:
        if prev_rules is None:
            del _local.rules
        else:
            _local.rules = prev_rules
        _local.mesh = prev_mesh


def logical_spec(
    logical: Sequence[Optional[str]], mesh: Optional[Mesh] = None, shape: Optional[Sequence[int]] = None
) -> P:
    return current_rules().mesh_axes(logical, mesh or current_mesh(), shape)


def logical_sharding(
    logical: Sequence[Optional[str]], mesh: Optional[Mesh] = None, shape: Optional[Sequence[int]] = None
) -> NamedSharding:
    mesh = mesh or current_mesh()
    if mesh is None:
        raise ValueError("logical_sharding requires a mesh (use axis_rules(..., mesh=...))")
    return NamedSharding(mesh, logical_spec(logical, mesh, shape))


def with_logical_constraint(x, logical: Sequence[Optional[str]]):
    """Apply a sharding constraint if a mesh is active; no-op otherwise.

    Model code calls this everywhere; on a laptop (no mesh) it vanishes.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(logical, mesh, getattr(x, "shape", None))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
