"""repro — GT4Py-style performance-portable stencil DSL + multi-pod JAX
training/serving framework.

Weather & climate stencils (the paper's domain) use float64, so x64 is
enabled globally; all model/kernel code states dtypes explicitly (bf16/f32)
and is unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "0.1.0"
