"""Ensemble execution: member-batched programs, perturbations, statistics.

BEYOND PAPER.  The paper's separation of concerns is pitched at one
forecast; operational weather and climate products run *ensembles* — tens
of perturbed members whose spread is the product.  This package turns N
per-member Python dispatches into ONE ``jax.vmap``-batched jit dispatch of
the PR-3 ``@program`` layer::

    from repro import ensemble
    from repro.ensemble import Ensemble

    ens = Ensemble(climate_step, members=8)       # or climate_step.ensemble(8)
    phi0 = ensemble.perturb(phi, 8, seed=0, amplitude=1e-3)   # counter-based
    ens(phi0, u, v, ..., dt=dt)                   # 8 members, 1 dispatch
    ens.iterate(100, phi0, u, v, ..., dt=dt)      # 100 steps x 8 members, 1 dispatch
    stats = ens.statistics()                      # fused IR stencil
    stats(phi0, threshold=2.0)                    # mean/var/spread/min/max/prob
    ens.distribute(mesh, member_axis="ens")       # members x domain co-sharded

Modules: ``batch`` (member-batched storage allocation), ``perturb``
(counter-based ``jax.random`` member initialization), ``stats`` (fused
statistics emitted through the stencil IR), ``compile`` (the vmap-batched
ensemble compiler and member×domain sharding).
"""

from . import batch
from .batch import (
    EnsembleError,
    broadcast,
    from_member_arrays,
    gather_member,
    is_member_batched,
    member_view,
    scatter_members,
    storage_for_domain,
)
from .compile import DistributedEnsemble, Ensemble
from .perturb import member_keys, normal_noise, perturb, spread_inflation, uniform_noise
from .stats import STAT_FIELDS, EnsembleStatistics, build_ensemble_stats, stats_definition

__all__ = [
    "Ensemble",
    "DistributedEnsemble",
    "EnsembleError",
    "EnsembleStatistics",
    "STAT_FIELDS",
    "batch",
    "broadcast",
    "build_ensemble_stats",
    "from_member_arrays",
    "gather_member",
    "is_member_batched",
    "member_keys",
    "member_view",
    "normal_noise",
    "perturb",
    "scatter_members",
    "spread_inflation",
    "stats_definition",
    "storage_for_domain",
    "uniform_noise",
]
