"""Member-batched storages: allocation and member-axis plumbing.

An ensemble field is one :class:`repro.core.storage.Storage` whose leading
axis is the member axis ``N`` (``axes=("N", "I", "J", "K")``, origin 0 along
``N``).  Stencils and programs never see the member axis — the ensemble
compiler slices per-member views for compilation and batches execution with
``jax.vmap`` — so everything the single-member toolchain knows (halos,
origins, dtypes, (8, 128) alignment padding) is computed per member and is
identical between batched and unbatched allocations.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import numpy as np

from repro.core import storage as core_storage
from repro.core.storage import Storage
from repro.program.trace import ProgramError


class EnsembleError(ProgramError):
    """An ensemble was constructed or called inconsistently."""


MEMBER_AXIS = "N"


def batched_axes(axes: Sequence[str]) -> Tuple[str, ...]:
    if axes and axes[0] == MEMBER_AXIS:
        raise EnsembleError(f"axes {tuple(axes)} already carry a member axis")
    return (MEMBER_AXIS,) + tuple(axes)


def is_member_batched(value: Any) -> bool:
    return isinstance(value, Storage) and value.is_member_batched


def member_count(value: Any) -> Optional[int]:
    return value.members if isinstance(value, Storage) else None


def zeros(
    members, shape, dtype="float64", backend="numpy", default_origin=None, axes=None, alignment=None
) -> Storage:
    return _alloc_batched("zeros", members, shape, dtype, backend, default_origin, axes, alignment)


def ones(
    members, shape, dtype="float64", backend="numpy", default_origin=None, axes=None, alignment=None
) -> Storage:
    return _alloc_batched("ones", members, shape, dtype, backend, default_origin, axes, alignment)


def empty(
    members, shape, dtype="float64", backend="numpy", default_origin=None, axes=None, alignment=None
) -> Storage:
    return _alloc_batched("empty", members, shape, dtype, backend, default_origin, axes, alignment)


def _alloc_batched(fill, members, shape, dtype, backend, default_origin, axes, alignment) -> Storage:
    shape = tuple(int(s) for s in shape)
    if axes is None:
        axes = ("I", "J", "K")[: len(shape)]
    if default_origin is None:
        default_origin = (0,) * len(shape)
    return core_storage._alloc(
        (int(members),) + shape,
        dtype,
        backend,
        (0,) + tuple(default_origin),
        fill,
        batched_axes(axes),
        alignment,
    )


def storage_for_domain(
    members: int,
    domain: Tuple[int, int, int],
    halo: Tuple[int, int, int],
    dtype="float64",
    backend="numpy",
    fill="zeros",
    axes=("I", "J", "K"),
    alignment=None,
) -> Storage:
    """Member-batched twin of ``core.storage.storage_for_domain``."""
    return core_storage.storage_for_domain(
        domain, halo, dtype=dtype, backend=backend, fill=fill, axes=axes, alignment=alignment, members=int(members)
    )


def from_member_arrays(arrays, backend="numpy", default_origin=None, dtype=None, axes=None) -> Storage:
    """Stack per-member arrays (or per-member ``Storage``) into one batched
    storage — members must agree on shape and dtype."""
    raws = [np.asarray(a) for a in arrays]
    if not raws:
        raise EnsembleError("from_member_arrays() needs at least one member")
    if any(r.shape != raws[0].shape for r in raws):
        raise EnsembleError(f"member shapes disagree: {sorted({r.shape for r in raws})}")
    first = arrays[0]
    if isinstance(first, Storage):
        default_origin = default_origin if default_origin is not None else first.default_origin
        axes = axes if axes is not None else first.axes
    data = np.stack(raws, axis=0)
    if dtype is not None:
        data = data.astype(dtype)
    if axes is None:
        axes = ("I", "J", "K")[: raws[0].ndim]
    if default_origin is None:
        default_origin = (0,) * raws[0].ndim
    return Storage(
        data, backend=backend, default_origin=(0,) + tuple(default_origin), axes=batched_axes(axes)
    )


def broadcast(value: Any, members: int, backend=None) -> Storage:
    """Replicate one field across ``members`` identical members (the batched
    form of an unperturbed initial condition)."""
    if isinstance(value, Storage):
        backend = backend or value.backend
        data = np.broadcast_to(np.asarray(value.data), (int(members),) + tuple(value.shape)).copy()
        return Storage(
            data,
            backend=backend,
            default_origin=(0,) + tuple(value.default_origin),
            axes=batched_axes(value.axes),
        )
    arr = np.asarray(value)
    data = np.broadcast_to(arr, (int(members),) + arr.shape).copy()
    return Storage(
        data,
        backend=backend or "numpy",
        default_origin=(0,) * (arr.ndim + 1),
        axes=batched_axes(("I", "J", "K")[: arr.ndim]),
    )


def scatter_members(arrays, members: int, *, template: Storage, backend=None) -> Storage:
    """Scatter request-shaped arrays onto member slots of one batched storage.

    The serving path: ``arrays[i]`` (a plain per-request array shaped like
    ``template``) lands in member slot ``i``; slots ``len(arrays)..members-1``
    are padded with copies of the LAST array.  Padding is free correctness-wise
    because vmapped members are independent — padded members compute garbage
    nobody gathers — and it lets a partial batch reuse the jit artifact of the
    nearest tuned member count instead of compiling a new one per batch size.
    """
    arrays = list(arrays)
    if not arrays:
        raise EnsembleError("scatter_members() needs at least one request array")
    if len(arrays) > int(members):
        raise EnsembleError(f"cannot scatter {len(arrays)} requests onto {members} member slots")
    for i, a in enumerate(arrays):
        shape = tuple(np.asarray(a).shape)
        if shape != tuple(template.shape):
            raise EnsembleError(
                f"request array {i} has shape {shape}, template field expects {tuple(template.shape)}"
            )
    pad = [arrays[-1]] * (int(members) - len(arrays))
    return from_member_arrays(
        arrays + pad,
        backend=backend or template.backend,
        default_origin=template.default_origin,
        dtype=str(template.dtype),
        axes=template.axes,
    )


def gather_member(batched, m: int) -> np.ndarray:
    """Gather member ``m`` back out as a host numpy copy.

    The inverse of :func:`scatter_members` — used by the serving engine to
    peel request ``m``'s state out of a batched storage for streaming, so the
    returned array must not alias device or batch memory."""
    if isinstance(batched, Storage):
        if not batched.is_member_batched:
            raise EnsembleError(f"storage with axes {batched.axes} has no member axis to gather")
        return np.array(np.asarray(batched.member(int(m)).data), copy=True)
    return np.array(np.asarray(batched)[int(m)], copy=True)


def member_view(batched: Storage, m: int) -> Storage:
    """The per-member storage for member ``m`` (copy-free on numpy)."""
    return batched.member(m)


def member_sample(value: Any):
    """The member-0 view used to key/compile the single-member program."""
    if is_member_batched(value):
        return value.member(0)
    return value
