"""Ensemble compiler: N members of a ``@program`` in one batched dispatch.

``Ensemble(prog, members=N)`` turns the per-member step into a single
``jax.vmap``-batched, jit-cached dispatch:

1. the single-member program is compiled (and cached) exactly as if it were
   called on one member — ``Ensemble`` slices member-0 views out of the
   batched storages and reuses ``ProgramObject.compiled``, so the traced
   graph, program passes, fused groups, and generated orchestrator are all
   shared with the unbatched path;
2. the generated orchestrator's pure ``run`` is wrapped in ``jax.vmap``
   (member axis 0 for batched fields, broadcast for shared ones) and one
   ``jax.jit``: N members advance in ONE dispatch instead of N;
3. ``iterate(n)`` nests the vmapped step inside one ``lax.fori_loop`` — n
   steps × N members, still one dispatch;
4. the batched compilation is cached under a fingerprint that folds the
   member count and the batch pattern into the program fingerprint.

Fields may be member-batched (leading ``N`` axis — state being forecast) or
shared (no member axis — static forcing like winds or orography, broadcast
by vmap without materializing N copies).  Everything the program *writes*
must be batched: members would otherwise race on one buffer.

Scalars are shared by default; a 1-D array of length N is a *per-member*
scalar (e.g. a perturbed physics constant) and is mapped over.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import caching
from repro.core.storage import Storage
from repro.obs import trace as otrace
from repro.program.compile import CompiledProgram, DistributedProgram, ProgramObject
from repro.program.trace import ProgramError

from .batch import EnsembleError, member_sample
from .stats import EnsembleStatistics

_JAX_FAMILY = ("jax", "pallas")


class Ensemble:
    """N perturbed members of one program, advanced as a single dispatch."""

    def __init__(self, prog: ProgramObject, members: int, *, name: Optional[str] = None):
        if not isinstance(prog, ProgramObject):
            raise EnsembleError(f"Ensemble wraps a @program object, got {type(prog).__name__}")
        if prog.backend not in _JAX_FAMILY:
            raise EnsembleError(f"Ensemble requires the jax/pallas backends (vmap batching), not {prog.backend!r}")
        self.prog = prog
        self.members = int(members)
        if self.members < 1:
            raise EnsembleError(f"members must be positive, got {members}")
        self.name = name or f"{prog.name}_ens{self.members}"
        self._cache: Dict[Any, "_CompiledEnsemble"] = {}

    # -- binding / batching ------------------------------------------------

    def _bind(self, args, kwargs) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        return self.prog._bind(args, kwargs)

    def _batch_pattern(self, fields: Dict[str, Any]) -> Dict[str, bool]:
        pattern: Dict[str, bool] = {}
        for n, v in fields.items():
            batched = isinstance(v, Storage) and v.is_member_batched
            if batched and v.members != self.members:
                raise EnsembleError(f"field {n!r} holds {v.members} members, ensemble has {self.members}")
            pattern[n] = batched
        if not any(pattern.values()):
            raise EnsembleError(
                f"ensemble {self.name!r} called with no member-batched field: allocate "
                "state with repro.ensemble.batch (axes ('N', 'I', 'J', 'K')) or perturb()"
            )
        return pattern

    def _scalar_pattern(self, scalars: Dict[str, Any]) -> Dict[str, bool]:
        out: Dict[str, bool] = {}
        for n, v in scalars.items():
            per_member = getattr(v, "ndim", 0) == 1
            if per_member and int(v.shape[0]) != self.members:
                raise EnsembleError(
                    f"per-member scalar {n!r} has length {int(v.shape[0])}, "
                    f"ensemble has {self.members}"
                )
            out[n] = per_member
        return out

    # -- compilation -------------------------------------------------------

    def _key(self, fields: Dict[str, Any], pattern: Dict[str, bool]):
        """Cache key from metadata only — the hot path must not materialize
        member-0 device slices just to look up the compiled artifact."""
        parts = []
        for name in self.prog.field_params:
            v = fields[name]
            shape = tuple(v.shape)
            origin = tuple(v.default_origin) if isinstance(v, Storage) else None
            if pattern[name]:
                shape = shape[1:]
                origin = origin[1:] if origin is not None else None
            parts.append((name, shape, str(v.dtype), origin))
        return (tuple(parts), tuple(sorted(pattern.items())))

    def compiled(self, fields: Dict[str, Any], scalars: Dict[str, Any]) -> "_CompiledEnsemble":
        pattern = self._batch_pattern(fields)
        key = self._key(fields, pattern)
        ce = self._cache.get(key)
        if ce is None:
            samples = {n: member_sample(v) for n, v in fields.items()}
            cp = self.prog.compiled(samples, scalars)
            ce = _CompiledEnsemble(self, cp, pattern)
            self._cache[key] = ce
        return ce

    # -- execution ---------------------------------------------------------

    @staticmethod
    def _raw(value):
        return value.data if isinstance(value, Storage) else value

    def __call__(self, *args, exec_info: Optional[dict] = None, **kwargs) -> Dict[str, Any]:
        fields, scalars = self._bind(args, kwargs)
        ce = self.compiled(fields, scalars)
        raw = {n: self._raw(v) for n, v in fields.items()}
        outs, writes = ce.execute(raw, dict(scalars), exec_info)
        ProgramObject._writeback(fields, writes)
        ProgramObject._writeback(fields, outs)
        return outs

    def iterate(self, n: int, *args, exec_info: Optional[dict] = None, **kwargs) -> Dict[str, Any]:
        """n fused steps of all N members: ONE ``fori_loop`` dispatch."""
        fields, scalars = self._bind(args, kwargs)
        ce = self.compiled(fields, scalars)
        raw = {n_: self._raw(v) for n_, v in fields.items()}
        final = ce.execute_iterate(int(n), raw, dict(scalars), exec_info)
        ProgramObject._writeback(fields, {b: final[b] for b in fields if b in final})
        return {o: final[o] for o in ce.cp.outputs}

    # -- companions --------------------------------------------------------

    def statistics(self, dtype: str = "float64", **backend_opts: Any) -> EnsembleStatistics:
        """The fused statistics stencil sized for this ensemble."""
        return EnsembleStatistics(self.members, self.prog.backend, dtype=dtype, **backend_opts)

    def distribute(self, mesh, **kwargs) -> "DistributedEnsemble":
        return DistributedEnsemble(self, mesh, **kwargs)

    def __repr__(self) -> str:
        return f"Ensemble({self.prog.name!r}, members={self.members}, backend={self.prog.backend!r})"


class _CompiledEnsemble:
    """One batched specialization: (program geometry, batch pattern)."""

    def __init__(self, ensemble: Ensemble, cp: CompiledProgram, pattern: Dict[str, bool]):
        self.ensemble = ensemble
        self.cp = cp
        self.pattern = dict(pattern)
        self.members = ensemble.members
        shared = sorted(n for n, b in pattern.items() if not b)
        written = set(cp.written_buffers) | set(cp.outputs.values())
        # output names that rebind program fields receive batched values on
        # writeback, so they must be batched exactly like written buffers
        written |= {o for o in cp.outputs if o in pattern}
        bad = sorted(b for b in written if not pattern.get(b, False))
        if bad:
            raise EnsembleError(
                f"ensemble {ensemble.name!r}: program writes {bad}, but those fields are "
                "not member-batched — members would race on one shared buffer; allocate "
                "them with a leading 'N' axis (repro.ensemble.batch)"
            )
        self.fingerprint = caching.program_fingerprint(
            ensemble.name,
            cp.fingerprint,
            [cp.fingerprint],
            cp.backend,
            {"members": self.members, "batched": tuple(sorted(pattern.items()))},
        )
        self._group_runs = self._bind_group_runs()
        self._jit_cache: Dict[Any, Callable] = {}
        self._iter_cache: Dict[Any, Callable] = {}
        self.report = {
            "members": self.members,
            "batched_fields": sorted(n for n, b in pattern.items() if b),
            "shared_fields": shared,
            "fingerprint": self.fingerprint,
            "program_report": dict(cp.report),
        }

    def _bind_group_runs(self) -> List[Callable]:
        """Group runs with the pallas tile re-resolved for BATCHED operand
        shapes (the autotune store keys on the full geometry, so a batched
        run never reuses a tile tuned for unbatched shapes)."""
        cp = self.cp
        if cp.backend != "pallas":
            return list(cp._group_runs)
        runs: List[Callable] = []
        for obj, g in zip(cp.group_objects, cp.groups):
            run = obj._run
            shapes = []
            for b in g.buffers():
                if b not in obj.field_info:
                    continue
                shape = _member_shape(cp, b)
                if shape is None:
                    continue
                if self.pattern.get(b, False):
                    shape = (self.members,) + shape
                shapes.append((b, shape))
            block, _rec = obj._resolve_block(tuple(g.domain), shapes or None)
            if block is None:
                runs.append(run)
            else:
                runs.append(_with_block(run, tuple(block)))
        return runs

    def _axes(self, scalar_pattern: Dict[str, bool]):
        field_axes = {n: 0 if b else None for n, b in self.pattern.items()}
        scalar_axes = {n: 0 if b else None for n, b in scalar_pattern.items()}
        # runtime-bound const scalars are always shared
        scalar_axes.update({n: None for n in self.cp.const_scalars})
        return field_axes, scalar_axes

    def _jit(self, scalar_pattern: Dict[str, bool]) -> Callable:
        skey = tuple(sorted(scalar_pattern.items()))
        fn = self._jit_cache.get(skey)
        if fn is None:
            import jax

            module_run, group_runs = self.cp._module.run, self._group_runs
            field_axes, scalar_axes = self._axes(scalar_pattern)

            def _pure(fields, scalars):
                return module_run(fields, scalars, group_runs)

            fn = jax.jit(jax.vmap(_pure, in_axes=(field_axes, scalar_axes)))
            self._jit_cache[skey] = fn
        return fn

    def execute(
        self,
        raw_fields: Dict[str, Any],
        scalar_values: Dict[str, Any],
        exec_info: Optional[dict] = None,
    ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        scalars = self.cp.runtime_scalars(scalar_values)
        fn = self._jit(self.ensemble._scalar_pattern(scalar_values))
        if exec_info is not None:
            exec_info["ensemble_report"] = dict(self.report)
            exec_info["run_start_time"] = time.perf_counter()
        with otrace.span(
            "ensemble.dispatch", category="ensemble",
            ensemble=self.ensemble.name, members=self.members,
        ):
            outs, writes = fn(raw_fields, scalars)
        if exec_info is not None:
            for v in outs.values():
                v.block_until_ready()
            exec_info["run_end_time"] = time.perf_counter()
        return outs, writes

    def execute_iterate(
        self,
        n: int,
        raw_fields: Dict[str, Any],
        scalar_values: Dict[str, Any],
        exec_info: Optional[dict] = None,
    ) -> Dict[str, Any]:
        if self.cp.iterable_reason is not None:
            raise ProgramError(
                f"ensemble {self.ensemble.name!r} cannot iterate: {self.cp.iterable_reason}"
            )
        scalar_pattern = self.ensemble._scalar_pattern(scalar_values)
        ikey = (int(n), tuple(sorted(scalar_pattern.items())))
        steps = self._iter_cache.get(ikey)
        if steps is None:
            import jax
            from jax import lax

            module_run, group_runs = self.cp._module.run, self._group_runs
            field_axes, scalar_axes = self._axes(scalar_pattern)
            # only member-batched entries leave the loop: shared (broadcast)
            # fields must not come back N-replicated — vmap's out_axes=0
            # would hand every member's identical copy to the writeback
            keep = sorted(b for b, batched in self.pattern.items() if batched)

            def _steps(vals, scalars):
                def body(_i, vals):
                    outs, writes = module_run(vals, scalars, group_runs)
                    return {**vals, **writes, **outs}

                final = lax.fori_loop(0, n, body, vals)
                return {b: final[b] for b in keep}

            steps = jax.jit(jax.vmap(_steps, in_axes=(field_axes, scalar_axes)))
            self._iter_cache[ikey] = steps
        scalars = self.cp.runtime_scalars(scalar_values)
        if exec_info is not None:
            exec_info["ensemble_report"] = dict(self.report)
            exec_info["ensemble_report"]["iterated_steps"] = int(n)
            exec_info["run_start_time"] = time.perf_counter()
        with otrace.span(
            "ensemble.iterate", category="ensemble",
            ensemble=self.ensemble.name, members=self.members, steps=int(n),
        ):
            final = steps(raw_fields, scalars)
        if exec_info is not None:
            for v in final.values():
                v.block_until_ready()
            exec_info["run_end_time"] = time.perf_counter()
        return final


def _member_shape(cp: CompiledProgram, buffer: str) -> Optional[Tuple[int, ...]]:
    bi = cp.graph.buffers.get(buffer)
    if bi is None:
        return None
    return tuple(int(s) for s in bi.shape)


def _with_block(run: Callable, block: Tuple[int, int]) -> Callable:
    def _fn(fields, scalars, domain, origins):
        return run(fields, scalars, domain, origins, block=block)

    return _fn


# ---------------------------------------------------------------------------
# Member × domain sharding
# ---------------------------------------------------------------------------


class DistributedEnsemble:
    """Members × domain tiles co-sharded over a 3-D device mesh.

    The horizontal plane is block-decomposed exactly like
    :class:`~repro.program.compile.DistributedProgram` (same per-shard step,
    same minimal halo-exchange plan) while the member axis shards over
    ``member_axis``; within a shard the local members advance under
    ``jax.vmap``, which *batches the halo exchanges* — each planned
    ``ppermute`` ships one stripe carrying every local member instead of one
    collective per member.

    Call convention follows ``DistributedProgram``: a dict of GLOBAL
    interior-only arrays, member-batched fields with a leading ``N`` axis,
    shared fields without it.  For bare arrays only the rank-4
    ``(N, Ni, Nj, Nk)`` form is recognized as batched — a batched 2-D
    ``(I, J)`` field is rank-3 and indistinguishable from an unbatched
    volume, so it must be passed as a member-batched :class:`Storage`
    (whose axes disambiguate).
    """

    def __init__(
        self,
        ensemble: Ensemble,
        mesh,
        *,
        member_axis: str = "ens",
        i_axis: str = "data",
        j_axis: str = "model",
        periodic: Tuple[bool, bool] = (False, False),
    ):
        self.ensemble = ensemble
        self.dp = DistributedProgram(ensemble.prog, mesh, i_axis=i_axis, j_axis=j_axis, periodic=periodic)
        self.mesh = mesh
        self.member_axis = member_axis
        self.m_size = int(mesh.shape[member_axis])
        if ensemble.members % self.m_size:
            raise EnsembleError(
                f"{ensemble.members} members must tile over the {self.m_size}-way "
                f"{member_axis!r} mesh axis"
            )
        self._cache: Dict[Any, Tuple[Callable, dict]] = {}

    def __call__(
        self,
        fields: Dict[str, Any],
        scalars: Optional[Dict[str, Any]] = None,
        *,
        exec_info: Optional[dict] = None,
    ) -> Dict[str, Any]:
        scalars = dict(scalars or {})
        raw = {n: (v.data if isinstance(v, Storage) else v) for n, v in fields.items()}
        # member-0 global samples key/compile the per-member plan
        samples = {}
        batched = {}
        for n, v in raw.items():
            if isinstance(fields[n], Storage):
                b = fields[n].is_member_batched
            else:
                b = len(v.shape) == 4  # (N, Ni, Nj, Nk) bare-array convention
            batched[n] = b
            samples[n] = v[0] if b else v
        if not any(batched.values()):
            raise EnsembleError(
                f"distributed ensemble {self.ensemble.name!r} called with no member-batched "
                "field (expected a leading axis of length N on the forecast state)"
            )
        for n, b in batched.items():
            if b and int(raw[n].shape[0]) != self.ensemble.members:
                raise EnsembleError(
                    f"field {n!r} holds {int(raw[n].shape[0])} members, "
                    f"ensemble has {self.ensemble.members}"
                )
        local, geo_key = self.dp._geometry(samples)
        key = (geo_key, tuple(sorted(batched.items())))
        if key not in self._cache:
            self._cache[key] = self._compile(samples, scalars, local, batched, key)
        fn, report = self._cache[key]
        if exec_info is not None:
            exec_info["ensemble_report"] = dict(report)
            exec_info["run_start_time"] = time.perf_counter()
        out = fn(raw, scalars)
        if exec_info is not None:
            for v in out.values():
                v.block_until_ready()
            exec_info["run_end_time"] = time.perf_counter()
        return out

    def _compile(self, samples, scalars, local, batched, plan_key):
        import jax
        from jax.sharding import PartitionSpec as P

        from repro.stencils.distributed import shard_map

        plan = self.dp._plan_for(samples, scalars, local, plan_key)
        bad = sorted(b for o, b in plan.outputs.items() if not batched.get(b, False))
        if bad:
            raise EnsembleError(f"distributed ensemble outputs rebind {bad}, which are not member-batched")
        used = plan.used_inputs
        in_axes = {n: 0 if batched[n] else None for n in used}
        vstep = jax.vmap(lambda f, s: plan.run_groups(f, s)[1], in_axes=(in_axes, None))

        def body(local_fields, scalar_vals):
            return vstep(local_fields, scalar_vals)

        def spec(name: str, is_batched: bool):
            m = self.member_axis if is_batched else None
            return self.dp._spec_for(plan, name, m)

        in_specs = ({n: spec(n, batched[n]) for n in used}, P())
        out_specs = {o: spec(b, True) for o, b in plan.outputs.items()}
        shard_fn = jax.jit(shard_map(body, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs))

        def fn(all_fields, scalar_vals):
            return shard_fn({n: all_fields[n] for n in used}, scalar_vals)

        report = {
            "members": self.ensemble.members,
            "member_axis": self.member_axis,
            "members_per_shard": self.ensemble.members // self.m_size,
            "batched_fields": sorted(n for n, b in batched.items() if b),
            "program_report": dict(plan.report),
        }
        return fn, report
