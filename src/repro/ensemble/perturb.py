"""Counter-based, reproducible ensemble member perturbations.

Member initialization uses ``jax.random`` (threefry counter-based PRNG): the
key for member ``m`` is ``fold_in(base_key, m)``, so every member's noise is
a pure function of ``(seed, member index)`` — independent of member count,
evaluation order, batching, and sharding.  Member 7 of an 8-member ensemble
draws exactly the bytes member 7 of a 64-member ensemble would, which is
what makes ensemble experiments extendable and restartable.

Generators return member-batched :class:`~repro.core.storage.Storage`
(leading ``N`` axis) on the base field's backend; the numpy backends get the
same counter-based streams, materialized to host arrays.
"""

from __future__ import annotations

from typing import Any, Tuple

import numpy as np

from repro.core.storage import Storage

from .batch import EnsembleError, batched_axes


def base_key(seed: Any):
    """A PRNG key from an int seed (keys pass through unchanged)."""
    import jax

    if isinstance(seed, (int, np.integer)):
        return jax.random.PRNGKey(int(seed))
    return seed


def member_keys(seed: Any, members: int):
    """The per-member key array ``fold_in(key, m) for m in range(members)``."""
    import jax

    key = base_key(seed)
    return jax.vmap(lambda m: jax.random.fold_in(key, m))(np.arange(int(members)))


def normal_noise(seed: Any, members: int, shape: Tuple[int, ...], dtype="float64"):
    """Standard-normal noise of shape ``(members, *shape)``, counter-based."""
    import jax

    keys = member_keys(seed, members)
    return jax.vmap(lambda k: jax.random.normal(k, tuple(shape), dtype=dtype))(keys)


def uniform_noise(seed: Any, members: int, shape: Tuple[int, ...], dtype="float64"):
    """Uniform noise in [-1, 1) of shape ``(members, *shape)``."""
    import jax

    keys = member_keys(seed, members)
    return jax.vmap(
        lambda k: jax.random.uniform(k, tuple(shape), dtype=dtype, minval=-1.0, maxval=1.0)
    )(keys)


_KINDS = {"normal": normal_noise, "uniform": uniform_noise}


def perturb(
    base: Any,
    members: int,
    *,
    seed: Any = 0,
    amplitude: float = 1e-3,
    kind: str = "normal",
    relative: bool = False,
    perturb_member0: bool = True,
) -> Storage:
    """``members`` perturbed copies of ``base`` as one batched storage.

    ``base`` is a Storage or array holding the control initial condition;
    member ``m`` becomes ``base + amplitude · noise_m`` (``relative=True``
    scales the noise by ``|base|`` pointwise).  ``perturb_member0=False``
    keeps member 0 as the unperturbed control run — the usual operational
    ensemble layout.
    """
    gen = _KINDS.get(kind)
    if gen is None:
        raise EnsembleError(f"unknown perturbation kind {kind!r}; expected one of {sorted(_KINDS)}")
    members = int(members)
    if members <= 0:
        raise EnsembleError(f"members must be positive, got {members}")
    if isinstance(base, Storage):
        backend = base.backend
        origin: Tuple[int, ...] = tuple(base.default_origin)
        axes = tuple(base.axes)
        arr = np.asarray(base.data)
    else:
        backend = "numpy"
        arr = np.asarray(base)
        origin = (0,) * arr.ndim
        axes = ("I", "J", "K")[: arr.ndim]
    if axes and axes[0] == "N":
        raise EnsembleError("perturb() expects an unbatched base field")

    noise = np.array(gen(seed, members, arr.shape, dtype=str(arr.dtype)))
    if relative:
        noise = noise * np.abs(arr)[None]
    if not perturb_member0:
        noise[0] = 0.0
    data = arr[None] + float(amplitude) * noise
    return Storage(data, backend=backend, default_origin=(0,) + origin, axes=batched_axes(axes))


def spread_inflation(batched: Storage, factor: float) -> Storage:
    """Inflate member deviations about the ensemble mean by ``factor`` —
    the standard covariance-inflation knob, host-side (initialization-time).
    """
    if not batched.is_member_batched:
        raise EnsembleError("spread_inflation() expects a member-batched storage")
    arr = np.asarray(batched.data)
    mean = arr.mean(axis=0, keepdims=True)
    return Storage(
        mean + float(factor) * (arr - mean),
        backend=batched.backend,
        default_origin=batched.default_origin,
        axes=batched.axes,
    )
