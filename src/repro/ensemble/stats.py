"""Fused ensemble statistics, emitted through the stencil IR.

The spread of the ensemble *is* the forecast product, so the reductions over
the member axis (mean, variance, spread, member min/max, threshold-exceedance
probability) are not ad-hoc numpy: a statistics stencil is synthesized as a
normal Definition IR — one API input per member, all statistics computed in
one fused pointwise pass — and built through ``build_from_definition``, so it
rides the whole existing toolchain: the pass pipeline (constant folding, CSE,
temp demotion), the fingerprint cache, every backend, and ``exec_info``.

The member unroll is exact: N is a compile-time constant of the ensemble, so
``mean = (m0 + … + mN−1)/N`` is straight-line IR the backends vectorize, and
a different N is simply a different (cached) stencil.
"""

from __future__ import annotations

from functools import reduce
from typing import Any, Dict, Optional

import numpy as np

from repro.core import ir
from repro.core import stencil as stencil_mod
from repro.core import storage as core_storage
from repro.core.storage import Storage

from .batch import EnsembleError

#: statistics fields written by the synthesized stencil, in declaration order
STAT_FIELDS = ("mean", "var", "spread", "mn", "mx", "prob")


def _member_names(members: int):
    return [f"m{i}" for i in range(int(members))]


def stats_definition(
    members: int, dtype: str = "float64", name: Optional[str] = None
) -> ir.StencilDefinition:
    """The Definition IR of the fused N-member statistics stencil."""
    members = int(members)
    if members < 1:
        raise EnsembleError(f"statistics need at least one member, got {members}")
    mem = [ir.FieldAccess(n, (0, 0, 0)) for n in _member_names(members)]
    inv_n = ir.Literal(1.0 / members, "float")

    def acc(n: str) -> ir.FieldAccess:
        return ir.FieldAccess(n, (0, 0, 0))

    def total(terms) -> ir.Expr:
        return reduce(lambda a, b: ir.BinOp("+", a, b), terms)

    dev = [ir.BinOp("-", m, acc("mean")) for m in mem]
    exceed = [
        ir.TernaryOp(
            ir.BinOp(">", m, ir.ScalarRef("threshold")),
            ir.Literal(1.0, "float"),
            ir.Literal(0.0, "float"),
        )
        for m in mem
    ]
    body = (
        ir.Assign(acc("mean"), ir.BinOp("*", total(mem), inv_n)),
        ir.Assign(acc("var"), ir.BinOp("*", total([ir.BinOp("*", d, d) for d in dev]), inv_n)),
        ir.Assign(acc("spread"), ir.NativeCall("sqrt", (acc("var"),))),
        ir.Assign(acc("mn"), reduce(lambda a, b: ir.NativeCall("min", (a, b)), mem)),
        ir.Assign(acc("mx"), reduce(lambda a, b: ir.NativeCall("max", (a, b)), mem)),
        ir.Assign(acc("prob"), ir.BinOp("*", total(exceed), inv_n)),
    )
    member_decls = tuple(ir.FieldDecl(n, dtype, ir.AXES_IJK, is_api=True) for n in _member_names(members))
    stat_decls = tuple(ir.FieldDecl(n, dtype, ir.AXES_IJK, is_api=True) for n in STAT_FIELDS)
    return ir.StencilDefinition(
        name=name or f"ensemble_stats_{members}",
        api_fields=member_decls + stat_decls,
        scalars=(ir.ScalarDecl("threshold", dtype),),
        computations=(
            ir.ComputationBlock(
                ir.IterationOrder.PARALLEL,
                (ir.IntervalBlock(ir.VerticalInterval.full(), body),),
            ),
        ),
        docstring=f"fused {members}-member ensemble statistics",
    )


def build_ensemble_stats(
    members: int,
    backend: str,
    dtype: str = "float64",
    *,
    name: Optional[str] = None,
    validate_args: bool = True,
    **backend_opts: Any,
) -> stencil_mod.StencilObject:
    """Compile the fused statistics stencil for ``members`` members."""
    defn = stats_definition(members, dtype=dtype, name=name)
    return stencil_mod.build_from_definition(
        defn, backend, validate_args=validate_args, backend_opts=dict(backend_opts)
    )


class EnsembleStatistics:
    """Callable wrapper: member-batched storage → statistics storages.

    ``stats(batched, threshold=2.0)`` slices the N member views out of the
    batched storage, allocates (or reuses, via ``out=``) statistics storages
    of the same per-member geometry, and runs the fused stencil once over the
    full buffer — mean, variance, spread, member min/max, and
    P(member > threshold) in a single dispatch.
    """

    def __init__(self, members: int, backend: str, dtype: str = "float64", **backend_opts: Any):
        self.members = int(members)
        self.backend = backend
        self.dtype = dtype
        self.stencil = build_ensemble_stats(self.members, backend, dtype=dtype, **backend_opts)

    def __call__(
        self,
        batched: Storage,
        *,
        threshold: float = 0.0,
        out: Optional[Dict[str, Storage]] = None,
        exec_info: Optional[dict] = None,
    ) -> Dict[str, Storage]:
        if not isinstance(batched, Storage) or not batched.is_member_batched:
            raise EnsembleError("statistics expect a member-batched Storage (leading 'N' axis)")
        if batched.members != self.members:
            raise EnsembleError(
                f"storage holds {batched.members} members, statistics compiled for {self.members}"
            )
        if tuple(batched.axes[1:]) != ("I", "J", "K"):
            raise EnsembleError(
                f"statistics support ('N', 'I', 'J', 'K') storages, got axes {batched.axes}"
            )
        shape = tuple(batched.shape[1:])
        origin = tuple(batched.default_origin[1:])
        if out is None:
            out = {
                n: core_storage.zeros(shape, dtype=self.dtype, backend=self.backend, default_origin=origin)
                for n in STAT_FIELDS
            }
        fields: Dict[str, Any] = {n: batched.member(i) for i, n in enumerate(_member_names(self.members))}
        fields.update(out)
        # statistics are pointwise (extent zero): run over the whole buffer,
        # halo included, so downstream stencils can read stats in their halos
        self.stencil(
            **fields,
            threshold=np.dtype(self.dtype).type(threshold),
            domain=shape,
            origin=(0, 0, 0),
            exec_info=exec_info,
        )
        return out
