"""Exporters: span buffer → Chrome-trace/Perfetto JSON, optional jax.profiler.

The span dicts produced by :mod:`repro.obs.trace` convert to the Chrome
Trace Event format (the JSON flavor Perfetto, ``chrome://tracing`` and
``ui.perfetto.dev`` all load):

* a finished span → one complete event (``"ph": "X"``) with microsecond
  ``ts``/``dur``, its attributes and trace ids under ``args``;
* an in-span event → one instant event (``"ph": "i"``, thread-scoped);
* per-request correlation rides ``args.trace_ids`` on every event, so
  filtering a request id in the Perfetto query bar surfaces its admission,
  every batched dispatch it shared, and the retry/bisect instants that hit
  it.

:func:`validate_chrome_trace` is the schema contract the tests and the CI
extras leg assert against; ``python -m repro.obs.export TRACE.json``
validates a captured file from the command line and prints a span census.

:func:`jax_profiler_span` is the opt-in bridge to ``jax.profiler``: when jax
is importable it opens a ``TraceAnnotation`` so serving dispatches show up
inside an XLA device profile; otherwise (or on any profiler error) it is a
no-op — telemetry must never take the dispatch down.
"""

from __future__ import annotations

import json
import os
import sys
import threading
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence

from . import trace as trace_mod

#: event phases this exporter emits (and the validator accepts)
_PHASES = {"X", "i", "M"}


def chrome_trace(spans: Sequence[Dict[str, Any]],
                 *, process_name: str = "repro",
                 metadata: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Span dicts (``Tracer.snapshot()``) → a Chrome-trace JSON object."""
    pid = os.getpid()
    events: List[Dict[str, Any]] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": pid,
            "tid": 0,
            "args": {"name": process_name},
        }
    ]
    for sp in spans:
        start = float(sp["start_s"])
        end = float(sp["end_s"] if sp.get("end_s") is not None else start)
        tid = int(sp.get("tid", 0))
        args = dict(sp.get("attrs", {}))
        if sp.get("trace_ids"):
            args["trace_ids"] = list(sp["trace_ids"])
        args["span_id"] = sp.get("id")
        if sp.get("parent") is not None:
            args["parent_span_id"] = sp["parent"]
        if sp.get("instant"):
            events.append(
                {
                    "name": sp["name"],
                    "cat": sp.get("cat", "repro"),
                    "ph": "i",
                    "s": "t",
                    "ts": start * 1e6,
                    "pid": pid,
                    "tid": tid,
                    "args": args,
                }
            )
        else:
            events.append(
                {
                    "name": sp["name"],
                    "cat": sp.get("cat", "repro"),
                    "ph": "X",
                    "ts": start * 1e6,
                    "dur": max(0.0, (end - start) * 1e6),
                    "pid": pid,
                    "tid": tid,
                    "args": args,
                }
            )
        for ev in sp.get("events", ()):
            events.append(
                {
                    "name": ev["name"],
                    "cat": sp.get("cat", "repro"),
                    "ph": "i",
                    "s": "t",
                    "ts": float(ev["ts_s"]) * 1e6,
                    "pid": pid,
                    "tid": tid,
                    "args": {**dict(ev.get("attrs", {})), "span_id": sp.get("id")},
                }
            )
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": dict(metadata or {}),
    }


def write_chrome_trace(path, spans: Optional[Sequence[Dict[str, Any]]] = None,
                       *, tracer: Optional[trace_mod.Tracer] = None,
                       metadata: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Dump spans (default: the process tracer's buffer) to ``path``."""
    if spans is None:
        spans = (tracer or trace_mod.get_tracer()).snapshot()
    data = chrome_trace(spans, metadata=metadata)
    Path(path).write_text(json.dumps(data) + "\n")
    return data


def validate_chrome_trace(data: Any) -> List[Dict[str, Any]]:
    """Assert ``data`` is a loadable Chrome-trace object; returns its events.

    Raises ``ValueError`` naming the first offending event — this is the
    schema contract the telemetry tests and the CI trace-capture step check.
    """
    if not isinstance(data, dict) or not isinstance(data.get("traceEvents"), list):
        raise ValueError("chrome trace must be an object with a 'traceEvents' list")
    for i, ev in enumerate(data["traceEvents"]):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] missing {key!r}")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"traceEvents[{i}] has unknown phase {ev['ph']!r}")
        if ev["ph"] != "M" and not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"traceEvents[{i}] missing numeric 'ts'")
        if ev["ph"] == "X" and not isinstance(ev.get("dur"), (int, float)):
            raise ValueError(f"traceEvents[{i}] complete event missing numeric 'dur'")
    return data["traceEvents"]


def request_events(data: Dict[str, Any], trace_id: str) -> List[Dict[str, Any]]:
    """Every event correlated with ``trace_id`` (via ``args.trace_ids`` or a
    direct ``request_id`` attribute) — the per-request view of a trace."""
    out = []
    for ev in data.get("traceEvents", ()):
        args = ev.get("args", {})
        if trace_id in args.get("trace_ids", ()) or args.get("request_id") == trace_id:
            out.append(ev)
    return out


# ---------------------------------------------------------------------------
# jax.profiler bridge (optional)
# ---------------------------------------------------------------------------

_jax_profiler = None
_jax_probe_lock = threading.Lock()
_jax_probed = False


def jax_profiler_available() -> bool:
    global _jax_profiler, _jax_probed
    if not _jax_probed:
        with _jax_probe_lock:
            if not _jax_probed:
                try:
                    from jax import profiler as _prof  # noqa: PLC0415

                    _jax_profiler = _prof
                except Exception:  # noqa: BLE001 — no jax, no profiler hook
                    _jax_profiler = None
                _jax_probed = True
    return _jax_profiler is not None


@contextmanager
def jax_profiler_span(name: str):
    """Annotate the enclosed work in a jax/XLA profile when jax is present;
    transparently a no-op otherwise.

    Only the *annotation* is guarded: an exception raised by the wrapped
    block must propagate with its original type/message (retry-with-bisect
    keys off it), so the body is never re-yielded from an ``except`` branch —
    that would turn every dispatch failure into contextlib's
    ``RuntimeError("generator didn't stop after throw()")``.
    """
    ctx = None
    if jax_profiler_available():
        try:
            ctx = _jax_profiler.TraceAnnotation(name)
            ctx.__enter__()
        except Exception:  # noqa: BLE001 — profiling must never fail the dispatch
            ctx = None
    try:
        yield
    finally:
        if ctx is not None:
            try:
                ctx.__exit__(None, None, None)
            except Exception:  # noqa: BLE001, S110 — annotation teardown is best-effort
                pass


def _census(events: Iterable[Dict[str, Any]]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for ev in events:
        if ev.get("ph") == "M":
            continue
        counts[ev["name"]] = counts.get(ev["name"], 0) + 1
    return counts


def main(argv: Optional[Sequence[str]] = None) -> int:
    """``python -m repro.obs.export [--census-json] TRACE.json`` — validate
    and summarize a captured dump.

    The exit code is the contract: 0 only for a readable, schema-valid trace;
    1 with a one-line reason on stderr for anything unreadable or invalid —
    in EVERY mode, so the CI trace-validation leg can never silently pass on
    a missing or truncated dump.  ``--census-json`` prints the span census as
    one machine-readable JSON line (what the CI sampled-vs-unsampled
    comparison diffs)."""
    argv = list(sys.argv[1:] if argv is None else argv)
    census_json = "--census-json" in argv
    argv = [a for a in argv if a != "--census-json"]
    if len(argv) != 1 or argv[0].startswith("-"):
        print("usage: python -m repro.obs.export [--census-json] TRACE.json", file=sys.stderr)
        return 2
    path = Path(argv[0])
    try:
        events = validate_chrome_trace(json.loads(path.read_text()))
    except (OSError, ValueError) as e:
        print(f"INVALID trace {path}: {e}", file=sys.stderr)
        return 1
    census = _census(events)
    if census_json:
        print(json.dumps(
            {"path": str(path), "events": sum(census.values()), "names": census},
            sort_keys=True,
        ))
        return 0
    print(f"OK: {path} holds {len(events)} events, {len(census)} distinct names")
    for name in sorted(census):
        print(f"  {census[name]:6d}  {name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
