"""Per-program SLOs with multi-window burn-rate alerting, and the
autoscaling signal computed from them.

An SLO here is a declarative :class:`Objective` — "program ``climate_step``
serves 99.9% of requests without an error event", "p99 latency stays under
500 ms for at least 99% of traffic" — evaluated over the live
:class:`~repro.obs.metrics.MetricsRegistry` the serving engine already
maintains.  Nothing is double-counted: the SLO engine *reads* the same
per-program counters ``/metrics`` exports.

**Burn-rate math** (the Google SRE multi-window multi-burn-rate recipe).
Every objective has an *error budget*: the fraction of traffic allowed to be
bad (``1 - target`` for availability, ``target`` for an error-rate
objective, ``budget`` — default 1% — for a latency objective, whose "bad"
traffic is the requests that finished while the windowed p99 exceeded the
target).  The *burn rate* over a window is::

    burn = (bad traffic / total traffic in the window) / error budget

``burn == 1`` spends the budget exactly at the sustainable rate; ``burn ==
14.4`` exhausts a 30-day budget in ~2 days.  A single window either pages on
noise (short) or pages an hour late (long), so each :class:`BurnRule` pairs
a short and a long window and fires only when BOTH exceed its threshold:
the default rules are **fast** (5 m AND 1 h above 14.4× — a page) and
**slow** (30 m AND 6 h above 6× — a ticket).  Latency objectives can instead
evaluate over windows scaled to the serving engine's *batching window*
(:meth:`SloEngine.wire_batch_window`, called by the engine at construction):
latency badness is made of slow batching windows, so sizing the burn windows
in units of them makes a breach recovery observable within one evaluation
cycle of good traffic rather than five minutes later.  Breach *transitions*
emit
``slo.breach``/``slo.recovered`` trace instants, flip the
``serving_slo_breach{program=,objective=}`` gauge, and invoke ``on_breach``
(the engine points that at the flight recorder).

Evaluation is sample-driven and clock-injectable: :meth:`SloEngine.evaluate`
takes an explicit ``now`` so a seeded chaos run replays the exact same
breach timeline twice — the determinism the acceptance tests lock.

**Autoscaling signal** (:class:`Autoscaler`): the documented desired-replica
rule served on ``GET /autoscale``, fed by queue depth, occupancy-derived
utilization, and p99-vs-SLO-target pressure, hysteresis-damped so the
recommendation is immediate on the way up and deliberate on the way down.
See docs/observability.md for the rule, worked through.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import metrics as obs_metrics
from .trace import Tracer, monotonic

#: objective kinds and the registry families they read
AVAILABILITY = "availability"
ERROR_RATE = "error_rate"
LATENCY_P99 = "latency_p99"
_KINDS = (AVAILABILITY, ERROR_RATE, LATENCY_P99)

#: default fraction of traffic a latency objective allows past its target
LATENCY_BUDGET = 0.01


@dataclass(frozen=True)
class Objective:
    """One declarative objective over a program's served traffic.

    ``target`` means: availability → the good fraction (0.999); error_rate →
    the max bad fraction (0.001); latency_p99 → the p99 latency bound in
    seconds.  ``budget`` (bad-traffic fraction) is derived from the target
    for the ratio kinds and defaults to :data:`LATENCY_BUDGET` for latency.
    """

    name: str
    program: str
    kind: str
    target: float
    budget: Optional[float] = None
    description: str = ""

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown objective kind {self.kind!r}; one of {_KINDS}")

    def error_budget(self) -> float:
        if self.budget is not None:
            return max(1e-9, float(self.budget))
        if self.kind == AVAILABILITY:
            return max(1e-9, 1.0 - self.target)
        if self.kind == ERROR_RATE:
            return max(1e-9, self.target)
        return LATENCY_BUDGET


@dataclass(frozen=True)
class BurnRule:
    """Fire when burn exceeds ``max_burn`` over BOTH paired windows."""

    name: str
    short_s: float
    long_s: float
    max_burn: float


#: Google SRE defaults scaled to seconds: page fast, ticket slow
DEFAULT_RULES = (
    BurnRule("fast", short_s=300.0, long_s=3600.0, max_burn=14.4),
    BurnRule("slow", short_s=1800.0, long_s=21600.0, max_burn=6.0),
)


def default_objectives(program: str, *, availability: float = 0.999,
                       p99_s: float = 0.5) -> List["Objective"]:
    """The serve launcher's out-of-the-box SLOs for one program: 99.9%
    of requests error-free, p99 under half a second."""
    return [
        Objective(f"{program}-availability", program, AVAILABILITY, availability,
                  description=f"{availability:.1%} of {program} requests succeed"),
        Objective(f"{program}-latency", program, LATENCY_P99, p99_s,
                  description=f"{program} p99 latency under {p99_s * 1000:.0f} ms"),
    ]


class SloEngine:
    """Evaluate objectives against the metrics registry; track breaches."""

    def __init__(
        self,
        registry: obs_metrics.MetricsRegistry,
        objectives: Sequence[Objective] = (),
        *,
        tracer: Optional[Callable[[], Tracer]] = None,
        rules: Sequence[BurnRule] = DEFAULT_RULES,
        max_samples: int = 8192,
        on_breach: Optional[Callable[[Dict[str, Any]], None]] = None,
    ):
        self.registry = registry
        self.objectives: List[Objective] = []
        self.rules = tuple(rules)
        self.on_breach = on_breach
        self._tracer = tracer
        self.max_samples = int(max_samples)
        # per objective: cumulative (t, total, bad) samples; latency "bad"
        # traffic is self-accumulated from request deltas while the windowed
        # p99 sits above target (the registry only holds cumulative counters)
        self._samples: Dict[str, "deque[Tuple[float, float, float]]"] = {}
        self._breaching: Dict[str, bool] = {}
        # batch-window-scaled rules for LATENCY objectives only, armed by
        # wire_batch_window(); None means every kind uses self.rules
        self._latency_rules: Optional[Tuple[BurnRule, ...]] = None
        self.add(*objectives)

    def wire_batch_window(
        self,
        window_s: float,
        *,
        short_windows: float = 64.0,
        min_short_s: float = 0.25,
    ) -> "SloEngine":
        """Scale the **latency** objectives' burn windows to the engine's
        batching window instead of the 5-minute SRE defaults.

        A latency breach is made of requests that rode slow batching windows,
        so its natural evaluation timescale is the window length, not wall-
        clock minutes: with the short window at ``~64`` batching windows
        (floored at ``min_short_s`` so a 2 ms window doesn't evaluate over
        noise), one evaluation cycle after traffic goes good again the bad
        samples have aged out of the short window and the breach recovers —
        observable immediately, instead of waiting out five minutes of
        history.  Availability/error-rate objectives keep the default rules:
        their failure modes aren't paced by the batching window."""
        w = max(float(window_s), 1e-4)
        short = max(w * float(short_windows), float(min_short_s))
        self._latency_rules = (
            BurnRule("batch_fast", short_s=short, long_s=short * 8.0, max_burn=14.4),
            BurnRule("batch_slow", short_s=short * 4.0, long_s=short * 32.0, max_burn=6.0),
        )
        return self

    def rules_for(self, obj: Objective) -> Tuple[BurnRule, ...]:
        """The burn rules one objective evaluates under (latency objectives
        get the batch-window-scaled pair once :meth:`wire_batch_window` ran)."""
        if obj.kind == LATENCY_P99 and self._latency_rules is not None:
            return self._latency_rules
        return self.rules

    def add(self, *objectives: Objective) -> "SloEngine":
        """Register objectives after construction — programs arrive at the
        serving engine one ``register()`` at a time, and their SLOs with
        them.  Duplicate names replace (fresh sample ring)."""
        for obj in objectives:
            if obj.name in self._samples:
                self.objectives = [o for o in self.objectives if o.name != obj.name]
            self.objectives.append(obj)
            self._samples[obj.name] = deque(maxlen=self.max_samples)
            self._breaching[obj.name] = False
        return self

    # -- reads ---------------------------------------------------------------

    def _totals(self, obj: Objective) -> Tuple[float, float, Optional[float]]:
        """Cumulative (total, bad, p99) for one objective right now."""
        reg = self.registry
        total = reg.sum_value("serving_requests_total", program=obj.program)
        p99 = reg.quantile("serving_request_latency_seconds", 0.99, program=obj.program)
        if obj.kind == LATENCY_P99:
            return total, 0.0, p99  # bad accumulated in sample()
        bad = reg.sum_value("serving_errors_total", program=obj.program)
        return total, bad, p99

    def latency_pressure(self) -> Optional[float]:
        """Worst current p99/target ratio across latency objectives; None
        when no latency objective is armed or nothing has been observed."""
        ratios = []
        for obj in self.objectives:
            if obj.kind != LATENCY_P99:
                continue
            p99 = self.registry.quantile(
                "serving_request_latency_seconds", 0.99, program=obj.program
            )
            if p99 is not None and obj.target > 0:
                ratios.append(p99 / obj.target)
        return max(ratios) if ratios else None

    # -- sampling + burn math ------------------------------------------------

    def sample(self, now: Optional[float] = None) -> None:
        """Append one cumulative sample per objective (idempotent per ``now``:
        re-sampling the same instant replaces nothing and hurts nothing)."""
        now = monotonic() if now is None else float(now)
        for obj in self.objectives:
            ring = self._samples[obj.name]
            total, bad, p99 = self._totals(obj)
            if obj.kind == LATENCY_P99:
                prev_t, prev_total, prev_bad = ring[-1] if ring else (now, 0.0, 0.0)
                delta = max(0.0, total - prev_total)
                bad = prev_bad + (delta if (p99 is not None and p99 > obj.target) else 0.0)
            ring.append((now, total, bad))

    def _window_burn(self, obj: Objective, window_s: float, now: float) -> float:
        """Burn rate over ``[now - window_s, now]`` from the sample ring."""
        ring = self._samples[obj.name]
        if not ring:
            return 0.0
        t_end, total_end, bad_end = ring[-1]
        cutoff = now - window_s
        # the newest sample at-or-before the window start anchors the diff;
        # a window older than history falls back to "since the beginning"
        t0, total0, bad0 = ring[0]
        for t, total, bad in ring:
            if t <= cutoff:
                t0, total0, bad0 = t, total, bad
            else:
                break
        dt_total = total_end - total0
        if dt_total <= 0:
            return 0.0
        rate = max(0.0, bad_end - bad0) / dt_total
        return rate / obj.error_budget()

    # -- evaluation ----------------------------------------------------------

    def evaluate(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Sample, compute every rule's burn rates, fire breach transitions;
        returns the status dict ``GET /autoscale`` and ``/stats`` embed."""
        now = monotonic() if now is None else float(now)
        self.sample(now)
        out: List[Dict[str, Any]] = []
        for obj in self.objectives:
            rules = []
            breaching = False
            for rule in self.rules_for(obj):
                short = self._window_burn(obj, rule.short_s, now)
                long = self._window_burn(obj, rule.long_s, now)
                fired = short > rule.max_burn and long > rule.max_burn
                breaching = breaching or fired
                rules.append(
                    {
                        "rule": rule.name,
                        "max_burn": rule.max_burn,
                        "short_burn": short,
                        "long_burn": long,
                        "breaching": fired,
                    }
                )
                self.registry.gauge(
                    "serving_slo_burn_rate",
                    "error-budget burn rate per objective and window",
                    objective=obj.name,
                    program=obj.program,
                    window=f"{rule.name}_short",
                ).set(short)
                self.registry.gauge(
                    "serving_slo_burn_rate",
                    "error-budget burn rate per objective and window",
                    objective=obj.name,
                    program=obj.program,
                    window=f"{rule.name}_long",
                ).set(long)
            _, total, bad = (
                self._samples[obj.name][-1] if self._samples[obj.name] else (now, 0.0, 0.0)
            )
            status = {
                "objective": obj.name,
                "program": obj.program,
                "kind": obj.kind,
                "target": obj.target,
                "budget": obj.error_budget(),
                "breaching": breaching,
                "rules": rules,
                "totals": {"requests": total, "bad": bad},
            }
            out.append(status)
            self._transition(obj, status, now)
        return {"breaching": any(s["breaching"] for s in out), "objectives": out}

    def _transition(self, obj: Objective, status: Dict[str, Any], now: float) -> None:
        was, is_now = self._breaching[obj.name], status["breaching"]
        self._breaching[obj.name] = is_now
        self.registry.gauge(
            "serving_slo_breach",
            "1 while the objective's burn rate breaches a rule",
            objective=obj.name,
            program=obj.program,
        ).set(1.0 if is_now else 0.0)
        if is_now == was:
            return
        tracer = self._tracer() if self._tracer is not None else None
        worst = max(
            (r for r in status["rules"]),
            key=lambda r: (r["breaching"], min(r["short_burn"], r["long_burn"])),
        )
        if tracer is not None:
            tracer.event(
                "slo.breach" if is_now else "slo.recovered",
                category="slo",
                objective=obj.name,
                program=obj.program,
                kind=obj.kind,
                rule=worst["rule"],
                short_burn=worst["short_burn"],
                long_burn=worst["long_burn"],
            )
        if is_now and self.on_breach is not None:
            try:
                self.on_breach(status)
            except Exception:  # noqa: BLE001, S110 — alerting must never take serving down
                pass

    def status(self) -> Dict[str, Any]:
        """The last-evaluated breach state without re-sampling (flight
        recorder snapshots call this from failure paths)."""
        return {
            "breaching": any(self._breaching.values()),
            "objectives": [
                {
                    "objective": o.name,
                    "program": o.program,
                    "kind": o.kind,
                    "target": o.target,
                    "breaching": self._breaching[o.name],
                }
                for o in self.objectives
            ],
        }


# ---------------------------------------------------------------------------
# the autoscaling signal
# ---------------------------------------------------------------------------


class Autoscaler:
    """Desired-replica recommendation, hysteresis-damped.

    The rule (documented with a worked example in docs/observability.md):

    * ``backlog = queue_depth + inflight`` — member-slots of waiting work.
    * ``utilization = backlog / (replicas * max_batch)`` — how full the
      fleet's batch capacity is; the queue term asks for the replica count
      that brings utilization back to ``target_utilization``:
      ``queue_term = replicas * utilization / target_utilization``.
    * ``latency_term = replicas * min(p99/target, latency_ratio_cap)`` when a
      latency objective is armed, its p99 pressure exceeds 1, and scaling
      could plausibly help (capped so one outlier cannot demand the moon).
    * ``breach_term = replicas + 1`` while any SLO objective is in breach —
      an active burn-rate alert always asks for at least one more replica.
    * ``desired = clamp(ceil(max(terms)), min_replicas, max_replicas)``.

    Hysteresis: an *increase* publishes immediately (underprovisioning burns
    error budget); a *decrease* publishes only after ``down_stable_evals``
    consecutive evaluations agreed, and then steps down one replica at a
    time (flap damping).  The recommendation never self-applies — a future
    multi-replica supervisor consumes it and reports back via
    :meth:`observe_replicas`.
    """

    def __init__(
        self,
        *,
        replicas: int = 1,
        min_replicas: int = 1,
        max_replicas: int = 8,
        target_utilization: float = 0.75,
        latency_ratio_cap: float = 4.0,
        down_stable_evals: int = 3,
    ):
        self.replicas = max(1, int(replicas))
        self.min_replicas = max(1, int(min_replicas))
        self.max_replicas = max(self.min_replicas, int(max_replicas))
        self.target_utilization = float(target_utilization)
        self.latency_ratio_cap = float(latency_ratio_cap)
        self.down_stable_evals = max(1, int(down_stable_evals))
        self._down_streak = 0

    def observe_replicas(self, n: int) -> None:
        """Tell the rule what is actually running (resets flap damping only
        when the fleet really changed size)."""
        n = max(1, int(n))
        if n != self.replicas:
            self.replicas = n
            self._down_streak = 0

    def recommend(
        self,
        *,
        queue_depth: int,
        inflight: int,
        max_batch: int,
        latency_ratio: Optional[float] = None,
        breaching: bool = False,
    ) -> Dict[str, Any]:
        r = max(self.min_replicas, self.replicas)
        backlog = max(0, int(queue_depth)) + max(0, int(inflight))
        capacity = max(1, r * max(1, int(max_batch)))
        utilization = backlog / capacity
        terms: Dict[str, float] = {
            "queue": r * utilization / max(1e-9, self.target_utilization)
        }
        if latency_ratio is not None and latency_ratio > 1.0:
            terms["latency"] = r * min(latency_ratio, self.latency_ratio_cap)
        if breaching:
            terms["slo_breach"] = float(r + 1)
        raw = max(terms.values())
        # deterministic dominant-term name (ties break alphabetically)
        dominant = min(t for t, v in terms.items() if v == raw)
        candidate = max(self.min_replicas, min(self.max_replicas, math.ceil(raw - 1e-9)))

        if candidate >= r:
            self._down_streak = 0
            published = min(candidate, self.max_replicas)
            reason = (
                f"scale_up:{dominant}" if published > r else f"hold:{dominant}"
            )
        else:
            # flap damping: agree for down_stable_evals evaluations, then
            # step down exactly one replica
            self._down_streak += 1
            if self._down_streak >= self.down_stable_evals:
                self._down_streak = 0
                published = max(candidate, r - 1, self.min_replicas)
                reason = "scale_down:stable"
            else:
                published = r
                reason = f"hold:damping({self._down_streak}/{self.down_stable_evals})"

        return {
            "desired_replicas": int(published),
            "replicas": int(r),
            "reason": reason,
            "inputs": {
                "queue_depth": int(queue_depth),
                "inflight": int(inflight),
                "max_batch": int(max_batch),
                "utilization": utilization,
                "latency_ratio": latency_ratio,
                "breaching": bool(breaching),
            },
            "terms": {k: round(v, 4) for k, v in terms.items()},
        }
