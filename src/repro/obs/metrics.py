"""Metrics registry: counters, gauges, streaming-quantile histograms.

Zero-dependency (stdlib only) and Prometheus-text exportable — the serving
engine keeps every operational counter here (``engine.stats()`` is a *view*
of this registry), and the transport serves :func:`MetricsRegistry.to_prometheus`
on ``GET /metrics``.

Three instrument kinds:

* :class:`Counter` — monotonically increasing (requests, retries, bisects).
* :class:`Gauge` — a point-in-time level; either set explicitly or backed by
  a zero-argument callable evaluated at read time (queue depth, health
  state), so scrapes always see the live value without anyone having to
  remember to update it.
* :class:`Histogram` — streaming quantiles over a bounded window of recent
  observations (dispatch walls, request latency, batch occupancy) plus
  all-time ``count``/``sum``.  Exported as a Prometheus ``summary``
  (``{quantile="0.5"}`` samples + ``_sum``/``_count``); windowed nearest-rank
  quantiles are deterministic and allocation-bounded, which matters more
  here than sketch-grade accuracy.

Metric *families* are keyed by name; each family holds children keyed by
label values, created on first touch::

    reg = MetricsRegistry()
    reg.counter("serving_rejected_total", "requests rejected", reason="overloaded").inc()
    print(reg.to_prometheus())

Thread-safety: instrument updates take the registry lock (they happen on
the asyncio loop and executor threads alike); reads take it too so an
export never sees a half-updated histogram window.  The lock is reentrant:
:meth:`MetricsRegistry.to_prometheus` and :meth:`MetricsRegistry.collect`
hold it across the whole walk (a scrape concurrent with first-touch child
creation must not see the family dicts mid-mutation) while the per-child
reads they call take it again.
"""

from __future__ import annotations

import math
import re
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: quantiles exported for every histogram (summary-style)
QUANTILES = (0.5, 0.9, 0.99)


def _escape_label(value: str) -> str:
    return value.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt(value: float) -> str:
    if math.isnan(value):
        return "NaN"
    if math.isinf(value):
        return "+Inf" if value > 0 else "-Inf"
    return repr(float(value))


class Counter:
    """Monotonic counter (one labeled child of a counter family)."""

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Settable level, or a live read-through when built with ``fn``."""

    def __init__(self, lock: threading.RLock, fn: Optional[Callable[[], float]] = None):
        self._lock = lock
        self._value = 0.0
        self._fn = fn

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:  # noqa: BLE001 — a scrape must never take the server down
                return float("nan")
        return self._value


class Histogram:
    """All-time count/sum + nearest-rank quantiles over a recent window."""

    def __init__(self, lock: threading.RLock, window: int = 512):
        self._lock = lock
        self.count = 0
        self.sum = 0.0
        self._window: "deque[float]" = deque(maxlen=int(window))

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self.count += 1
            self.sum += value
            self._window.append(value)

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile of the retained window; NaN when empty."""
        with self._lock:
            if not self._window:
                return float("nan")
            ordered = sorted(self._window)
            rank = max(1, math.ceil(q * len(ordered)))
            return ordered[min(rank, len(ordered)) - 1]

    def summary(self) -> Dict[str, float]:
        with self._lock:  # count/sum/quantiles from ONE consistent snapshot
            out: Dict[str, float] = {"count": float(self.count), "sum": self.sum}
            # a never-observed histogram has NO quantiles, not NaN ones —
            # the keys are omitted so /stats JSON consumers don't choke
            if self._window:
                for q in QUANTILES:
                    out[f"p{int(q * 100)}"] = self.quantile(q)
            return out


class _Family:
    def __init__(self, name: str, help_text: str, kind: str):
        self.name = name
        self.help = help_text
        self.kind = kind  # "counter" | "gauge" | "summary"
        self.children: Dict[Tuple[Tuple[str, str], ...], Any] = {}


class MetricsRegistry:
    """Named metric families with labeled children; Prometheus-exportable."""

    def __init__(self):
        # reentrant: exports hold it across the family walk while the
        # per-child value/quantile reads take it again
        self._lock = threading.RLock()
        self._families: Dict[str, _Family] = {}

    def _family(self, name: str, help_text: str, kind: str) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(name, help_text, kind)
            self._families[name] = fam
        elif fam.kind != kind:
            raise ValueError(f"metric {name!r} already registered as a {fam.kind}")
        return fam

    @staticmethod
    def _label_key(labels: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"invalid label name {k!r}")
        return tuple(sorted((k, str(v)) for k, v in labels.items()))

    def _child(self, name: str, help_text: str, kind: str, labels: Dict[str, str], build):
        with self._lock:
            fam = self._family(name, help_text, kind)
            key = self._label_key(labels)
            child = fam.children.get(key)
            if child is None:
                child = build()
                fam.children[key] = child
            return child

    def counter(self, name: str, help_text: str = "", **labels: str) -> Counter:
        return self._child(name, help_text, "counter", labels, lambda: Counter(self._lock))

    def gauge(self, name: str, help_text: str = "",
              fn: Optional[Callable[[], float]] = None, **labels: str) -> Gauge:
        gauge = self._child(name, help_text, "gauge", labels, lambda: Gauge(self._lock, fn))
        if fn is not None:
            gauge._fn = fn  # re-registration refreshes a stale callback
        return gauge

    def histogram(self, name: str, help_text: str = "", window: int = 512,
                  **labels: str) -> Histogram:
        return self._child(name, help_text, "summary", labels,
                           lambda: Histogram(self._lock, window=window))

    # -- reads (the SLO engine and autoscaler sit on these) ------------------

    def read(self, name: str, **labels: str) -> List[Tuple[Dict[str, str], Any]]:
        """Children of family ``name`` whose labels include every given
        ``labels`` pair (subset match, so ``program="x"`` finds children that
        also carry a ``code`` label); ``[]`` for an unknown family."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return []
            want = {(k, str(v)) for k, v in labels.items()}
            return [
                (dict(key), child)
                for key, child in fam.children.items()
                if want <= set(key)
            ]

    def sum_value(self, name: str, **labels: str) -> float:
        """Sum of matching counter/gauge children (0.0 when none match) —
        how a per-program family with extra label dimensions rolls up."""
        return sum(
            child.value
            for _, child in self.read(name, **labels)
            if not isinstance(child, Histogram)
        )

    def quantile(self, name: str, q: float, **labels: str) -> Optional[float]:
        """The worst (max) ``q``-quantile across matching histogram children,
        or None when nothing has been observed yet."""
        vals = [
            child.quantile(q)
            for _, child in self.read(name, **labels)
            if isinstance(child, Histogram) and child.count
        ]
        vals = [v for v in vals if not math.isnan(v)]
        return max(vals) if vals else None

    def quantiles_by(self, name: str, q: float, label: str, **labels: str) -> Dict[str, float]:
        """The ``q``-quantile per value of ``label`` across matching histogram
        children (max within each group, same roll-up as :meth:`quantile`) —
        e.g. p99 request latency keyed by priority class."""
        groups: Dict[str, List[float]] = {}
        for child_labels, child in self.read(name, **labels):
            if not isinstance(child, Histogram) or not child.count or label not in child_labels:
                continue
            v = child.quantile(q)
            if math.isnan(v):
                continue
            groups.setdefault(child_labels[label], []).append(v)
        return {k: max(vs) for k, vs in sorted(groups.items())}

    # -- export -------------------------------------------------------------

    @staticmethod
    def _sample(name: str, labels: Sequence[Tuple[str, str]], value: float) -> str:
        if labels:
            body = ",".join(f'{k}="{_escape_label(v)}"' for k, v in labels)
            return f"{name}{{{body}}} {_fmt(value)}"
        return f"{name} {_fmt(value)}"

    def to_prometheus(self) -> str:
        """The Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        with self._lock:  # a scrape must not race first-touch child creation
            for name in sorted(self._families):
                fam = self._families[name]
                lines.append(f"# HELP {name} {fam.help}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for key, child in sorted(fam.children.items()):
                    labels = list(key)
                    if isinstance(child, Histogram):
                        # Prometheus-idiomatic empty summary: _sum/_count at
                        # zero, no quantile samples (never NaN — scrapers and
                        # the text-format parser both reject it)
                        if child._window:
                            for q in QUANTILES:
                                lines.append(
                                    self._sample(
                                        name, labels + [("quantile", str(q))], child.quantile(q)
                                    )
                                )
                        lines.append(self._sample(f"{name}_sum", labels, child.sum))
                        lines.append(self._sample(f"{name}_count", labels, child.count))
                    else:
                        lines.append(self._sample(name, labels, child.value))
        return "\n".join(lines) + "\n"

    def collect(self) -> Dict[str, Any]:
        """A JSON-friendly dump (what enriches ``/stats``): counters and
        gauges as numbers, histograms as their quantile summaries."""
        out: Dict[str, Any] = {}
        with self._lock:  # same discipline as to_prometheus()
            for name, fam in sorted(self._families.items()):
                entries: Dict[str, Any] = {}
                for key, child in sorted(fam.children.items()):
                    label = ",".join(f"{k}={v}" for k, v in key) or ""
                    value = child.summary() if isinstance(child, Histogram) else child.value
                    entries[label] = value
                out[name] = entries[""] if list(entries) == [""] else entries
        return out


#: process-default registry (the serving engine builds its own by default so
#: tests stay isolated; CLI/process-wide consumers can share this one)
default_registry = MetricsRegistry()
