"""Unified telemetry: request-correlated tracing, metrics, exporters, SLOs.

BEYOND PAPER.  The paper's separation of concerns (frontend → IR → passes →
backends, §2.3) pays off operationally only when an operator can see *where*
time goes across the layers it separates.  Production deployments of this
toolchain family (PACE, the ESCAPE dwarfs) treat per-kernel timing and
scaling telemetry as first-class outputs; this package is that substrate:

* :mod:`repro.obs.trace` — structured span tracer: nested spans on ONE
  monotonic clock, bounded ring-buffer retention, a strict no-op fast path
  when disabled, and per-request trace-id correlation (one batched dispatch
  span links every request that rode it).
* :mod:`repro.obs.sampling` — deterministic head-based trace sampling: the
  keep/drop decision is a pure hash of the request id, so the tracer can
  stay on in production at ``REPRO_TRACE_SAMPLE=0.1`` and a sampled-out
  request costs one hash check.  Error paths are always force-sampled.
* :mod:`repro.obs.metrics` — counters / gauges / streaming-quantile
  histograms behind a registry with Prometheus text export; the serving
  engine's ``stats()`` is a view of it and ``GET /metrics`` serves it.
* :mod:`repro.obs.slo` — declarative per-program service-level objectives
  evaluated with multi-window burn-rate math, plus the hysteresis-damped
  autoscaling recommendation served at ``GET /autoscale``.
* :mod:`repro.obs.flight` — the failure flight recorder: one self-contained
  JSON black box (spans + metrics + stats + config) dumped on worker death,
  crash-loop give-up, SLO breach, or SIGUSR2.
* :mod:`repro.obs.export` — Chrome-trace/Perfetto JSON dump + validation,
  and the optional ``jax.profiler`` annotation bridge.

Instrumented layers: stencil build (frontend → passes → codegen → autotune),
program trace/compile, ensemble dispatch, and the full serving request
lifecycle (admit → queue → window → scatter → dispatch → gather → emit).
Everything is off by default and ≈ free while off; arm with ``REPRO_TRACE=1``,
``serve --trace-out``, or per call via ``exec_info={"trace": True}``.
See docs/observability.md for the span taxonomy and metric names.
"""

from . import export, flight, metrics, sampling, slo, trace
from .export import chrome_trace, jax_profiler_span, validate_chrome_trace, write_chrome_trace
from .flight import FlightRecorder, load_bundle, validate_flight_bundle
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .sampling import SamplingPolicy, head_sampled
from .slo import Autoscaler, BurnRule, Objective, SloEngine
from .trace import NOOP_SPAN, Span, Tracer, capture, configure, monotonic, span, use_tracer

__all__ = [
    "Autoscaler",
    "BurnRule",
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NOOP_SPAN",
    "Objective",
    "SamplingPolicy",
    "SloEngine",
    "Span",
    "Tracer",
    "capture",
    "chrome_trace",
    "configure",
    "export",
    "flight",
    "head_sampled",
    "jax_profiler_span",
    "load_bundle",
    "metrics",
    "monotonic",
    "sampling",
    "slo",
    "span",
    "trace",
    "use_tracer",
    "validate_chrome_trace",
    "validate_flight_bundle",
    "write_chrome_trace",
]
