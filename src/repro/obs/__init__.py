"""Unified telemetry: request-correlated tracing, metrics, exporters.

BEYOND PAPER.  The paper's separation of concerns (frontend → IR → passes →
backends, §2.3) pays off operationally only when an operator can see *where*
time goes across the layers it separates.  Production deployments of this
toolchain family (PACE, the ESCAPE dwarfs) treat per-kernel timing and
scaling telemetry as first-class outputs; this package is that substrate:

* :mod:`repro.obs.trace` — structured span tracer: nested spans on ONE
  monotonic clock, bounded ring-buffer retention, a strict no-op fast path
  when disabled, and per-request trace-id correlation (one batched dispatch
  span links every request that rode it).
* :mod:`repro.obs.metrics` — counters / gauges / streaming-quantile
  histograms behind a registry with Prometheus text export; the serving
  engine's ``stats()`` is a view of it and ``GET /metrics`` serves it.
* :mod:`repro.obs.export` — Chrome-trace/Perfetto JSON dump + validation,
  and the optional ``jax.profiler`` annotation bridge.

Instrumented layers: stencil build (frontend → passes → codegen → autotune),
program trace/compile, ensemble dispatch, and the full serving request
lifecycle (admit → queue → window → scatter → dispatch → gather → emit).
Everything is off by default and ≈ free while off; arm with ``REPRO_TRACE=1``,
``serve --trace-out``, or per call via ``exec_info={"trace": True}``.
See docs/observability.md for the span taxonomy and metric names.
"""

from . import export, metrics, trace
from .export import chrome_trace, jax_profiler_span, validate_chrome_trace, write_chrome_trace
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import NOOP_SPAN, Span, Tracer, capture, configure, monotonic, span, use_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NOOP_SPAN",
    "Span",
    "Tracer",
    "capture",
    "chrome_trace",
    "configure",
    "export",
    "jax_profiler_span",
    "metrics",
    "monotonic",
    "span",
    "trace",
    "use_tracer",
    "validate_chrome_trace",
    "write_chrome_trace",
]
