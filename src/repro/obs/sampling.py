"""Head-based trace sampling: deterministic per-request-id keep decisions.

Always-on tracing is only viable in production if most requests cost almost
nothing to trace.  The head-based scheme here makes the keep/drop decision
once per request id, at the "head" of its story, from a hash of the id —
no coordination, no RNG state, and the same id samples the same way on every
process that sees it (a batched dispatch span kept on the engine is also
kept by any sidecar hashing the same ids):

* :func:`sample_unit` maps ``(seed, trace_id)`` → uniform [0, 1) via the same
  ``blake2b`` recipe the fault injector uses for per-site decisions — one
  short-string hash, no allocation beyond the digest.
* :class:`SamplingPolicy` holds a tracer's rate plus the *force-sampled*
  override set: error/bisect/deadline paths force a request's id so the tail
  of its story is retained even when the head hash said drop (tail-latency
  stories never get dropped).
* Spans carrying **no** trace ids (compile spans, batching windows before any
  link) are always kept — sampling is a per-request budget, not a global one.
* A span carrying **many** ids (one batched dispatch serves several requests)
  is kept iff *any* of its ids is sampled, so a sampled request always sees
  the shared batch spans it rode.

Arm process-wide with ``REPRO_TRACE_SAMPLE=0.1`` (read once per
:class:`~repro.obs.trace.Tracer` construction) or per tracer via
``Tracer(sample_rate=0.1)``.  Rate 1.0 (the default) keeps everything and
skips the hash entirely; rate 0.0 keeps only forced ids.
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from hashlib import blake2b
from typing import Iterable

#: force-sampled ids retained per policy — errors are rare, so this is a
#: backstop against a crash-looping client growing the set without bound,
#: not a knob anyone should need to raise
FORCED_CAPACITY = 4096


def sample_unit(trace_id: str, seed: int = 0) -> float:
    """Deterministic uniform-[0, 1) draw for one trace id."""
    digest = blake2b(f"{seed}:{trace_id}".encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big") / 2**64


def head_sampled(trace_id: str, rate: float, seed: int = 0) -> bool:
    """The pure head decision: hash the id, keep iff it lands under ``rate``."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    return sample_unit(str(trace_id), seed) < rate


def rate_from_env(default: float = 1.0) -> float:
    """``REPRO_TRACE_SAMPLE`` as a clamped [0, 1] rate; ``default`` when the
    variable is unset or unparseable (a typo must not silently disable
    tracing in production)."""
    raw = os.environ.get("REPRO_TRACE_SAMPLE", "")
    if not raw:
        return default
    try:
        rate = float(raw)
    except ValueError:
        return default
    return min(1.0, max(0.0, rate))


class SamplingPolicy:
    """One tracer's sampling state: the head rate plus forced-id overrides."""

    def __init__(self, rate: float = 1.0, *, seed: int = 0,
                 forced_capacity: int = FORCED_CAPACITY):
        self.rate = min(1.0, max(0.0, float(rate)))
        self.seed = int(seed)
        self.forced_capacity = int(forced_capacity)
        self._forced: "OrderedDict[str, None]" = OrderedDict()
        self._lock = threading.Lock()

    @property
    def always(self) -> bool:
        """True when every span is kept — the hash is skipped entirely."""
        return self.rate >= 1.0

    def force(self, *trace_ids: str) -> None:
        """Pin ids as always-sampled from now on (error paths call this the
        moment a request enters retry/bisect/deadline territory)."""
        with self._lock:
            for t in trace_ids:
                self._forced[str(t)] = None
                self._forced.move_to_end(str(t))
            while len(self._forced) > self.forced_capacity:
                self._forced.popitem(last=False)

    def is_forced(self, trace_id: str) -> bool:
        return str(trace_id) in self._forced

    def decide(self, trace_id: str) -> bool:
        """Keep/drop for one id: forced wins, else the head hash."""
        if self.rate >= 1.0:
            return True
        tid = str(trace_id)
        if tid in self._forced:
            return True
        return head_sampled(tid, self.rate, self.seed)

    def sampled(self, trace_ids: Iterable[str]) -> bool:
        """Keep/drop for a span: no ids → keep; any sampled id → keep."""
        if self.rate >= 1.0:
            return True
        ids = list(trace_ids)
        if not ids:
            return True
        return any(self.decide(t) for t in ids)
