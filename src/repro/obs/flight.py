"""Failure flight recorder: a self-contained JSON black box per incident.

When something goes wrong in a serving process — a worker death, a
crash-loop give-up, an SLO breach, or an operator poking SIGUSR2 — the
question is always the same: *what was happening just before?*  The flight
recorder answers it with one JSON bundle written at the moment of failure,
holding everything the process knows:

* ``spans``   — the most recent slice of the tracer's span ring (the black
  box's "cockpit voice recorder": admissions, batch dispatches, retries,
  bisects, deadline events — error paths are force-sampled, so the story of
  the request that killed the worker is in here even under heavy sampling);
* ``metrics`` — the full :class:`~repro.obs.metrics.MetricsRegistry` dump
  (per-program counters, burn-rate gauges, latency summaries);
* ``stats``   — the owner's stats snapshot (engine counters, health state,
  fault-injector tallies — whatever callable was bound);
* ``slo``     — the last-evaluated breach state, when an SLO engine is bound;
* ``config`` / ``versions`` — what was deployed, on what stack.

Bundles are written atomically (tmp + rename), pruned to ``max_bundles``,
and **dumping never raises** — a diagnostic must not be the second failure.
Arm with ``REPRO_FLIGHT_DIR=/path`` (the serving engine and the supervisor
both check it) or construct/bind explicitly.

Inspect from the command line::

    python -m repro.obs.flight BUNDLE.json              # validate + summary
    python -m repro.obs.flight BUNDLE.json --request ID # one request's story
    python -m repro.obs.flight A.json --diff B.json     # what changed

Exit codes: 0 valid, 1 unreadable/invalid (one-line reason on stderr),
2 usage — same contract as ``python -m repro.obs.export``.
"""

from __future__ import annotations

import json
import os
import platform
import sys
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from . import export as obs_export
from . import metrics as obs_metrics
from .trace import Tracer, monotonic

#: bundle schema tag; bump on breaking layout changes
SCHEMA = "repro.obs.flight/1"

#: keys every bundle must carry to validate
_REQUIRED = ("schema", "reason", "wall_time", "monotonic_s", "pid",
             "versions", "spans", "metrics", "stats")

_TracerSource = Union[Tracer, Callable[[], Tracer], None]


def _versions() -> Dict[str, Any]:
    import numpy as np

    import repro

    out: Dict[str, Any] = {
        "python": platform.python_version(),
        "numpy": np.__version__,
        "repro": getattr(repro, "__version__", "0"),
        "jax": None,
    }
    try:
        import jax

        out["jax"] = jax.__version__
    except Exception:  # noqa: BLE001, S110 — jax is optional everywhere else too
        pass
    return out


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion so a dump never dies on a numpy scalar or an
    exotic attr value sitting in a span."""
    try:
        json.dumps(obj)
        return obj
    except (TypeError, ValueError):
        pass
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item"):  # numpy scalars
        try:
            return obj.item()
        except Exception:  # noqa: BLE001
            pass
    return repr(obj)


class FlightRecorder:
    """Bind telemetry sources once; :meth:`dump` writes one bundle per call."""

    def __init__(
        self,
        out_dir: Union[str, Path],
        *,
        tracer: _TracerSource = None,
        metrics: Optional[obs_metrics.MetricsRegistry] = None,
        stats: Optional[Callable[[], Dict[str, Any]]] = None,
        slo: Any = None,
        config: Optional[Dict[str, Any]] = None,
        max_spans: int = 4096,
        max_bundles: int = 16,
    ):
        self.out_dir = Path(out_dir)
        self.max_spans = int(max_spans)
        self.max_bundles = int(max_bundles)
        self._tracer = tracer
        self._metrics = metrics
        self._stats = stats
        self._slo = slo
        self.config: Dict[str, Any] = dict(config or {})
        self._seq = 0
        self.last_bundle: Optional[Path] = None

    @classmethod
    def from_env(cls, env: Optional[Dict[str, str]] = None, **kwargs: Any) -> Optional["FlightRecorder"]:
        """A recorder targeting ``$REPRO_FLIGHT_DIR``, or None when unset —
        the same arming pattern as the fault injector's ``from_env``."""
        env = os.environ if env is None else env
        out_dir = env.get("REPRO_FLIGHT_DIR", "")
        if not out_dir:
            return None
        return cls(out_dir, **kwargs)

    def bind(
        self,
        *,
        tracer: _TracerSource = None,
        metrics: Optional[obs_metrics.MetricsRegistry] = None,
        stats: Optional[Callable[[], Dict[str, Any]]] = None,
        slo: Any = None,
        config: Optional[Dict[str, Any]] = None,
    ) -> "FlightRecorder":
        """Attach (or replace) telemetry sources after construction — the
        engine binds itself onto a recorder the CLI armed from the env."""
        if tracer is not None:
            self._tracer = tracer
        if metrics is not None:
            self._metrics = metrics
        if stats is not None:
            self._stats = stats
        if slo is not None:
            self._slo = slo
        if config is not None:
            self.config.update(config)
        return self

    # -- snapshotting --------------------------------------------------------

    def _resolve_tracer(self) -> Optional[Tracer]:
        t = self._tracer
        return t() if callable(t) else t

    def _section(self, fn: Callable[[], Any]) -> Any:
        """One guarded section: a failing source becomes an error note, not a
        failed dump."""
        try:
            return _jsonable(fn())
        except Exception as e:  # noqa: BLE001 — diagnostics must not cascade
            return {"error": f"{type(e).__name__}: {e}"}

    def snapshot(self, reason: str, extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """The bundle as a dict (every section individually guarded)."""
        self._seq += 1
        tracer = None
        try:
            tracer = self._resolve_tracer()
        except Exception:  # noqa: BLE001, S110
            pass
        spans: List[Dict[str, Any]] = []
        if tracer is not None:
            spans = self._section(tracer.snapshot)
            if isinstance(spans, list) and len(spans) > self.max_spans:
                spans = spans[-self.max_spans :]
        bundle: Dict[str, Any] = {
            "schema": SCHEMA,
            "reason": str(reason),
            "wall_time": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "monotonic_s": monotonic(),
            "pid": os.getpid(),
            "sequence": self._seq,
            "argv": list(sys.argv),
            "versions": self._section(_versions),
            "config": self._section(lambda: dict(self.config)),
            "spans": spans if isinstance(spans, list) else [],
            "metrics": self._section(self._metrics.collect) if self._metrics is not None else {},
            "stats": self._section(self._stats) if self._stats is not None else {},
            "slo": self._section(self._slo.status) if self._slo is not None else None,
            "extra": self._section(lambda: dict(extra or {})),
        }
        return bundle

    def dump(self, reason: str, extra: Optional[Dict[str, Any]] = None) -> Optional[Path]:
        """Write one bundle; returns its path, or None when writing failed
        (a flight recorder must never be the second failure)."""
        try:
            bundle = self.snapshot(reason, extra)
            self.out_dir.mkdir(parents=True, exist_ok=True)
            slug = "".join(c if c.isalnum() else "-" for c in str(reason))[:48].strip("-")
            stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
            path = self.out_dir / f"flight-{stamp}-p{os.getpid()}-{bundle['sequence']:03d}-{slug}.json"
            tmp = path.with_suffix(".tmp")
            tmp.write_text(json.dumps(bundle) + "\n")
            tmp.rename(path)
            self.last_bundle = path
            self._prune()
            return path
        except Exception:  # noqa: BLE001 — never raise out of a failure path
            return None

    def _prune(self) -> None:
        bundles = sorted(self.out_dir.glob("flight-*.json"))
        for old in bundles[: max(0, len(bundles) - self.max_bundles)]:
            try:
                old.unlink()
            except OSError:
                pass


# ---------------------------------------------------------------------------
# validation / inspection
# ---------------------------------------------------------------------------


def validate_flight_bundle(data: Any) -> Dict[str, Any]:
    """Assert ``data`` is a well-formed bundle; returns it.  Raises
    ``ValueError`` naming the first offence — the schema contract the chaos
    CI leg and the supervise tests assert against."""
    if not isinstance(data, dict):
        raise ValueError("flight bundle must be a JSON object")
    if data.get("schema") != SCHEMA:
        raise ValueError(f"unknown flight schema {data.get('schema')!r} (want {SCHEMA!r})")
    for key in _REQUIRED:
        if key not in data:
            raise ValueError(f"flight bundle missing {key!r}")
    if not isinstance(data["spans"], list):
        raise ValueError("flight bundle 'spans' must be a list")
    for i, sp in enumerate(data["spans"]):
        if not isinstance(sp, dict) or "name" not in sp:
            raise ValueError(f"spans[{i}] is not a span dict")
    if not isinstance(data["metrics"], dict):
        raise ValueError("flight bundle 'metrics' must be an object")
    if not isinstance(data["stats"], dict):
        raise ValueError("flight bundle 'stats' must be an object")
    return data


def load_bundle(path: Union[str, Path]) -> Dict[str, Any]:
    """Read + validate one bundle file (OSError/ValueError propagate)."""
    return validate_flight_bundle(json.loads(Path(path).read_text()))


def span_census(bundle: Dict[str, Any]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for sp in bundle.get("spans", ()):
        counts[sp["name"]] = counts.get(sp["name"], 0) + 1
    return counts


def request_story(bundle: Dict[str, Any], request_id: str) -> List[Dict[str, Any]]:
    """Every trace event correlated with one request id, in time order —
    the "what happened to req X" view of a bundle."""
    data = obs_export.chrome_trace(bundle.get("spans", ()))
    events = obs_export.request_events(data, request_id)
    return sorted(events, key=lambda ev: ev.get("ts", 0.0))


def _flatten(prefix: str, obj: Any, out: Dict[str, Any]) -> None:
    if isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, out)
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)


def diff_bundles(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, Any]:
    """Numeric metric/stat deltas and the span-census delta, a → b."""
    out: Dict[str, Any] = {"metrics": {}, "stats": {}, "spans": {}}
    for section in ("metrics", "stats"):
        fa: Dict[str, Any] = {}
        fb: Dict[str, Any] = {}
        _flatten("", a.get(section, {}), fa)
        _flatten("", b.get(section, {}), fb)
        for key in sorted(set(fa) | set(fb)):
            va, vb = fa.get(key, 0.0), fb.get(key, 0.0)
            if va != vb:
                out[section][key] = {"a": va, "b": vb, "delta": vb - va}
    ca, cb = span_census(a), span_census(b)
    for name in sorted(set(ca) | set(cb)):
        if ca.get(name, 0) != cb.get(name, 0):
            out["spans"][name] = {"a": ca.get(name, 0), "b": cb.get(name, 0)}
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    """``python -m repro.obs.flight BUNDLE.json [--diff OTHER] [--request ID]``."""
    argv = list(sys.argv[1:] if argv is None else argv)
    usage = "usage: python -m repro.obs.flight BUNDLE.json [--diff OTHER.json] [--request ID]"
    paths: List[str] = []
    diff_path: Optional[str] = None
    request_id: Optional[str] = None
    i = 0
    while i < len(argv):
        arg = argv[i]
        if arg == "--diff":
            i += 1
            if i >= len(argv):
                print(usage, file=sys.stderr)
                return 2
            diff_path = argv[i]
        elif arg == "--request":
            i += 1
            if i >= len(argv):
                print(usage, file=sys.stderr)
                return 2
            request_id = argv[i]
        elif arg.startswith("-"):
            print(usage, file=sys.stderr)
            return 2
        else:
            paths.append(arg)
        i += 1
    if len(paths) != 1:
        print(usage, file=sys.stderr)
        return 2
    try:
        bundle = load_bundle(paths[0])
    except (OSError, ValueError) as e:
        print(f"INVALID flight bundle {paths[0]}: {e}", file=sys.stderr)
        return 1
    if diff_path is not None:
        try:
            other = load_bundle(diff_path)
        except (OSError, ValueError) as e:
            print(f"INVALID flight bundle {diff_path}: {e}", file=sys.stderr)
            return 1
        print(json.dumps(diff_bundles(bundle, other), indent=2))
        return 0
    if request_id is not None:
        story = request_story(bundle, request_id)
        print(f"{len(story)} events for request {request_id!r} in {paths[0]}")
        for ev in story:
            print(f"  {ev.get('ts', 0.0) / 1e6:.6f}s  {ev['ph']:>2}  {ev['name']}")
        return 0
    census = span_census(bundle)
    print(
        f"OK: {paths[0]} — reason {bundle['reason']!r} at {bundle['wall_time']} "
        f"(pid {bundle['pid']}), {len(bundle['spans'])} spans, "
        f"{len(census)} distinct names"
    )
    for name in sorted(census):
        print(f"  {census[name]:6d}  {name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
