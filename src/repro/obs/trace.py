"""Structured span tracing with a strict no-op fast path.

One process-wide :class:`Tracer` records nested, wall-clocked spans into a
bounded ring buffer; exporters (``obs.export``) turn the buffer into
Chrome-trace/Perfetto JSON.  Design constraints, in order:

1. **Disabled ≈ free.**  Serving and stencil hot paths call ``span()``
   unconditionally; when tracing is off the call returns one shared
   :data:`NOOP_SPAN` singleton after a single attribute check — no
   allocation, no clock read, no buffer write.  ``tests/test_obs.py``
   asserts both the identity and a generous wall bound on a million
   disabled calls.
2. **One clock.**  :func:`monotonic` is THE time source for every latency,
   deadline, and span timestamp in the serving stack (engine, client,
   watchdog) — mixing ``time.time`` with ``perf_counter`` arithmetic is how
   deadline math silently breaks, so everything imports this one name.
3. **Bounded retention.**  Finished spans land in a ``deque(maxlen=...)``
   ring: a long-running server never grows without bound; exporters drain
   the most recent ``capacity`` spans.
4. **Async-safe nesting.**  The current span is a :mod:`contextvars` var, so
   parent/child links are correct across ``await`` points and threads
   (each asyncio task sees its own span stack).

Trace IDs are *request correlation*, not span identity: a span may carry
many ``trace_ids`` (one batched dispatch serves several requests), and every
span/event that touches a request lists its id — that is what lets one slow
request be followed through admission, the shared batch dispatches it rode,
and any retry/bisect events that hit it.

Enable globally with ``REPRO_TRACE=1`` (capacity via
``REPRO_TRACE_CAPACITY``), programmatically with :func:`configure`, or
locally/temporarily with :class:`capture` (used by the per-call
``exec_info={"trace": True}`` opt-in on stencils and programs).

Always-on production tracing rides head-based sampling
(:mod:`repro.obs.sampling`): ``REPRO_TRACE_SAMPLE=0.1`` /
``Tracer(sample_rate=0.1)`` drops spans whose trace ids all hash out, for
one hash check per id — while ``force=True`` events (the engine's
retry/bisect/deadline/error paths) both survive the gate and pin their ids
so the rest of those requests' stories are retained.
"""

from __future__ import annotations

import contextvars
import itertools
import os
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional

from . import sampling as _sampling

#: the ONE monotonic clock for spans, latencies, and deadlines (satellite:
#: no mixed time.time/perf_counter arithmetic across engine/client/watchdog)
monotonic = time.perf_counter


class Span:
    """One finished-or-open span; also its own context manager."""

    __slots__ = (
        "name",
        "category",
        "span_id",
        "parent_id",
        "trace_ids",
        "start_s",
        "end_s",
        "attrs",
        "events",
        "_tracer",
        "_token",
    )

    def __init__(self, tracer: "Tracer", name: str, category: str, span_id: int,
                 parent_id: Optional[int], trace_ids: List[str], attrs: Dict[str, Any]):
        self.name = name
        self.category = category
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace_ids = trace_ids
        self.start_s = monotonic()
        self.end_s: Optional[float] = None
        self.attrs = attrs
        self.events: List[Dict[str, Any]] = []
        self._tracer = tracer
        self._token: Optional[contextvars.Token] = None

    def set(self, key: str, value: Any) -> None:
        self.attrs[key] = value

    def event(self, name: str, **attrs: Any) -> None:
        """An instant event inside this span (rendered as an arrow/instant)."""
        self.events.append({"name": name, "ts_s": monotonic(), "attrs": attrs})

    def link(self, trace_id: str) -> None:
        """Correlate one more request/trace id with this span."""
        if trace_id not in self.trace_ids:
            self.trace_ids.append(trace_id)

    def __enter__(self) -> "Span":
        self._token = self._tracer._current.set(self)
        return self

    def __exit__(self, exc_type, exc, _tb) -> bool:
        if exc_type is not None:
            self.attrs["error"] = f"{exc_type.__name__}: {exc}"
        if self._token is not None:
            self._tracer._current.reset(self._token)
            self._token = None
        self._tracer._finish(self)
        return False

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "cat": self.category,
            "id": self.span_id,
            "parent": self.parent_id,
            "trace_ids": list(self.trace_ids),
            "start_s": self.start_s,
            "end_s": self.end_s,
            "attrs": dict(self.attrs),
            "events": [dict(e) for e in self.events],
            "tid": threading.get_ident(),
        }


class _NoopSpan:
    """The shared disabled-path span: every method is a constant no-op."""

    __slots__ = ()

    def set(self, key: str, value: Any) -> None:
        pass

    def event(self, name: str, **attrs: Any) -> None:
        pass

    def link(self, trace_id: str) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


#: singleton returned by every span() call while tracing is disabled
NOOP_SPAN = _NoopSpan()


class Tracer:
    """Span recorder: ring-buffered retention, contextvar nesting."""

    def __init__(self, *, enabled: bool = False, capacity: int = 65536,
                 sample_rate: Optional[float] = None, sample_seed: int = 0):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        # None → the REPRO_TRACE_SAMPLE env default (1.0: keep everything)
        if sample_rate is None:
            sample_rate = _sampling.rate_from_env()
        self.sampling = _sampling.SamplingPolicy(sample_rate, seed=sample_seed)
        self._spans: "deque[Dict[str, Any]]" = deque(maxlen=self.capacity)
        self._ids = itertools.count(1)
        self._current: contextvars.ContextVar[Optional[Span]] = contextvars.ContextVar(
            "repro_obs_current_span", default=None
        )
        self._lock = threading.Lock()

    @property
    def sample_rate(self) -> float:
        return self.sampling.rate

    def force_sample(self, *trace_ids: str) -> None:
        """Pin ids as always-sampled (error paths: the tail of a failing
        request's story must survive even when its head hashed out)."""
        self.sampling.force(*trace_ids)

    def keeps(self, trace_ids: Iterable[str]) -> bool:
        """Would a span carrying ``trace_ids`` be retained right now?  One
        hash check per id on the sampled-out path; constant-time at rate 1.0."""
        return self.enabled and self.sampling.sampled(trace_ids)

    # -- recording ----------------------------------------------------------

    def span(self, name: str, *, category: str = "repro",
             trace_id: Optional[str] = None, trace_ids: Iterable[str] = (),
             **attrs: Any):
        """Open a span (use as a context manager).  Disabled → NOOP_SPAN.
        Sampling: a span whose trace ids ALL hash out (none forced) is
        NOOP too — id-free spans (compiles, windows) are always kept."""
        if not self.enabled:
            return NOOP_SPAN
        ids = [str(t) for t in trace_ids]
        if trace_id is not None and str(trace_id) not in ids:
            ids.insert(0, str(trace_id))
        if ids and not self.sampling.sampled(ids):
            return NOOP_SPAN
        parent = self._current.get()
        return Span(
            self,
            name,
            category,
            next(self._ids),
            parent.span_id if parent is not None else None,
            ids,
            dict(attrs),
        )

    def event(self, name: str, *, category: str = "repro",
              trace_ids: Iterable[str] = (), force: bool = False,
              **attrs: Any) -> None:
        """A standalone instant event: attached to the current span when one
        is open, else recorded as a zero-duration entry of its own — so
        retry/bisect/fault markers survive even outside any span.

        ``force=True`` (the engine's error paths) bypasses the sampling gate
        AND pins the event's trace ids as force-sampled, so everything that
        happens to those requests from here on is retained."""
        if not self.enabled:
            return
        trace_ids = [str(t) for t in trace_ids]
        if force and trace_ids:
            self.sampling.force(*trace_ids)
        elif not force and not self.sampling.sampled(trace_ids):
            return
        current = self._current.get()
        if current is not None:
            ids = list(trace_ids)
            for t in ids:
                current.link(t)
            if ids:
                attrs = {**attrs, "trace_ids": ids}
            current.event(name, **attrs)
            return
        now = monotonic()
        self._record(
            {
                "name": name,
                "cat": category,
                "id": next(self._ids),
                "parent": None,
                "trace_ids": [str(t) for t in trace_ids],
                "start_s": now,
                "end_s": now,
                "attrs": dict(attrs),
                "events": [],
                "tid": threading.get_ident(),
                "instant": True,
            }
        )

    def add_span(self, name: str, start_s: float, end_s: float, *,
                 category: str = "repro", trace_ids: Iterable[str] = (),
                 force: bool = False, **attrs: Any) -> None:
        """Record a retroactive span from explicit timestamps (e.g. queue
        wait, measured between two points that no context manager brackets).
        ``force=True`` bypasses sampling and pins the ids, like
        :meth:`event`."""
        if not self.enabled:
            return
        trace_ids = [str(t) for t in trace_ids]
        if force and trace_ids:
            self.sampling.force(*trace_ids)
        elif trace_ids and not force and not self.sampling.sampled(trace_ids):
            return
        self._record(
            {
                "name": name,
                "cat": category,
                "id": next(self._ids),
                "parent": None,
                "trace_ids": [str(t) for t in trace_ids],
                "start_s": float(start_s),
                "end_s": float(end_s),
                "attrs": dict(attrs),
                "events": [],
                "tid": threading.get_ident(),
            }
        )

    def _record(self, entry: Dict[str, Any]) -> None:
        """Every retained-buffer write lands here, under the same lock that
        snapshot()/clear() take — recording happens from loop and executor
        threads alike, and the discipline must not silently rely on deque
        append atomicity."""
        with self._lock:
            self._spans.append(entry)

    def _finish(self, span: Span) -> None:
        span.end_s = monotonic()
        self._record(span.to_dict())

    # -- inspection ---------------------------------------------------------

    def snapshot(self) -> List[Dict[str, Any]]:
        """The finished spans currently retained (oldest first)."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def __len__(self) -> int:
        return len(self._spans)


# ---------------------------------------------------------------------------
# process default + contextvar override (capture)
# ---------------------------------------------------------------------------


def _env_enabled() -> bool:
    return os.environ.get("REPRO_TRACE", "") not in ("", "0")


_default = Tracer(
    enabled=_env_enabled(),
    capacity=int(os.environ.get("REPRO_TRACE_CAPACITY", "65536")),
)

_local: contextvars.ContextVar[Optional[Tracer]] = contextvars.ContextVar(
    "repro_obs_local_tracer", default=None
)


def get_tracer() -> Tracer:
    """The process-default tracer (ignores any :class:`capture` override)."""
    return _default


def current_tracer() -> Tracer:
    """The tracer module-level ``span()``/``event()`` route to: a
    :class:`capture` override in this context, else the process default."""
    local = _local.get()
    return local if local is not None else _default


def configure(*, enabled: Optional[bool] = None, capacity: Optional[int] = None,
              sample_rate: Optional[float] = None) -> Tracer:
    """Reconfigure the process-default tracer; returns it."""
    global _default
    if capacity is not None and capacity != _default.capacity:
        _default = Tracer(
            enabled=_default.enabled,
            capacity=capacity,
            sample_rate=_default.sample_rate,
        )
    if enabled is not None:
        _default.enabled = bool(enabled)
    if sample_rate is not None:
        _default.sampling = _sampling.SamplingPolicy(
            sample_rate, seed=_default.sampling.seed
        )
    return _default


def enabled() -> bool:
    return current_tracer().enabled


def span(name: str, **kwargs: Any):
    return current_tracer().span(name, **kwargs)


def event(name: str, **kwargs: Any) -> None:
    current_tracer().event(name, **kwargs)


class use_tracer:
    """Route this context's module-level :func:`span`/:func:`event` calls to
    an *existing* tracer (contrast :class:`capture`, which makes a fresh one).

    The serving engine uses this to pin its resolved tracer before snapshotting
    a :mod:`contextvars` context for an executor thread —
    ``loop.run_in_executor`` does not propagate contextvars, so without the
    pin the instrumented code running in the executor (e.g.
    ``ensemble.dispatch``/``ensemble.iterate`` spans) would silently land in
    the process-default tracer instead of the engine's or a capture()'s::

        with trace.use_tracer(tracer):
            ctx = contextvars.copy_context()
        await loop.run_in_executor(None, ctx.run, work)
    """

    def __init__(self, tracer: Tracer):
        self.tracer = tracer
        self._token: Optional[contextvars.Token] = None

    def __enter__(self) -> Tracer:
        self._token = _local.set(self.tracer)
        return self.tracer

    def __exit__(self, *exc: Any) -> bool:
        if self._token is not None:
            _local.reset(self._token)
            self._token = None
        return False


class capture:
    """Temporarily route this context's spans into a fresh enabled tracer.

    Powers the per-call trace opt-in (``exec_info={"trace": True}``): the
    instrumented code keeps calling module-level :func:`span`, and for the
    duration of the ``with`` block (in this task/thread only) those spans
    land in ``capture.tracer`` instead of the process default::

        with trace.capture() as t:
            stencil(...)
        chrome = export.chrome_trace(t.snapshot())
    """

    def __init__(self, capacity: int = 16384, sample_rate: float = 1.0):
        # a deliberate per-call capture defaults to keeping everything —
        # the env sampling knob governs the always-on process tracer only
        self.tracer = Tracer(enabled=True, capacity=capacity, sample_rate=sample_rate)
        self._token: Optional[contextvars.Token] = None

    def __enter__(self) -> Tracer:
        self._token = _local.set(self.tracer)
        return self.tracer

    def __exit__(self, *exc: Any) -> bool:
        if self._token is not None:
            _local.reset(self._token)
            self._token = None
        return False
