from .ops import hdiff

__all__ = ["hdiff"]
