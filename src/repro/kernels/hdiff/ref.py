"""Pure-jnp oracle for hdiff (hand-vectorized, independent of the DSL)."""

from __future__ import annotations

import jax.numpy as jnp


def hdiff_ref(in_phi, alpha, *, lim: float = 0.01):
    """in_phi: (NI+6, NJ+6, NK); returns full array with interior updated."""

    def lap(a):
        out = jnp.zeros_like(a)
        return out.at[1:-1, 1:-1, :].set(
            -4.0 * a[1:-1, 1:-1, :] + a[:-2, 1:-1, :] + a[2:, 1:-1, :]
            + a[1:-1, :-2, :] + a[1:-1, 2:, :]
        )

    def gx(a):
        out = jnp.zeros_like(a)
        return out.at[:-1, :, :].set(a[1:, :, :] - a[:-1, :, :])

    def gy(a):
        out = jnp.zeros_like(a)
        return out.at[:, :-1, :].set(a[:, 1:, :] - a[:, :-1, :])

    x = in_phi
    bilap = lap(lap(x))
    fx = gx(bilap)
    fy = gy(bilap)
    fx = jnp.where(fx * gx(x) > lim, fx, lim)
    fy = jnp.where(fy * gy(x) > lim, fy, lim)
    upd = x[3:-3, 3:-3, :] + alpha * (
        (fx[3:-3, 3:-3, :] - fx[2:-4, 3:-3, :]) + (fy[3:-3, 3:-3, :] - fy[3:-3, 2:-4, :])
    )
    return x.at[3:-3, 3:-3, :].set(upd)
