from .ops import vadv

__all__ = ["vadv"]
