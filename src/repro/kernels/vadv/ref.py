"""Pure-jnp Thomas-algorithm oracle (scan-based, independent of the DSL)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def vadv_ref(a, b, c, d):
    """Solve (a, b, c)·x = d along the last axis (tridiagonal, Thomas)."""

    def fwd(carry, abcd):
        cp_prev, dp_prev = carry
        a_k, b_k, c_k, d_k = abcd
        denom = b_k - a_k * cp_prev
        cp = c_k / denom
        dp = (d_k - a_k * dp_prev) / denom
        return (cp, dp), (cp, dp)

    abcd = (
        jnp.moveaxis(a, -1, 0),
        jnp.moveaxis(b, -1, 0),
        jnp.moveaxis(c, -1, 0),
        jnp.moveaxis(d, -1, 0),
    )
    zeros = jnp.zeros(a.shape[:-1], a.dtype)
    _, (cp, dp) = jax.lax.scan(fwd, (zeros, zeros), abcd)

    def bwd(x_next, cpdp):
        cp_k, dp_k = cpdp
        x = dp_k - cp_k * x_next
        return x, x

    _, xs = jax.lax.scan(bwd, zeros, (cp, dp), reverse=True)
    return jnp.moveaxis(xs, 0, -1)
