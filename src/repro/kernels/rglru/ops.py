"""jit'd wrapper: padding + dispatch for the RG-LRU scan kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import rglru_scan_bsd


@functools.partial(jax.jit, static_argnames=("bb", "bd", "chunk", "interpret"))
def rglru_scan(
    a: jax.Array,  # (B, S, D)
    b: jax.Array,
    h0: Optional[jax.Array] = None,
    *,
    bb: int = 8,
    bd: int = 512,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, S, D = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, D), a.dtype)
    bb_eff = min(bb, B)
    bd_eff = min(bd, D)
    chunk_eff = min(chunk, S)
    pad_b = (-B) % bb_eff
    pad_d = (-D) % bd_eff
    pad_s = (-S) % chunk_eff
    if pad_b or pad_d or pad_s:
        # pad decay with zeros: padded steps write b only, never corrupt state
        a = jnp.pad(a, ((0, pad_b), (0, pad_s), (0, pad_d)))
        b = jnp.pad(b, ((0, pad_b), (0, pad_s), (0, pad_d)))
        h0 = jnp.pad(h0, ((0, pad_b), (0, pad_d)))
    y = rglru_scan_bsd(a, b, h0, bb=bb_eff, bd=bd_eff, chunk=chunk_eff, interpret=interpret)
    return y[:B, :S, :D]
