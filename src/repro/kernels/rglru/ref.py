"""Pure-jnp oracle: associative-scan linear recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t · h_{t−1} + b_t over axis 1; a, b (B, S, D); h0 (B, D)."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    b32 = b32.at[:, 0, :].add(a32[:, 0, :] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(a.dtype)
