"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t·h_{t−1} + b_t.

This is the GT4Py ``computation(FORWARD)`` schedule on TPU (DESIGN.md §4):
sequential in time, fully vectorized over (batch, channel) planes.  The grid
is (B/BB, D/BD, S/CHUNK) with the trailing (time-chunk) dimension sequential,
carrying the hidden state in VMEM scratch across chunks — the same
plane-carried scheme the DSL's pallas backend uses for vertical solvers.
Within a chunk, a fori_loop steps the recurrence on (BB, BD) tiles in f32.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_scratch, *, chunk: int):
    sc = pl.program_id(2)

    @pl.when(sc == 0)
    def _init():
        h_scratch[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[:, t, :].astype(jnp.float32)
        b_t = b_ref[:, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        y_ref[:, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scratch[...])
    h_scratch[...] = h


def rglru_scan_bsd(
    a: jax.Array,  # (B, S, D) decay
    b: jax.Array,  # (B, S, D) input term
    h0: jax.Array,  # (B, D) initial state
    *,
    bb: int = 8,
    bd: int = 512,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, S, D = a.shape
    bb = min(bb, B)
    bd = min(bd, D)
    chunk = min(chunk, S)
    assert B % bb == 0 and D % bd == 0 and S % chunk == 0, "ops.py pads first"
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    grid = (B // bb, D // bd, S // chunk)
    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, chunk, bd), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bb, chunk, bd), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((bb, bd), lambda i, j, s: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, chunk, bd), lambda i, j, s: (i, s, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, h0)
