"""jax version compatibility helpers shared by the hand-written kernels."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (jax >= 0.5) / ``TPUCompilerParams`` (jax 0.4)."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
