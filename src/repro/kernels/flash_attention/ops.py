"""jit'd public wrapper: layout transform + padding around the Pallas kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "cap", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, Dh) — model layout
    k: jax.Array,  # (B, Skv, Kh, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset=0,
    kv_len=None,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    bq: int = 256,
    bk: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    b, sq, h, dh = q.shape
    skv = k.shape[1]

    bq_eff = min(bq, sq)
    bk_eff = min(bk, skv)
    pad_q = (-sq) % bq_eff
    pad_k = (-skv) % bk_eff

    qt = jnp.moveaxis(q, 2, 1)  # (B, H, S, Dh)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    eff_kv_len = kv_len if kv_len is not None else skv  # padded keys masked out

    o = flash_attention_bhsd(
        qt, kt, vt,
        causal=causal, q_offset=q_offset, kv_len=eff_kv_len,
        window=window, cap=cap, bq=bq_eff, bk=bk_eff, interpret=interpret,
    )
    if pad_q:
        o = o[:, :, :sq]
    return jnp.moveaxis(o, 1, 2)
