"""Pure-jnp oracle for the flash-attention kernel (no pallas imports)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, Skv, Kh, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset=0,
    kv_len=None,
    window: Optional[int] = None,
    cap: Optional[float] = None,
) -> jax.Array:
    b, sq, h, dh = q.shape
    kh, skv = k.shape[2], k.shape[1]
    qg = q.reshape(b, sq, kh, h // kh, dh).astype(jnp.float32)
    scale = float(1.0 / np.sqrt(dh))
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    if kv_len is not None:
        mask &= kpos[None, :] < kv_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)
