"""Pallas TPU flash-attention forward kernel (GQA, causal, window, kv_len).

Schedule: grid (B, H, Sq/BQ, Skv/BK) — the trailing (kv) grid dimension is
sequential on TPU, so the (acc, m, l) online-softmax state lives in VMEM
scratch and persists across kv steps; the output block is written once, on
the last kv step.  Causal/window masking skips whole kv blocks via pl.when
(the MXU never sees them); GQA folds the q-head group into the kv index
map.  All matmuls hit the MXU in f32 accumulation.

Layout: (B, H, S, Dh) — heads-major so q/k/v blocks are (BQ|BK, Dh) tiles,
lane-aligned for Dh ∈ {64, 96, 128, 160, 256}.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(
    # scalar-prefetch operands (SMEM)
    qoff_ref,  # (1,) int32: absolute position of q block row 0
    kvlen_ref,  # (1,) int32: valid kv length
    # tensor operands (VMEM blocks)
    q_ref,  # (1, 1, BQ, Dh)
    k_ref,  # (1, 1, BK, Dh)
    v_ref,  # (1, 1, BK, Dh)
    o_ref,  # (1, 1, BQ, Dh)
    # scratch
    acc_ref,  # (BQ, Dh) f32
    m_ref,  # (BQ, 128) f32  (lane-padded)
    l_ref,  # (BQ, 128) f32
    *,
    bq: int,
    bk: int,
    n_kv_blocks: int,
    causal: bool,
    window: Optional[int],
    cap: Optional[float],
    scale: float,
):
    qb = pl.program_id(2)
    kvb = pl.program_id(3)

    @pl.when(kvb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qoff_ref[0] + qb * bq  # absolute position of first q row
    k_start = kvb * bk
    kv_len = kvlen_ref[0]

    # block-level skip: entirely-masked kv blocks never touch the MXU
    live = k_start < kv_len
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, Dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (BQ, BK)
        if cap is not None:
            s = cap * jnp.tanh(s / cap)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (BQ, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (BQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)  # (BQ, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kvb == n_kv_blocks - 1)
    def _finalize():
        lse = l_ref[:, :1]
        o = acc_ref[...] / jnp.maximum(lse, 1e-37)
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, Dh)
    k: jax.Array,  # (B, Kh, Skv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset=0,
    kv_len=None,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    bq: int = 256,
    bk: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    b, h, sq, dh = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, "ops.py pads to block multiples"
    n_kv_blocks = skv // bk
    if interpret is None:
        interpret = jax.devices()[0].platform != "tpu"

    kernel = functools.partial(
        _flash_kernel,
        bq=bq,
        bk=bk,
        n_kv_blocks=n_kv_blocks,
        causal=causal,
        window=window,
        cap=cap,
        scale=float(1.0 / np.sqrt(dh)),
    )

    grid = (b, h, sq // bq, n_kv_blocks)

    qoff = jnp.asarray(q_offset, jnp.int32).reshape(1)
    klen = jnp.asarray(skv if kv_len is None else kv_len, jnp.int32).reshape(1)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, dh), lambda bb, hh, qb, kb, *_: (bb, hh, qb, 0)),
                pl.BlockSpec((1, 1, bk, dh), lambda bb, hh, qb, kb, *_: (bb, hh // g if g > 1 else hh, kb, 0)),
                pl.BlockSpec((1, 1, bk, dh), lambda bb, hh, qb, kb, *_: (bb, hh // g if g > 1 else hh, kb, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, dh), lambda bb, hh, qb, kb, *_: (bb, hh, qb, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, dh), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qoff, klen, q, k, v)
