"""Production meshes.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state — required for the smoke tests to keep seeing the
single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds the 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now, as a 1-D 'data' mesh (laptop/tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
