"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**,
regardless of trip count (verified: a 20-step scanned matmul reports the
flops of one matmul).  Scan-over-layers + microbatch-accumulation models are
therefore undercounted by orders of magnitude.  This module re-walks the
compiled HLO text, multiplying through the call graph:

* **flops** — every ``dot`` (2·|out|·|contraction|), descending into fusion
  bodies, ×trip for whiles;
* **bytes** — per *direct* op at fusion granularity (operands + outputs),
  matching XLA's bytes-accessed definition, ×trip;
* **collectives** — payload bytes per kind (all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute), ×trip.

While trip counts use the counted-loop pattern jax emits: the condition
computation compares the induction variable against a constant; we take the
largest integer constant in the condition.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\/]+?))\s+([\w\-]+)\(",
)

_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_info(type_str: str) -> Tuple[int, List[List[int]]]:
    """bytes + list of dim-lists for (possibly tuple) type string."""
    total = 0
    dims_list = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        dims_list.append(dims)
    return total, dims_list


@dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    line: str
    out_bytes: int = 0
    out_dims: List[List[int]] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, Tuple[int, List[List[int]]]] = field(default_factory=dict)


def parse_hlo_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        # computation header: `%name (args) -> type {`  or `ENTRY %name ...{`
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
            if m:
                current = Computation(m.group(1))
                comps[current.name] = current
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, out_type, opcode = m.group(1), m.group(2), m.group(3)
        nbytes, dims = _shape_info(out_type)
        op = Op(name=name, out_type=out_type, opcode=opcode, line=line,
                out_bytes=nbytes, out_dims=dims)
        current.ops.append(op)
        current.shapes[name] = (nbytes, dims)
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count of a jax-emitted counted loop: the constant operand of the
    condition's compare op (falling back to the largest constant present)."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            args = op.line[op.line.index("(") :].split(")", 1)[0]
            for m in _OPERAND_RE.finditer(args):
                if m.group(1) in consts:
                    return max(1, consts[m.group(1)])
    return max([1] + list(consts.values()))


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems = 1
    for dims in op.out_dims:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    contract = 1
    if m:
        lhs_name_m = _OPERAND_RE.search(op.line[op.line.index("("):])
        if lhs_name_m:
            lhs = comp.shapes.get(lhs_name_m.group(1))
            if lhs and lhs[1]:
                lhs_dims = lhs[1][0]
                for idx_s in m.group(1).split(","):
                    if idx_s:
                        idx = int(idx_s)
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    # control flow: the call-site operands are loop carries / branch args,
    # not HBM traffic — the bodies are walked instead
    "while", "call", "conditional",
}


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo_module(text)
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
        entry = None
        for name, comp in self.comps.items():
            if name.startswith("main") or entry is None:
                if name.startswith("main"):
                    entry = name
        self.entry = entry or next(iter(self.comps))

    def _operand_bytes(self, comp: Computation, op: Op) -> int:
        total = 0
        args = op.line[op.line.index("(") :]
        args = args.split(")", 1)[0]
        for m in _OPERAND_RE.finditer(args):
            info = comp.shapes.get(m.group(1))
            if info:
                total += info[0]
        return total

    def cost_of(self, comp_name: str) -> Tuple[float, float, Dict[str, float]]:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0, 0.0, {}
        self._memo[comp_name] = (0.0, 0.0, {})  # cycle guard
        flops = 0.0
        bytes_ = 0.0
        colls: Dict[str, float] = {}

        for op in comp.ops:
            code = op.opcode
            if code in ("dot", "convolution"):
                flops += _dot_flops(comp, op)
            ck = next((c for c in _COLLECTIVES if code.startswith(c)), None)
            if ck is not None and not code.endswith("-done"):
                colls[ck] = colls.get(ck, 0.0) + op.out_bytes

            if code not in _SKIP_BYTES_OPS and not code.endswith("-done"):
                bytes_ += op.out_bytes + self._operand_bytes(comp, op)

            # descend
            called = _CALLS_RE.findall(op.line)
            if called:
                mult = 1
                if code == "while":
                    cm = _COND_RE.search(op.line)
                    if cm and cm.group(1) in self.comps:
                        mult = _trip_count(self.comps[cm.group(1)])
                for sub in called:
                    if sub == comp_name:
                        continue
                    f, b, c = self.cost_of(sub)
                    flops += mult * f
                    # fusion bodies execute register/VMEM-resident: their HBM
                    # traffic is the call-site operands+outputs (counted
                    # above) — descending for bytes would double-count every
                    # fused elementwise op at full tensor size.
                    if code != "fusion":
                        bytes_ += mult * b
                    for k, v in c.items():
                        colls[k] = colls.get(k, 0.0) + mult * v

        self._memo[comp_name] = (flops, bytes_, colls)
        return self._memo[comp_name]

    def totals(self) -> Dict[str, object]:
        flops, bytes_, colls = self.cost_of(self.entry)
        return {"flops": flops, "bytes": bytes_, "collectives": colls}


def analyze_hlo_text(text: str) -> Dict[str, object]:
    return HloCost(text).totals()
