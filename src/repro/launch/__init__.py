"""Launch layer: production meshes, input specs, dry-run, train/serve drivers."""
