"""Launch layer: production meshes, input specs, dry-run, the train driver,
and the forecast-serving driver (``python -m repro.launch.serve`` — the
CLI over ``repro.serving``, docs/serving.md)."""
