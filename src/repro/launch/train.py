"""End-to-end training driver.

Laptop-scale by default (reduced config, host mesh); the same driver drives
the production mesh when run under a real multi-host topology — mesh size,
shardings, and checkpoints are all derived from logical rules, so the script
is identical (elastic by construction).

Example (the ~100M-model end-to-end run used in EXPERIMENTS.md)::

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m \
        --reduced --steps 300 --batch 16 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import logging
import time
from pathlib import Path

import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import get_arch
from repro.data.pipeline import SyntheticLMDataset
from repro.models import build_model
from repro.parallel.sharding import DEFAULT_RULES, axis_rules
from repro.runtime.loop import StragglerWatchdog, Trainer, make_train_step

from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")
    entry = get_arch(args.arch)
    cfg = entry.reduced if args.reduced else entry.full
    model = build_model(cfg)

    dataset = SyntheticLMDataset(
        vocab=cfg.vocab,
        seq_len=args.seq,
        global_batch=args.batch,
        frames_shape=(cfg.encoder_seq, cfg.d_model) if cfg.is_encdec else None,
        patches_shape=(cfg.encoder_seq, cfg.d_model) if cfg.frontend == "vision" else None,
    )

    mesh = make_host_mesh()
    step_fn = make_train_step(
        model, base_lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5), microbatches=args.microbatches,
    )

    with axis_rules(DEFAULT_RULES, mesh=mesh):
        trainer = Trainer(
            model, dataset, args.ckpt_dir,
            train_step=step_fn, ckpt_every=args.ckpt_every,
            watchdog=StragglerWatchdog(),
        )
        t0 = time.time()
        state = trainer.restore_or_init()
        start_step = int(state.step)
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in dataset.batch_at(step).items()}
            state, metrics = trainer._step(state, batch)
            if (step + 1) % args.log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                print(f"step {step + 1:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  gnorm {float(metrics['grad_norm']):.3f}")
                trainer.metrics_history.append({k: float(v) for k, v in metrics.items()})
                n_logged += 1
            if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                trainer.ckpt.save_async(step + 1, state)
        trainer.ckpt.wait()
        dt = time.time() - t0
        steps_done = args.steps - start_step
        print(f"done: {steps_done} steps in {dt:.1f}s "
              f"({steps_done * args.batch * args.seq / max(dt, 1e-9):.0f} tok/s)")

    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(trainer.metrics_history, indent=1))


if __name__ == "__main__":
    main()
