"""Forecast-serving driver: hold a compiled stencil program hot, batch
concurrent requests onto the ensemble member axis, stream steps back.

(This entrypoint used to be an LM prompt-decode demo; it now drives the
``repro.serving`` subsystem — see docs/serving.md.)

Serve the demo forecast program over websockets (needs aiohttp)::

    PYTHONPATH=src python -m repro.launch.serve --port 8765

In-process load test, no network or aiohttp needed::

    PYTHONPATH=src python -m repro.launch.serve --load 8 --steps 10 --stream-every 2

Print the catalog a client would see and exit::

    PYTHONPATH=src python -m repro.launch.serve --dry

Run under the process supervisor (spawn → probe /healthz → restart with
backoff → give up on a crash loop)::

    PYTHONPATH=src python -m repro.launch.serve --supervise --port 8765

The server itself shuts down gracefully on SIGTERM: /healthz flips to 503
(``DRAINING``), new requests are rejected, queued and in-flight work is
finished (bounded by ``--drain-timeout``), then the process exits 0 — the
supervisor treats that as a deliberate stop, not a crash.
"""

from __future__ import annotations

import argparse
import asyncio
import functools
import json
import signal
from typing import Tuple

import repro  # noqa: F401
from repro.obs import export as obs_export
from repro.obs import slo as obs_slo
from repro.obs import trace as otrace
from repro.obs.flight import FlightRecorder
from repro.runtime.supervise import RestartPolicy, Supervisor, http_ready
from repro.serving import ProgramEntry, RequestSpec, ServingEngine, drive_engine
from repro.stencils.forecast import build_forecast_step, make_forecast_fields, request_state


def _arm_tracing(args: argparse.Namespace) -> bool:
    """Enable the process tracer when ``--trace-out`` asks for a dump (or
    ``REPRO_TRACE=1`` already armed it); returns whether a dump is due.
    ``--trace-sample`` arms *sampled* always-on tracing: keep/drop is a
    deterministic hash of the request id, error paths are force-sampled."""
    if args.trace_sample is not None:
        otrace.configure(enabled=True, sample_rate=args.trace_sample)
    elif args.trace_out:
        otrace.configure(enabled=True)
    return bool(args.trace_out)


def _build_engine(args: argparse.Namespace) -> ServingEngine:
    """One engine, fully armed from the CLI: flight recorder (``--flight-dir``
    beats ``$REPRO_FLIGHT_DIR``), default SLOs attached per program at
    registration time (see ``_attach_slos``)."""
    flight = FlightRecorder(args.flight_dir) if args.flight_dir else None
    return ServingEngine(
        window_ms=args.window_ms,
        flight=flight,
        scheduler=args.scheduler,
        priority_classes=args.priority_classes,
    )


def _attach_slos(engine: ServingEngine, entry: ProgramEntry, args: argparse.Namespace) -> None:
    if not args.no_slo:
        engine.slo.add(
            *obs_slo.default_objectives(
                entry.name, availability=args.slo_availability, p99_s=args.slo_p99
            )
        )


def _dump_trace(args: argparse.Namespace) -> None:
    data = obs_export.write_chrome_trace(
        args.trace_out, metadata={"entry": "repro.launch.serve", "backend": args.backend}
    )
    n = sum(1 for ev in data["traceEvents"] if ev.get("ph") != "M")
    print(f"wrote {n} trace events to {args.trace_out}", flush=True)


def build_forecast_entry(
    engine: ServingEngine,
    *,
    backend: str = "jax",
    domain: Tuple[int, int, int] = (48, 48, 16),
    member_counts: Tuple[int, ...] = (1, 2, 4, 8),
    warm: bool = True,
    warm_chunk: int = 1,
) -> ProgramEntry:
    """Register the demo forecast step (advect + euler + diffuse) — the
    reusable builder examples/serve_forecast.py and the bench wrap."""
    fields, scalars = make_forecast_fields(backend, domain)
    step = build_forecast_step(backend, domain)
    return engine.register(
        step,
        fields=fields,
        scalars=scalars,
        request_fields=("phi",),
        member_counts=member_counts,
        warm=warm,
        warm_chunk=warm_chunk,
    )


async def _load_test(args: argparse.Namespace) -> None:
    dump = _arm_tracing(args)
    engine = _build_engine(args)
    domain = tuple(args.domain)
    entry = build_forecast_entry(
        engine, backend=args.backend, domain=domain, warm=True, warm_chunk=args.stream_every
    )
    _attach_slos(engine, entry, args)
    specs = [
        RequestSpec(
            program=entry.name,
            fields={"phi": request_state(domain, seed=i + 1)},
            steps=args.steps,
            stream_every=args.stream_every,
        )
        for i in range(args.load)
    ]
    async with engine:
        report = await drive_engine(engine, specs, keep_fields="none")
    s = report.summary()
    print(
        f"{args.load} concurrent requests x {args.steps} steps (stream_every={args.stream_every}) "
        f"on {args.backend} {domain}"
    )
    print(
        f"  {s['requests_per_second']:.1f} req/s  p50 {s['p50_ms']:.1f} ms  "
        f"p99 {s['p99_ms']:.1f} ms  occupancy {s['mean_occupancy']:.2f}"
    )
    print(f"  in order: {report.all_in_order}   engine: {json.dumps(engine.stats())}")
    if dump:
        _dump_trace(args)


async def _serve(args: argparse.Namespace) -> None:
    from repro.serving.server import ForecastServer

    dump = _arm_tracing(args)
    engine = _build_engine(args)
    entry = build_forecast_entry(
        engine, backend=args.backend, domain=tuple(args.domain), warm=not args.no_warm
    )
    _attach_slos(engine, entry, args)
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGTERM, signal.SIGINT):
        loop.add_signal_handler(sig, stop.set)
    # operator's black-box button: SIGUSR2 drops a flight bundle on demand
    # (no-op unless --flight-dir / $REPRO_FLIGHT_DIR armed a recorder)
    loop.add_signal_handler(signal.SIGUSR2, lambda: engine._flight_dump("sigusr2"))
    async with ForecastServer(engine, host=args.host, port=args.port) as srv:
        print(f"forecast server on {srv.ws_url}  (GET /programs for the catalog; SIGTERM drains)", flush=True)
        await stop.wait()
        # graceful drain: /healthz flips to DRAINING (503), new submits are
        # rejected, queued + in-flight requests finish before we exit 0
        print(f"draining (timeout {args.drain_timeout}s) ...", flush=True)
        await engine.drain(timeout_s=args.drain_timeout)
    if dump:
        _dump_trace(args)


def _supervise(args: argparse.Namespace) -> None:
    """Parent mode: spawn the server as a child of this interpreter, probe
    /healthz until ready, restart with backoff when it dies, give up on a
    crash loop (SupervisorGaveUp propagates)."""
    child_args = ["--backend", args.backend, "--domain", *map(str, args.domain),
                  "--window-ms", str(args.window_ms), "--host", args.host,
                  "--port", str(args.port), "--drain-timeout", str(args.drain_timeout),
                  "--slo-p99", str(args.slo_p99), "--slo-availability", str(args.slo_availability),
                  "--priority-classes", str(args.priority_classes)]
    if args.scheduler is not None:
        child_args.extend(["--scheduler", args.scheduler])
    if args.no_warm:
        child_args.append("--no-warm")
    if args.no_slo:
        child_args.append("--no-slo")
    if args.trace_sample is not None:
        child_args.extend(["--trace-sample", str(args.trace_sample)])
    if args.flight_dir:
        child_args.extend(["--flight-dir", args.flight_dir])
    from repro.runtime.supervise import serve_command

    url = f"http://{args.host}:{args.port}/healthz"
    sup = Supervisor(
        serve_command(child_args),
        probe=functools.partial(http_ready, url),
        policy=RestartPolicy(),
        ready_timeout_s=args.ready_timeout,
        # the supervisor's own bundles (restart cadence, exit codes) land in
        # the same directory as the child's in-process ones
        flight=FlightRecorder(args.flight_dir) if args.flight_dir else None,
    )

    def _forward(signum, _frame):
        sup.stop()
        raise SystemExit(0)

    signal.signal(signal.SIGTERM, _forward)
    signal.signal(signal.SIGINT, _forward)
    print(f"supervising forecast server (probe {url})", flush=True)
    sup.run_forever()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--backend", default="jax", choices=["jax", "pallas"])
    ap.add_argument("--domain", type=int, nargs=3, default=[48, 48, 16], metavar=("NI", "NJ", "NK"))
    ap.add_argument("--window-ms", type=float, default=2.0, help="batching window")
    ap.add_argument("--scheduler", default=None, choices=["fifo", "edf"],
                    help="batching scheduler policy (default: $REPRO_SCHEDULER or edf — "
                         "earliest-deadline-first within priority classes)")
    ap.add_argument("--priority-classes", type=int, default=3, metavar="N",
                    help="number of request priority classes the engine accepts "
                         "(priorities 0..N-1, lower = more urgent)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8765)
    ap.add_argument("--no-warm", action="store_true", help="skip pre-jitting every member count")
    ap.add_argument("--load", type=int, default=0, help="run an in-process load test with N requests")
    ap.add_argument("--steps", type=int, default=10, help="(--load) steps per request")
    ap.add_argument("--stream-every", type=int, default=2, help="(--load) stream cadence")
    ap.add_argument("--dry", action="store_true", help="print the catalog and exit")
    ap.add_argument("--supervise", action="store_true",
                    help="run the server as a supervised child (restart with backoff)")
    ap.add_argument("--drain-timeout", type=float, default=10.0,
                    help="seconds to finish in-flight work on SIGTERM before exiting")
    ap.add_argument("--ready-timeout", type=float, default=120.0,
                    help="(--supervise) seconds for /healthz to come up before counting a crash")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="arm span tracing and write a Chrome-trace/Perfetto JSON dump "
                         "on exit (serve mode) or after the run (--load mode)")
    ap.add_argument("--trace-sample", type=float, default=None, metavar="RATE",
                    help="arm ALWAYS-ON tracing at this head-sampling rate in [0,1] "
                         "(deterministic per request id; error paths always kept); "
                         "also honors REPRO_TRACE_SAMPLE")
    ap.add_argument("--flight-dir", default=None, metavar="DIR",
                    help="arm the failure flight recorder: JSON black-box bundles land "
                         "here on worker death, SLO breach, crash-loop give-up, SIGUSR2 "
                         "(also honors REPRO_FLIGHT_DIR)")
    ap.add_argument("--slo-p99", type=float, default=0.5, metavar="SECONDS",
                    help="p99 latency SLO target for the served program")
    ap.add_argument("--slo-availability", type=float, default=0.999, metavar="FRACTION",
                    help="availability SLO target for the served program")
    ap.add_argument("--no-slo", action="store_true", help="disable the default SLO objectives")
    args = ap.parse_args()

    if args.dry:
        engine = ServingEngine(window_ms=args.window_ms)
        entry = build_forecast_entry(engine, backend=args.backend, domain=tuple(args.domain), warm=False)
        print(json.dumps(entry.describe(), indent=2))
        return
    if args.load:
        asyncio.run(_load_test(args))
        return
    if args.supervise:
        _supervise(args)
        return
    try:
        asyncio.run(_serve(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
