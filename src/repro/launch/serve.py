"""Batched serving driver: prefill a batch of prompts, decode greedily.

Demonstrates the serve path end-to-end on CPU with a reduced config::

    PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import get_arch
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    entry = get_arch(args.arch)
    cfg = entry.reduced if args.reduced else entry.full
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(2, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32))

    max_len = args.prompt_len + args.gen
    cache = model.make_cache(batch=args.batch, max_len=max_len)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits, axis=-1)[:, None]
    outputs = [tokens]
    t0 = time.time()
    for _ in range(args.gen - 1):
        step_batch = {"tokens": tokens}
        if cfg.is_encdec:
            step_batch["frames"] = batch["frames"]
        logits, cache = decode(params, step_batch, cache)
        tokens = jnp.argmax(logits, axis=-1)[:, None]
        outputs.append(tokens)
    jax.block_until_ready(outputs[-1])
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in outputs], axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms   decode: {t_decode * 1e3:.1f} ms "
          f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for row in gen[:2]:
        print("  ", row[:16].tolist())


if __name__ == "__main__":
    main()
