"""ShapeDtypeStruct input specs + sharding trees for every (arch × shape) cell.

``input_specs(arch, shape_id)`` returns weak-type-correct, shardable
stand-ins for every model input (the dry-run contract): training batches for
``train_*`` shapes; (tokens, cache) for prefill/decode shapes.  No device
allocation happens here.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch, get_shape
from repro.configs.base import ArchConfig
from repro.data.pipeline import make_batch_specs
from repro.models import build_model
from repro.models.layers import ParamSpec
from repro.parallel.sharding import logical_spec
from repro.runtime.loop import TrainState


# ---------------------------------------------------------------------------
# per-arch serve batch specs
# ---------------------------------------------------------------------------


def serve_input_specs(cfg: ArchConfig, kind: str, seq_len: int, batch: int) -> Dict[str, Any]:
    """Model inputs for prefill (full prompt) or decode (1 token + cache)."""
    s = seq_len if kind == "prefill" else 1
    if cfg.frontend == "vision" and kind == "prefill":
        # seq_len budgets the TOTAL sequence: image patch prefix + text prompt
        s = seq_len - cfg.encoder_seq
    specs: Dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((batch, s), np.int32)}
    if cfg.frontend == "vision" and kind == "prefill":
        specs["patches"] = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), np.float32)
    if cfg.is_encdec:
        if kind == "prefill":
            specs["frames"] = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), np.float32)
        else:
            # decode uses the cross-attention K/V precomputed at prefill
            hd = cfg.resolved_head_dim
            specs["enc_kv"] = (
                jax.ShapeDtypeStruct((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                                     cfg.dtype),
                jax.ShapeDtypeStruct((cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, hd),
                                     cfg.dtype),
            )
    return specs


def cache_specs(model, batch: int, max_len: int) -> Any:
    """ShapeDtypeStruct tree of the serve cache (no allocation)."""
    return jax.eval_shape(lambda: model.make_cache(batch=batch, max_len=max_len))


def input_specs(arch: str, shape_id: str) -> Dict[str, Any]:
    """Entry point required by the dry-run: stand-ins for every model input."""
    entry = get_arch(arch)
    cfg = entry.full
    shape = get_shape(shape_id)
    model = build_model(cfg)
    if shape.kind == "train":
        return {"batch": make_batch_specs(cfg, shape)}
    batch = serve_input_specs(cfg, shape.kind, shape.seq_len, shape.global_batch)
    cache = cache_specs(model, shape.global_batch, shape.seq_len)
    return {"batch": batch, "cache": cache}


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------


def _spec_to_sharding(mesh: Mesh, spec: ParamSpec) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(spec.logical, mesh, spec.shape))


def param_shardings(model, mesh: Mesh) -> Any:
    specs = model.param_specs()
    return jax.tree_util.tree_map(
        lambda s: _spec_to_sharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def state_shardings(model, mesh: Mesh) -> TrainState:
    """TrainState shardings: opt m/v follow their parameters exactly."""
    ps = param_shardings(model, mesh)
    scalar = NamedSharding(mesh, P())
    from repro.optim.adamw import OptState

    return TrainState(
        step=scalar,
        params=ps,
        opt=OptState(step=scalar, m=ps, v=ps),
    )


def batch_shardings(mesh: Mesh, batch_specs: Dict[str, Any]) -> Dict[str, Any]:
    def shard_one(s):
        logical = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, logical_spec(logical, mesh, s.shape))

    return jax.tree_util.tree_map(shard_one, batch_specs)


_CACHE_LOGICAL_BY_KEY = {
    # stacked (L, B, S, Kh, Dh) attention caches
    "k": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
    # mamba2 (L, B, H, N, P) state + (L, B, K-1, C) conv tail
    "state": (None, "batch", "ssm_heads", None, None),
    "conv": (None, "batch", None, "mlp"),
    # rglru hidden state (L, B, Dr)
    "h": (None, "batch", "mlp"),
}


def cache_shardings(mesh: Mesh, cache_tree: Any) -> Any:
    """Path-keyed shardings for a serve cache tree (stacked or unstacked)."""

    def walk(path, leaf):
        key = None
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                key = k
                break
        stacked_tail = any(
            isinstance(getattr(p, "key", None), str) and str(getattr(p, "key", "")).startswith("tail_")
            for p in path
        )
        logical = _CACHE_LOGICAL_BY_KEY.get(key)
        if key == "pos" or logical is None:
            return NamedSharding(mesh, P())
        if stacked_tail:  # unstacked single-layer cache: drop the layer dim
            logical = logical[1:]
        logical = logical[: len(leaf.shape)] if len(logical) > len(leaf.shape) else logical
        if len(logical) < len(leaf.shape):
            logical = logical + (None,) * (len(leaf.shape) - len(logical))
        return NamedSharding(mesh, logical_spec(logical, mesh, leaf.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(treedef, [walk(p, leaf) for p, leaf in flat])


def serve_batch_shardings(mesh: Mesh, batch_specs: Dict[str, Any]) -> Dict[str, Any]:
    def shard_one(path, s):
        key = None
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                key = k
                break
        if key == "enc_kv" or (key is None and len(s.shape) == 5):
            logical = (None, "batch", None, "kv_heads", "head_dim")
        else:
            logical = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, logical_spec(logical, mesh, s.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_specs)
    return jax.tree_util.tree_unflatten(treedef, [shard_one(p, leaf) for p, leaf in flat])


# ---------------------------------------------------------------------------
# train-state specs (shapes only — no allocation)
# ---------------------------------------------------------------------------


def train_state_specs(model) -> TrainState:
    params = model.param_shapes()
    from repro.optim.adamw import OptState

    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)  # noqa: E731
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=params,
        opt=OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree_util.tree_map(f32, params),
            v=jax.tree_util.tree_map(f32, params),
        ),
    )
