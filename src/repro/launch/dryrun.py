import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods × 256 chips.
For each cell we jit the real step function (train_step / prefill /
decode_step) with production in/out shardings, ``.lower().compile()`` it,
and record ``memory_analysis()`` + ``cost_analysis()`` + per-collective
byte counts (parsed from the compiled HLO) into a JSON report consumed by
the roofline analysis (benchmarks/roofline.py, EXPERIMENTS.md §Dry-run).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict

import jax
import numpy as np

import repro  # noqa: F401  (x64 flag)
from repro.configs import get_arch, get_shape, list_archs
from repro.models import build_model
from repro.parallel.sharding import DEFAULT_RULES, axis_rules
from repro.runtime.loop import make_train_step

from .mesh import make_production_mesh
from .specs import (
    batch_shardings,
    cache_shardings,
    cache_specs,
    input_specs,
    param_shardings,
    serve_batch_shardings,
    serve_input_specs,
    state_shardings,
    train_state_specs,
)

# microbatch counts keeping per-device live activations bounded at train_4k
TRAIN_MICROBATCHES = {
    "deepseek-coder-33b": 8,
    "command-r-35b": 8,
    "stablelm-12b": 8,
    # (mb=16 measured: per-device memory flat, collective rounds +26% — the
    # residual footprint is not microbatch-scaled; keep 8. §Perf iteration 8)
    "phi3.5-moe-42b-a6.6b": 8,
    "moonshot-v1-16b-a3b": 8,
    "recurrentgemma-2b": 4,
    "phi3-mini-3.8b": 4,
    "whisper-medium": 4,
    "internvl2-1b": 4,
    "mamba2-370m": 4,
}

_COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\w+\[[^\]]*\][^ ]*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def _tensor_bytes(shape_str: str) -> int:
    """'f32[8,128]' (or tuple '(f32[..], f32[..])') → total bytes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum output bytes per collective kind from compiled HLO text."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        kind = m.group(3)
        nbytes = _tensor_bytes(m.group(2))
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def _collective_link_bytes(colls: Dict[str, Dict[str, float]]) -> float:
    """Ring-model per-device link traffic (bytes) from collective sums."""
    total = 0.0
    for kind, rec in colls.items():
        b = rec["bytes"]
        if kind == "all-reduce":
            total += 2.0 * b  # reduce-scatter + all-gather phases
        elif kind in ("all-gather", "reduce-scatter"):
            total += b
        elif kind == "all-to-all":
            total += b
        elif kind == "collective-permute":
            total += b
    return total


def lower_cell(arch: str, shape_id: str, multi_pod: bool) -> Dict[str, Any]:
    entry = get_arch(arch)
    cfg = entry.full
    shape = get_shape(shape_id)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    report: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "kind": shape.kind,
    }
    t0 = time.time()

    with axis_rules(DEFAULT_RULES, mesh=mesh):
        if shape.kind == "train":
            specs = input_specs(arch, shape_id)["batch"]
            st_specs = train_state_specs(model)
            st_sh = state_shardings(model, mesh)
            b_sh = batch_shardings(mesh, specs)
            step = make_train_step(model, microbatches=TRAIN_MICROBATCHES.get(arch, 1))
            jitted = jax.jit(
                step,
                in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
                donate_argnums=(0,),
            )
            with mesh:
                lowered = jitted.lower(st_specs, specs)
        else:
            batch = serve_input_specs(cfg, shape.kind, shape.seq_len, shape.global_batch)
            cache = cache_specs(model, shape.global_batch, shape.seq_len)
            p_sh = param_shardings(model, mesh)
            c_sh = cache_shardings(mesh, cache)
            b_sh = serve_batch_shardings(mesh, batch)
            fn = model.prefill if shape.kind == "prefill" else model.decode_step
            jitted = jax.jit(
                fn,
                in_shardings=(p_sh, b_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            )
            with mesh:
                lowered = jitted.lower(model.param_shapes(), batch, cache)

        compiled = lowered.compile()

    report["lower_compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        report["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        report["memory"]["total_per_device_bytes"] = (
            report["memory"]["argument_bytes"]
            + report["memory"]["output_bytes"]
            + report["memory"]["temp_bytes"]
        )

    cost = compiled.cost_analysis()
    if cost:
        c = cost if isinstance(cost, dict) else cost[0]
        report["cost"] = {
            "flops": float(c.get("flops", 0.0)),
            "bytes_accessed": float(c.get("bytes accessed", 0.0)),
            "transcendentals": float(c.get("transcendentals", 0.0)),
        }

    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    report["collectives"] = colls
    report["collective_link_bytes"] = _collective_link_bytes(colls)
    report["hlo_bytes"] = len(hlo)

    # trip-count-aware re-walk: XLA's cost_analysis counts while bodies once,
    # which undercounts scan-over-layers models by O(layers × microbatches)
    from .hlo_count import analyze_hlo_text

    walked = analyze_hlo_text(hlo)
    report["walked"] = {
        "flops": walked["flops"],
        "bytes": walked["bytes"],
        "collectives": walked["collectives"],
        "collective_link_bytes": _collective_link_bytes(
            {k: {"bytes": v} for k, v in walked["collectives"].items()}
        ),
    }
    return report


def lower_stencil_cell(multi_pod: bool, *, global_ij: int = 8192, nk: int = 64,
                       backend: str = "jax", overlap: bool = False,
                       dtype: str = "float64") -> Dict[str, Any]:
    """The paper's own workload at production scale: distributed horizontal
    diffusion (halo exchange on the torus + fused local stencil)."""
    from repro.stencils.distributed import DistributedStencil
    from repro.stencils.hdiff import build_hdiff

    mesh = make_production_mesh(multi_pod=multi_pod)
    st = build_hdiff(backend, dtype=dtype)
    # decompose i over data(+pod), j over model
    dist = DistributedStencil(st, mesh, i_axis="data", j_axis="model", overlap=overlap)
    gi = global_ij * (2 if multi_pod else 1)
    specs = {
        "in_phi": jax.ShapeDtypeStruct((gi, global_ij, nk), dtype),
        "out_phi": jax.ShapeDtypeStruct((gi, global_ij, nk), dtype),
    }
    report: Dict[str, Any] = {
        "arch": f"stencil-hdiff-{backend}" + ("-f32" if dtype == "float32" else ""),
        "shape": f"{gi}x{global_ij}x{nk}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": int(np.prod(mesh.devices.shape)),
        "kind": "stencil",
    }
    t0 = time.time()
    lowered = dist.lower(specs, {"alpha": np.float64(0.05)})
    compiled = lowered.compile()
    report["lower_compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        report["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        }
        report["memory"]["total_per_device_bytes"] = sum(report["memory"].values())
    cost = compiled.cost_analysis()
    if cost:
        c = cost if isinstance(cost, dict) else cost[0]
        report["cost"] = {"flops": float(c.get("flops", 0.0)),
                         "bytes_accessed": float(c.get("bytes accessed", 0.0))}
    hlo = compiled.as_text()
    report["collectives"] = parse_collectives(hlo)
    report["collective_link_bytes"] = _collective_link_bytes(report["collectives"])
    from .hlo_count import analyze_hlo_text

    walked = analyze_hlo_text(hlo)
    report["walked"] = {
        "flops": walked["flops"],
        "bytes": walked["bytes"],
        "collectives": walked["collectives"],
        "collective_link_bytes": _collective_link_bytes(
            {k: {"bytes": v} for k, v in walked["collectives"].items()}
        ),
    }
    return report


def cells_for(arch: str):
    entry = get_arch(arch)
    return list(entry.shapes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--stencil", action="store_true",
                    help="run the distributed-stencil (paper workload) cell")
    ap.add_argument("--stencil-overlap", action="store_true")
    ap.add_argument("--stencil-dtype", default="float64")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.stencil:
        meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
        for multi_pod in meshes:
            tag = f"stencil-hdiff_{'multi' if multi_pod else 'single'}" + (
                "_overlap" if args.stencil_overlap else "") + (
                "_f32" if args.stencil_dtype == "float32" else "")
            report = lower_stencil_cell(multi_pod, overlap=args.stencil_overlap,
                                        dtype=args.stencil_dtype)
            (outdir / f"{tag}.json").write_text(json.dumps(report, indent=1))
            print(f"OK   {tag}: compile {report['lower_compile_s']}s, "
                  f"colls {report['walked']['collectives']}")
        return

    if args.all:
        targets = [(a, s) for a in list_archs() for s in cells_for(a)]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        targets = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape_id in targets:
        for multi_pod in meshes:
            tag = f"{arch}_{shape_id}_{'multi' if multi_pod else 'single'}"
            path = outdir / f"{tag}.json"
            try:
                report = lower_cell(arch, shape_id, multi_pod)
                path.write_text(json.dumps(report, indent=1))
                mem_gb = report.get("memory", {}).get("total_per_device_bytes", 0) / 2**30
                print(f"OK   {tag}: compile {report['lower_compile_s']}s, "
                      f"{mem_gb:.2f} GiB/dev, flops {report.get('cost', {}).get('flops', 0):.3e}")
            except Exception as e:  # noqa: BLE001
                failures += 1
                path.with_suffix(".error.txt").write_text(traceback.format_exc())
                print(f"FAIL {tag}: {type(e).__name__}: {e}")
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
