"""Wire protocol for the forecast server: JSON text frames, bit-exact arrays.

Every frame is one JSON object with a ``"type"`` discriminator.  Arrays cross
the wire as ``{"shape", "dtype", "data"}`` where ``data`` is the base64 of the
raw C-order bytes — float64 state survives the round trip *bit-identically*,
which the serving contract (batched == sequential, exactly) depends on; a
decimal text encoding would quietly round it.

Client → server:

``forecast``
    ``{"type": "forecast", "request_id", "program", "steps", "stream_every",
    "fields": {name: array}, "scalars": {name: float}, "fingerprint"?,
    "stats"?, "deadline_ms"?, "priority"?}`` — submit one forecast request.
    ``priority`` is an integer urgency class in ``[0, priority_classes)``
    (lower is more urgent; the engine defaults omitted priorities to the
    normal class and rejects out-of-range values with 422); deadline-aware
    schedulers order the backlog by ``(priority, deadline)``.
``programs``
    ``{"type": "programs"}`` — ask for the catalog of registered programs.

Server → client (per request, in this order):

``accepted`` → ``step``* → ``done``, or ``error`` at any point.  ``step``
carries the streamed fields (encoded arrays), optional per-field statistics,
and the batch the dispatch rode (members / live requests / occupancy).
``done`` carries end-to-end telemetry: ``latency_s`` (submit → done on the
engine's monotonic clock) and ``queue_wait_s`` (submit → batching-window
pickup) — the same quantities the engine's metrics registry tracks as the
``serving_request_latency_seconds`` / ``serving_queue_wait_seconds``
summaries on ``GET /metrics``.

Admission errors reuse HTTP flavors so clients can switch on ``code``:
400 malformed frame, 404 unknown program, 409 fingerprint mismatch,
413 field shape/dtype mismatch, 422 bad scalars, step counts, or priority,
503 overloaded/draining (the frame carries ``retry_after_ms``), 504 deadline
exceeded — either at window pickup (the request died waiting in the queue and
was never dispatched) or at a segment boundary mid-horizon.
"""

from __future__ import annotations

import base64
import json
from typing import Any, Dict, Optional

import numpy as np

#: admission / protocol error codes (HTTP-flavored, carried in "error" frames)
BAD_REQUEST = 400
UNKNOWN_PROGRAM = 404
FINGERPRINT_MISMATCH = 409
SHAPE_MISMATCH = 413
INVALID_VALUE = 422
INTERNAL = 500
OVERLOADED = 503  # admission queue full, or the engine is draining
DEADLINE_EXCEEDED = 504  # deadline expired at window pickup or a segment boundary


class ServingError(Exception):
    """An admission- or protocol-level rejection with an HTTP-flavored code.

    503 rejections carry ``retry_after_ms`` — the engine's estimate (from the
    watchdog's median dispatch wall and the queue depth) of when capacity
    frees up; well-behaved clients back off that long before retrying."""

    def __init__(self, code: int, reason: str, *, retry_after_ms: Optional[float] = None):
        super().__init__(f"[{code}] {reason}")
        self.code = int(code)
        self.reason = reason
        self.retry_after_ms = None if retry_after_ms is None else float(retry_after_ms)


def encode_array(arr: np.ndarray) -> Dict[str, Any]:
    """Array → JSON-safe spec; raw C-order bytes in base64 (bit-exact)."""
    arr = np.ascontiguousarray(arr)
    return {
        "shape": list(arr.shape),
        "dtype": str(arr.dtype),
        "data": base64.b64encode(arr.tobytes()).decode("ascii"),
    }


def decode_array(spec: Any) -> np.ndarray:
    """JSON spec → array; structural problems are 400s, never exceptions."""
    if not isinstance(spec, dict) or not {"shape", "dtype", "data"} <= set(spec):
        raise ServingError(BAD_REQUEST, "array spec must be a {shape, dtype, data} object")
    try:
        dtype = np.dtype(spec["dtype"])
        shape = tuple(int(s) for s in spec["shape"])
        raw = base64.b64decode(spec["data"])
        arr = np.frombuffer(raw, dtype=dtype)
    except (TypeError, ValueError) as e:
        raise ServingError(BAD_REQUEST, f"undecodable array spec: {e}") from None
    if arr.size != int(np.prod(shape, dtype=np.int64)):
        raise ServingError(BAD_REQUEST, f"array payload holds {arr.size} elements, shape says {shape}")
    return arr.reshape(shape)


def parse_forecast(msg: Dict[str, Any]) -> Dict[str, Any]:
    """Validate a ``forecast`` frame and decode it into ``engine.submit``
    keyword arguments.  Only structure is checked here — semantic admission
    (program existence, shapes, scalar names) belongs to the engine."""
    if not isinstance(msg.get("program"), str):
        raise ServingError(BAD_REQUEST, "forecast frame needs a string 'program'")
    fields_spec = msg.get("fields")
    if not isinstance(fields_spec, dict):
        raise ServingError(BAD_REQUEST, "forecast frame needs a 'fields' object")
    fields = {str(n): decode_array(spec) for n, spec in fields_spec.items()}
    scalars = msg.get("scalars", {})
    if not isinstance(scalars, dict):
        raise ServingError(BAD_REQUEST, "'scalars' must be an object of numbers")
    return {
        "program": msg["program"],
        "fields": fields,
        "scalars": {str(n): v for n, v in scalars.items()},
        "steps": msg.get("steps", 1),
        "stream_every": msg.get("stream_every", 1),
        "fingerprint": msg.get("fingerprint"),
        "request_id": msg.get("request_id"),
        "stats": bool(msg.get("stats", False)),
        "deadline_ms": msg.get("deadline_ms"),
        "priority": msg.get("priority"),
    }


def encode_event(ev: Dict[str, Any]) -> Dict[str, Any]:
    """Engine event → wire frame: numpy arrays in ``fields`` get encoded,
    everything else passes through as-is."""
    if "fields" not in ev:
        return ev
    out = dict(ev)
    out["fields"] = {n: encode_array(a) for n, a in ev["fields"].items()}
    return out


def decode_event(frame: Dict[str, Any]) -> Dict[str, Any]:
    """Wire frame → engine-shaped event (arrays decoded back to numpy)."""
    if "fields" not in frame:
        return frame
    out = dict(frame)
    out["fields"] = {n: decode_array(spec) for n, spec in frame["fields"].items()}
    return out


def error_frame(
    code: int,
    reason: str,
    request_id: Optional[str] = None,
    *,
    retry_after_ms: Optional[float] = None,
) -> Dict[str, Any]:
    frame: Dict[str, Any] = {"type": "error", "code": int(code), "reason": reason}
    if request_id is not None:
        frame["request_id"] = request_id
    if retry_after_ms is not None:
        frame["retry_after_ms"] = float(retry_after_ms)
    return frame


def loads(text: str) -> Dict[str, Any]:
    """Parse one frame; anything that is not a JSON object is a 400."""
    try:
        msg = json.loads(text)
    except ValueError as e:
        raise ServingError(BAD_REQUEST, f"frame is not valid JSON: {e}") from None
    if not isinstance(msg, dict) or "type" not in msg:
        raise ServingError(BAD_REQUEST, "frame must be a JSON object with a 'type'")
    return msg


def dumps(frame: Dict[str, Any]) -> str:
    return json.dumps(frame, separators=(",", ":"))
