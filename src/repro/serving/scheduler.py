"""Pluggable batching schedulers: who rides the next window, in what order.

The engine's worker used to be a strict FIFO single-window loop: pop the
oldest request, collect arrivals for one ``window_ms``, dispatch, repeat.
That policy is blind to everything the request already tells us — its
deadline, its priority class, which program it targets.  This module owns
that decision instead:

* The scheduler holds the **backlog**: every request the worker has pulled
  off the admission queue but not yet taken into a dispatch window.  The
  admission queue stays a plain FIFO hand-off between ``submit()`` and the
  worker; ordering policy applies to the whole backlog, not just to whatever
  happened to arrive inside one window.
* ``take()`` forms **per-program windows**: for each program present in the
  backlog (in policy order) it takes up to that program's ``max_batch`` most
  urgent requests.  The engine dispatches distinct programs' windows
  concurrently; the surplus stays in the backlog and is *re-ordered again*
  on the next round, so a tight-deadline request that arrived late still
  overtakes a queued bulk job.
* ``window_cap()`` is derived from the programs **actually present** in the
  backlog — not ``max()`` over the whole registry — which both fixes the
  over-collection bug (a window for a small-cap program no longer waits to
  fill a larger program's cap, then chunks the surplus into serial
  dispatches) and removes the ``max()``-on-empty-registry crash.

Policies are deterministic: every sort key ends in the admission sequence
number, so the same backlog always yields the same windows, and any two
requests are totally ordered.  Reordering is safe because batched execution
is bit-identical to sequential execution per request (the PR-6/7 contract):
a request computes the same bits no matter which window it rides.

``fifo``
    Arrival order (admission sequence).  The PR-6 behavior, kept as the
    baseline policy and for A/B comparison in the bench.

``edf``
    Earliest-deadline-first within priority classes: order by
    ``(priority, deadline, arrival)``.  Lower ``priority`` values are more
    urgent; a request without a deadline sorts after every request with one
    in the same class.  This is the default — with no deadlines and one
    priority class it degenerates to exactly FIFO.

Select with ``ServingEngine(scheduler=...)``, the serve CLI ``--scheduler``
flag, or the ``REPRO_SCHEDULER`` environment variable.
"""

from __future__ import annotations

import math
import os
from typing import TYPE_CHECKING, Callable, Dict, List, Tuple, Union

if TYPE_CHECKING:  # engine imports this module; never the other way at runtime
    from .engine import ForecastRequest, ProgramEntry

#: environment knob honored when the engine is built without an explicit policy
SCHEDULER_ENV = "REPRO_SCHEDULER"


class BatchingScheduler:
    """Base policy: FIFO by admission sequence.  Subclasses override
    :meth:`sort_key`; everything else — backlog ownership, per-program window
    formation, the present-programs cap — is policy-independent."""

    name = "fifo"

    def __init__(self) -> None:
        self._backlog: List["ForecastRequest"] = []

    # -- backlog ------------------------------------------------------------

    def push(self, req: "ForecastRequest") -> None:
        self._backlog.append(req)

    def backlog(self) -> int:
        return len(self._backlog)

    def oldest_waiting(self) -> Union[int, None]:
        """Smallest admission seq still pooled (None when empty) — the engine
        compares it against each round's picks to count real reorderings."""
        return min((r.seq for r in self._backlog), default=None)

    def flush(self) -> List["ForecastRequest"]:
        """Remove and return the entire backlog (worker failure/shutdown:
        the engine fails them rather than spinning on a poisoned pool)."""
        out, self._backlog = self._backlog, []
        return out

    def sweep(self, dead: Callable[["ForecastRequest"], bool]) -> List["ForecastRequest"]:
        """Remove and return every backlog request ``dead`` says to drop
        (expired / abandoned / already terminal) — checked at pickup, before
        any window slot or dispatch is spent on them."""
        gone = [r for r in self._backlog if dead(r)]
        if gone:
            self._backlog = [r for r in self._backlog if not dead(r)]
        return gone

    # -- policy -------------------------------------------------------------

    def sort_key(self, req: "ForecastRequest") -> Tuple:
        return (req.seq,)

    def window_cap(self) -> int:
        """How many requests one collection round can usefully hold: the sum
        of ``max_batch`` over the programs *present* in the backlog (each
        program dispatches its own window concurrently).  Zero on an empty
        backlog — never a ``max()`` over the registry."""
        entries: Dict[str, "ProgramEntry"] = {}
        for r in self._backlog:
            entries.setdefault(r.entry.name, r.entry)
        return sum(e.max_batch for e in entries.values())

    def take(self, now: float) -> List[Tuple["ProgramEntry", List["ForecastRequest"]]]:
        """Form this round's windows: order the backlog by policy, then give
        each program (in order of its most urgent request) its up-to-
        ``max_batch`` most urgent requests.  The surplus stays in the backlog
        in policy order and competes again next round."""
        ordered = sorted(self._backlog, key=self.sort_key)
        windows: List[Tuple["ProgramEntry", List["ForecastRequest"]]] = []
        index: Dict[str, int] = {}
        leftover: List["ForecastRequest"] = []
        for r in ordered:
            slot = index.get(r.entry.name)
            if slot is None:
                index[r.entry.name] = len(windows)
                windows.append((r.entry, [r]))
            elif len(windows[slot][1]) < r.entry.max_batch:
                windows[slot][1].append(r)
            else:
                leftover.append(r)
        self._backlog = leftover
        return windows


class FifoScheduler(BatchingScheduler):
    """Arrival order — the explicit name for the base policy."""

    name = "fifo"


class EdfScheduler(BatchingScheduler):
    """Earliest-deadline-first within priority classes.

    Key: ``(priority, deadline_at, seq)`` — class 0 preempts class 1, the
    soonest deadline wins within a class, deadline-less requests sort last in
    their class, and the admission sequence breaks every remaining tie so
    the order is total and deterministic."""

    name = "edf"

    def sort_key(self, req: "ForecastRequest") -> Tuple:
        deadline = req.deadline_at if req.deadline_at is not None else math.inf
        return (req.priority, deadline, req.seq)


SCHEDULERS: Dict[str, type] = {
    FifoScheduler.name: FifoScheduler,
    EdfScheduler.name: EdfScheduler,
}


def make_scheduler(
    spec: Union[str, BatchingScheduler, None] = None,
) -> BatchingScheduler:
    """Resolve a scheduler: an instance passes through, a name looks up the
    registry, ``None`` reads ``$REPRO_SCHEDULER`` and falls back to ``edf``
    (which is FIFO-identical when requests carry no deadlines/priorities)."""
    if isinstance(spec, BatchingScheduler):
        return spec
    if spec is None:
        spec = os.environ.get(SCHEDULER_ENV, "") or EdfScheduler.name
    try:
        cls = SCHEDULERS[str(spec).lower()]
    except KeyError:
        raise ValueError(
            f"unknown scheduler {spec!r}; available: {sorted(SCHEDULERS)}"
        ) from None
    return cls()
