"""Websocket transport for the forecast engine (aiohttp, optional dep).

The engine (``serving.engine``) is transport-agnostic; this module exposes it
over HTTP/websockets when ``aiohttp`` is installed (``pip install
repro[serving]``):

* ``GET /ws``       — the websocket endpoint speaking ``serving.protocol``
* ``GET /healthz``  — liveness probe
* ``GET /stats``    — engine counters (requests, batches, occupancy, stragglers)
  plus the full metrics-registry dump under ``"metrics"``
* ``GET /metrics``  — the same registry as Prometheus text exposition 0.0.4
  (per-program request/retry/bisect counters, queue-depth/state gauges,
  latency summaries, SLO burn-rate/breach gauges)
* ``GET /slo``      — evaluate the engine's SLOs now; burn rates per
  objective and window, breach flags
* ``GET /autoscale``— the desired-replica recommendation (documented rule
  over queue depth, capacity, p99-vs-SLO pressure; hysteresis-damped)
* ``GET /programs`` — the catalog, same payload as a ``programs`` frame

Each connection may multiplex many requests: frames carry ``request_id`` and
every request's events are streamed in submission order (one pump task per
request; a per-connection send lock keeps frames whole).

Disconnect handling: a client that vanishes mid-stream (send failure, or the
connection closing with pumps still running) gets its in-flight requests
marked *abandoned* — the engine stops gathering/emitting for those member
slots, the batch's other requests finish untouched, and nothing is leaked
into the next batch.  ``/healthz`` reflects the engine health state machine:
200 while ``SERVING``/``DEGRADED``, 503 once ``DRAINING`` so supervisors and
load balancers stop routing to a process that is shutting down."""

from __future__ import annotations

import asyncio
from typing import Any, Dict, Optional

try:
    from aiohttp import WSMsgType, web
except ImportError:  # pragma: no cover - exercised via _require_aiohttp
    web = None
    WSMsgType = None

from . import protocol
from .engine import DRAINING, ForecastRequest, ServingEngine
from .protocol import ServingError


def _require_aiohttp() -> None:
    if web is None:
        raise RuntimeError(
            "the websocket transport needs aiohttp (pip install repro[serving]); "
            "the engine itself (repro.serving.ServingEngine) has no such dependency"
        )


async def _send(ws, lock: asyncio.Lock, frame: Dict[str, Any]) -> None:
    async with lock:
        await ws.send_str(protocol.dumps(protocol.encode_event(frame)))


async def _pump(engine: ServingEngine, req: ForecastRequest, ws, lock: asyncio.Lock) -> None:
    """Stream one request's events to its connection until done/error.  A
    send failure (the client vanished, or an injected ``ws_send`` fault —
    indistinguishable from here) abandons the request: the engine stops
    emitting for its member slot and the rest of the batch is unaffected."""
    try:
        async for ev in engine.stream(req):
            engine.faults.check("ws_send", keys=(req.request_id,))
            await _send(ws, lock, ev)
    except asyncio.CancelledError:
        req.abandoned = True
        raise
    except Exception:  # noqa: BLE001 — any transport failure means nobody is listening
        req.abandoned = True


async def _handle_frame(
    engine: ServingEngine, msg: Dict[str, Any], ws, lock, pumps: Dict[asyncio.Task, ForecastRequest]
):
    kind = msg["type"]
    if kind == "programs":
        await _send(ws, lock, {"type": "catalog", "programs": engine.catalog()})
        return
    if kind != "forecast":
        raise ServingError(protocol.BAD_REQUEST, f"unknown frame type {kind!r}")
    kwargs = protocol.parse_forecast(msg)
    program = kwargs.pop("program")
    fields = kwargs.pop("fields")
    scalars = kwargs.pop("scalars")
    req = engine.submit(program, fields, scalars, **kwargs)
    task = asyncio.get_running_loop().create_task(_pump(engine, req, ws, lock))
    pumps[task] = req
    task.add_done_callback(lambda t: pumps.pop(t, None))


def create_app(engine: ServingEngine) -> "web.Application":
    _require_aiohttp()

    async def ws_handler(request: "web.Request") -> "web.WebSocketResponse":
        ws = web.WebSocketResponse()
        await ws.prepare(request)
        lock = asyncio.Lock()
        pumps: Dict[asyncio.Task, ForecastRequest] = {}
        try:
            async for raw in ws:
                if raw.type != WSMsgType.TEXT:
                    continue
                request_id = None
                try:
                    msg = protocol.loads(raw.data)
                    request_id = msg.get("request_id")
                    await _handle_frame(engine, msg, ws, lock, pumps)
                except ServingError as e:
                    await _send(
                        ws,
                        lock,
                        protocol.error_frame(
                            e.code, e.reason, request_id, retry_after_ms=e.retry_after_ms
                        ),
                    )
        finally:
            # connection gone: abandon every request still streaming so the
            # engine frees their member slots instead of gathering into the void
            for t, req in list(pumps.items()):
                req.abandoned = True
                t.cancel()
        return ws

    async def healthz(_request: "web.Request") -> "web.Response":
        ok = engine.state != DRAINING
        return web.json_response({"ok": ok, "state": engine.state}, status=200 if ok else 503)

    async def stats(_request: "web.Request") -> "web.Response":
        payload = engine.stats()
        payload["metrics"] = engine.metrics.collect()
        return web.json_response(payload)

    async def metrics(_request: "web.Request") -> "web.Response":
        return web.Response(
            body=engine.metrics.to_prometheus().encode("utf-8"),
            headers={"Content-Type": "text/plain; version=0.0.4; charset=utf-8"},
        )

    async def programs(_request: "web.Request") -> "web.Response":
        return web.json_response({"programs": engine.catalog()})

    async def slo(_request: "web.Request") -> "web.Response":
        return web.json_response(engine.slo.evaluate())

    async def autoscale(_request: "web.Request") -> "web.Response":
        return web.json_response(engine.autoscale_signal())

    app = web.Application()
    app.router.add_get("/ws", ws_handler)
    app.router.add_get("/healthz", healthz)
    app.router.add_get("/stats", stats)
    app.router.add_get("/metrics", metrics)
    app.router.add_get("/slo", slo)
    app.router.add_get("/autoscale", autoscale)
    app.router.add_get("/programs", programs)
    return app


class ForecastServer:
    """Engine + aiohttp app bound to a host:port (0 → ephemeral, see
    ``.port`` after ``start()``)."""

    def __init__(self, engine: ServingEngine, *, host: str = "127.0.0.1", port: int = 0):
        _require_aiohttp()
        self.engine = engine
        self.host = host
        self.port = port
        self._runner: Optional["web.AppRunner"] = None

    async def start(self) -> "ForecastServer":
        self._runner = web.AppRunner(create_app(self.engine))
        await self._runner.setup()
        site = web.TCPSite(self._runner, self.host, self.port)
        await site.start()
        self.port = site._server.sockets[0].getsockname()[1]
        return self

    @property
    def ws_url(self) -> str:
        return f"ws://{self.host}:{self.port}/ws"

    async def stop(self) -> None:
        if self._runner is not None:
            await self._runner.cleanup()
            self._runner = None
        await self.engine.aclose()

    async def __aenter__(self) -> "ForecastServer":
        return await self.start()

    async def __aexit__(self, *exc: Any) -> None:
        await self.stop()
