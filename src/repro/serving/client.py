"""Serving clients + the deterministic load generator.

Two drivers share one request/report shape:

* :func:`drive_engine` — in-process, pure asyncio against a
  :class:`~repro.serving.engine.ServingEngine` (no aiohttp; this is what the
  contract tests and the ``serving_throughput`` bench use, so the bench runs
  in the minimal CI environment).
* :func:`drive_server` — over a real websocket (aiohttp client) against a
  running :class:`~repro.serving.server.ForecastServer`.

Both issue all requests concurrently, record per-request latency
(submit → done), assert streamed steps arrive strictly in order, and keep
the streamed states so callers can verify bit-identity against sequential
execution."""

from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .engine import ServingEngine
from .protocol import ServingError, decode_event, dumps, encode_array, loads


@dataclass
class RequestSpec:
    """One simulated client request."""

    program: str
    fields: Dict[str, np.ndarray]
    scalars: Dict[str, Any] = field(default_factory=dict)
    steps: int = 1
    stream_every: int = 1
    stats: bool = False
    request_id: Optional[str] = None
    fingerprint: Optional[str] = None


@dataclass
class RequestResult:
    """What came back for one request."""

    request_id: str
    steps_seen: List[int]
    final_fields: Dict[str, np.ndarray]
    step_fields: Dict[int, Dict[str, np.ndarray]]
    latency_s: float
    occupancy: float
    members: int

    @property
    def in_order(self) -> bool:
        return self.steps_seen == sorted(self.steps_seen) and len(set(self.steps_seen)) == len(self.steps_seen)


@dataclass
class LoadReport:
    """Aggregate view of one load-generator run."""

    results: List[RequestResult]
    wall_s: float

    @property
    def requests(self) -> int:
        return len(self.results)

    @property
    def requests_per_second(self) -> float:
        return self.requests / self.wall_s if self.wall_s > 0 else float("inf")

    @property
    def latencies_ms(self) -> List[float]:
        return [r.latency_s * 1e3 for r in self.results]

    @property
    def p50_ms(self) -> float:
        return percentile(self.latencies_ms, 50.0)

    @property
    def p99_ms(self) -> float:
        return percentile(self.latencies_ms, 99.0)

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean([r.occupancy for r in self.results])) if self.results else 0.0

    @property
    def all_in_order(self) -> bool:
        return all(r.in_order for r in self.results)

    def summary(self) -> Dict[str, float]:
        return {
            "requests": self.requests,
            "wall_s": self.wall_s,
            "requests_per_second": self.requests_per_second,
            "p50_ms": self.p50_ms,
            "p99_ms": self.p99_ms,
            "mean_occupancy": self.mean_occupancy,
        }


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile — deterministic, no interpolation surprises."""
    if not values:
        return float("nan")
    ordered = sorted(values)
    rank = max(1, int(np.ceil(q / 100.0 * len(ordered))))
    return float(ordered[min(rank, len(ordered)) - 1])


def _fold_events(request_id: str, events: List[Dict[str, Any]], t0: float, keep: str) -> RequestResult:
    steps_seen: List[int] = []
    step_fields: Dict[int, Dict[str, np.ndarray]] = {}
    final_fields: Dict[str, np.ndarray] = {}
    occupancy, members, latency = 0.0, 0, time.perf_counter() - t0
    for ev in events:
        if ev["type"] == "error":
            raise ServingError(ev["code"], ev["reason"])
        if ev["type"] == "step":
            steps_seen.append(int(ev["step"]))
            if keep == "all":
                step_fields[int(ev["step"])] = ev["fields"]
            if keep in ("all", "final"):
                final_fields = ev["fields"]
        if ev["type"] == "done":
            occupancy = float(ev["batch"]["occupancy"])
            members = int(ev["batch"]["members"])
            latency = float(ev.get("latency_s", latency))
    return RequestResult(
        request_id=request_id,
        steps_seen=steps_seen,
        final_fields=final_fields,
        step_fields=step_fields,
        latency_s=latency,
        occupancy=occupancy,
        members=members,
    )


async def drive_engine(
    engine: ServingEngine, specs: Sequence[RequestSpec], *, keep_fields: str = "all"
) -> LoadReport:
    """Issue all specs concurrently against an in-process engine."""

    async def one(i: int, spec: RequestSpec) -> RequestResult:
        t0 = time.perf_counter()
        req = engine.submit(
            spec.program,
            spec.fields,
            spec.scalars,
            steps=spec.steps,
            stream_every=spec.stream_every,
            fingerprint=spec.fingerprint,
            request_id=spec.request_id or f"load-{i}",
            stats=spec.stats,
        )
        events = [ev async for ev in engine.stream(req)]
        return _fold_events(req.request_id, events, t0, keep_fields)

    t0 = time.perf_counter()
    results = await asyncio.gather(*(one(i, s) for i, s in enumerate(specs)))
    return LoadReport(results=list(results), wall_s=time.perf_counter() - t0)


async def drive_server(
    url: str, specs: Sequence[RequestSpec], *, keep_fields: str = "all"
) -> LoadReport:
    """Issue all specs concurrently over one real websocket connection."""
    try:
        import aiohttp
    except ImportError:
        raise RuntimeError("drive_server needs aiohttp (pip install repro[serving])") from None

    ids = [s.request_id or f"load-{i}" for i, s in enumerate(specs)]
    events: Dict[str, List[Dict[str, Any]]] = {rid: [] for rid in ids}
    done: Dict[str, asyncio.Event] = {rid: asyncio.Event() for rid in ids}
    t0s: Dict[str, float] = {}

    async with aiohttp.ClientSession() as session:
        async with session.ws_connect(url) as ws:

            async def reader() -> None:
                async for raw in ws:
                    if raw.type != aiohttp.WSMsgType.TEXT:
                        continue
                    ev = decode_event(loads(raw.data))
                    rid = ev.get("request_id")
                    if rid in events:
                        events[rid].append(ev)
                        if ev["type"] in ("done", "error"):
                            done[rid].set()

            pump = asyncio.get_running_loop().create_task(reader())
            t0 = time.perf_counter()
            for rid, spec in zip(ids, specs):
                t0s[rid] = time.perf_counter()
                frame = {
                    "type": "forecast",
                    "request_id": rid,
                    "program": spec.program,
                    "steps": spec.steps,
                    "stream_every": spec.stream_every,
                    "fields": {n: encode_array(a) for n, a in spec.fields.items()},
                    "scalars": {n: float(v) for n, v in spec.scalars.items()},
                    "stats": spec.stats,
                }
                if spec.fingerprint is not None:
                    frame["fingerprint"] = spec.fingerprint
                await ws.send_str(dumps(frame))
            await asyncio.gather(*(d.wait() for d in done.values()))
            wall = time.perf_counter() - t0
            pump.cancel()
    results = [_fold_events(rid, events[rid], t0s[rid], keep_fields) for rid in ids]
    return LoadReport(results=results, wall_s=wall)
