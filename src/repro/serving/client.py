"""Serving clients + the deterministic load generator.

Two drivers share one request/report shape:

* :func:`drive_engine` — in-process, pure asyncio against a
  :class:`~repro.serving.engine.ServingEngine` (no aiohttp; this is what the
  contract tests and the ``serving_throughput`` bench use, so the bench runs
  in the minimal CI environment).
* :func:`drive_server` — over a real websocket (aiohttp client) against a
  running :class:`~repro.serving.server.ForecastServer`.

Both issue all requests concurrently, record per-request latency
(submit → done), assert streamed steps arrive strictly in order, and keep
the streamed states so callers can verify bit-identity against sequential
execution.

Resilience: both drivers honor 503 ``OVERLOADED`` rejections by backing off
``retry_after_ms`` and resubmitting, up to ``retry_503`` attempts; the
websocket driver additionally bounds the connect and per-frame read waits
(``connect_timeout_s`` / ``read_timeout_s``) so a dead server yields error
results instead of a hung client.  A request that ends in an ``error`` event
(or times out) folds into a :class:`RequestResult` carrying ``error_code`` /
``error_reason`` rather than raising — load reports under fault injection
count recovered vs. failed requests instead of dying on the first casualty."""

from __future__ import annotations

import asyncio
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.trace import monotonic

from .engine import ServingEngine
from .protocol import OVERLOADED, ServingError, decode_event, dumps, encode_array, loads

#: error code used for client-side failures (timeouts, closed connections)
#: that never reached the server — deliberately outside the HTTP range
CLIENT_TIMEOUT = 0

#: never sleep longer than this on a 503, whatever retry_after_ms claims
MAX_RETRY_SLEEP_S = 2.0


@dataclass
class RequestSpec:
    """One simulated client request."""

    program: str
    fields: Dict[str, np.ndarray]
    scalars: Dict[str, Any] = field(default_factory=dict)
    steps: int = 1
    stream_every: int = 1
    stats: bool = False
    request_id: Optional[str] = None
    fingerprint: Optional[str] = None
    deadline_ms: Optional[float] = None
    priority: Optional[int] = None  # urgency class, lower = more urgent


@dataclass
class RequestResult:
    """What came back for one request; ``error_code`` is None iff it completed."""

    request_id: str
    steps_seen: List[int]
    final_fields: Dict[str, np.ndarray]
    step_fields: Dict[int, Dict[str, np.ndarray]]
    latency_s: float
    occupancy: float
    members: int
    error_code: Optional[int] = None
    error_reason: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error_code is None

    @property
    def in_order(self) -> bool:
        ordered = self.steps_seen == sorted(self.steps_seen)
        return ordered and len(set(self.steps_seen)) == len(self.steps_seen)


@dataclass
class LoadReport:
    """Aggregate view of one load-generator run.  Latency percentiles cover
    *completed* requests only; errored ones show up in ``errors`` and drag
    ``recovered_rate`` down instead of polluting the timing."""

    results: List[RequestResult]
    wall_s: float

    @property
    def requests(self) -> int:
        return len(self.results)

    @property
    def completed(self) -> List[RequestResult]:
        return [r for r in self.results if r.ok]

    @property
    def errors(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for r in self.results:
            if not r.ok:
                out[r.error_code] = out.get(r.error_code, 0) + 1
        return out

    @property
    def recovered_rate(self) -> float:
        return len(self.completed) / self.requests if self.requests else 0.0

    @property
    def requests_per_second(self) -> float:
        return self.requests / self.wall_s if self.wall_s > 0 else float("inf")

    @property
    def latencies_ms(self) -> List[float]:
        return [r.latency_s * 1e3 for r in self.completed]

    @property
    def p50_ms(self) -> float:
        return percentile(self.latencies_ms, 50.0)

    @property
    def p99_ms(self) -> float:
        return percentile(self.latencies_ms, 99.0)

    @property
    def mean_occupancy(self) -> float:
        done = self.completed
        return float(np.mean([r.occupancy for r in done])) if done else 0.0

    @property
    def all_in_order(self) -> bool:
        return all(r.in_order for r in self.results)

    def summary(self) -> Dict[str, float]:
        return {
            "requests": self.requests,
            "completed": len(self.completed),
            "recovered_rate": self.recovered_rate,
            "wall_s": self.wall_s,
            "requests_per_second": self.requests_per_second,
            "p50_ms": self.p50_ms,
            "p99_ms": self.p99_ms,
            "mean_occupancy": self.mean_occupancy,
        }


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile — deterministic, no interpolation surprises."""
    if not values:
        return float("nan")
    ordered = sorted(values)
    rank = max(1, int(np.ceil(q / 100.0 * len(ordered))))
    return float(ordered[min(rank, len(ordered)) - 1])


def _fold_events(request_id: str, events: List[Dict[str, Any]], t0: float, keep: str) -> RequestResult:
    steps_seen: List[int] = []
    step_fields: Dict[int, Dict[str, np.ndarray]] = {}
    final_fields: Dict[str, np.ndarray] = {}
    occupancy, members, latency = 0.0, 0, monotonic() - t0
    error_code: Optional[int] = None
    error_reason: Optional[str] = None
    for ev in events:
        if ev["type"] == "error":
            error_code = int(ev.get("code", 500))
            error_reason = str(ev.get("reason", ""))
        if ev["type"] == "step":
            steps_seen.append(int(ev["step"]))
            if keep == "all":
                step_fields[int(ev["step"])] = ev["fields"]
            if keep in ("all", "final"):
                final_fields = ev["fields"]
        if ev["type"] == "done":
            occupancy = float(ev["batch"]["occupancy"])
            members = int(ev["batch"]["members"])
            latency = float(ev.get("latency_s", latency))
    return RequestResult(
        request_id=request_id,
        steps_seen=steps_seen,
        final_fields=final_fields,
        step_fields=step_fields,
        latency_s=latency,
        occupancy=occupancy,
        members=members,
        error_code=error_code,
        error_reason=error_reason,
    )


def _retry_sleep_s(retry_after_ms: Optional[float], attempt: int = 1) -> float:
    """How long to back off before resubmitting a 503-rejected request: the
    server's estimate, scaled up linearly per attempt (the estimate proving
    optimistic is itself a sign of overload), floored and capped."""
    base = 0.01 if retry_after_ms is None or retry_after_ms <= 0 else retry_after_ms / 1e3
    return min(max(base, 0.005) * max(attempt, 1), MAX_RETRY_SLEEP_S)


async def drive_engine(
    engine: ServingEngine,
    specs: Sequence[RequestSpec],
    *,
    keep_fields: str = "all",
    retry_503: int = 3,
) -> LoadReport:
    """Issue all specs concurrently against an in-process engine."""

    async def one(i: int, spec: RequestSpec) -> RequestResult:
        rid = spec.request_id or f"load-{i}"
        t0 = monotonic()
        attempt = 0
        while True:
            try:
                req = engine.submit(
                    spec.program,
                    spec.fields,
                    spec.scalars,
                    steps=spec.steps,
                    stream_every=spec.stream_every,
                    fingerprint=spec.fingerprint,
                    request_id=rid,
                    stats=spec.stats,
                    deadline_ms=spec.deadline_ms,
                    priority=spec.priority,
                )
                break
            except ServingError as e:
                if e.code == OVERLOADED and attempt < retry_503:
                    attempt += 1
                    await asyncio.sleep(_retry_sleep_s(e.retry_after_ms, attempt))
                    continue
                return _fold_events(
                    rid,
                    [{"type": "error", "code": e.code, "reason": e.reason}],
                    t0,
                    keep_fields,
                )
        events = [ev async for ev in engine.stream(req)]
        return _fold_events(rid, events, t0, keep_fields)

    t0 = monotonic()
    results = await asyncio.gather(*(one(i, s) for i, s in enumerate(specs)))
    return LoadReport(results=list(results), wall_s=monotonic() - t0)


def _forecast_frame(rid: str, spec: RequestSpec) -> Dict[str, Any]:
    frame: Dict[str, Any] = {
        "type": "forecast",
        "request_id": rid,
        "program": spec.program,
        "steps": spec.steps,
        "stream_every": spec.stream_every,
        "fields": {n: encode_array(a) for n, a in spec.fields.items()},
        "scalars": {n: float(v) for n, v in spec.scalars.items()},
        "stats": spec.stats,
    }
    if spec.fingerprint is not None:
        frame["fingerprint"] = spec.fingerprint
    if spec.deadline_ms is not None:
        frame["deadline_ms"] = spec.deadline_ms
    if spec.priority is not None:
        frame["priority"] = spec.priority
    return frame


async def drive_server(
    url: str,
    specs: Sequence[RequestSpec],
    *,
    keep_fields: str = "all",
    connect_timeout_s: float = 10.0,
    read_timeout_s: float = 60.0,
    retry_503: int = 3,
) -> LoadReport:
    """Issue all specs concurrently over one real websocket connection.

    The connect wait and every frame read are bounded; a server that stops
    answering turns still-pending requests into ``CLIENT_TIMEOUT`` error
    results rather than hanging the driver.  503 rejections are resubmitted
    after their advertised ``retry_after_ms`` (capped), ``retry_503`` times."""
    try:
        import aiohttp
    except ImportError:
        raise RuntimeError("drive_server needs aiohttp (pip install repro[serving])") from None

    ids = [s.request_id or f"load-{i}" for i, s in enumerate(specs)]
    frames = {rid: _forecast_frame(rid, spec) for rid, spec in zip(ids, specs)}
    events: Dict[str, List[Dict[str, Any]]] = {rid: [] for rid in ids}
    done: Dict[str, asyncio.Event] = {rid: asyncio.Event() for rid in ids}
    retries: Dict[str, int] = {rid: 0 for rid in ids}
    t0s: Dict[str, float] = {}

    def _fail_pending(reason: str) -> None:
        for rid, d in done.items():
            if not d.is_set():
                events[rid].append(
                    {"type": "error", "code": CLIENT_TIMEOUT, "reason": reason, "request_id": rid}
                )
                d.set()

    async with aiohttp.ClientSession() as session:
        ws = await asyncio.wait_for(session.ws_connect(url), connect_timeout_s)
        resend_tasks: List[asyncio.Task] = []
        try:

            async def resend(rid: str, after_ms: Optional[float]) -> None:
                await asyncio.sleep(_retry_sleep_s(after_ms, retries[rid]))
                await ws.send_str(dumps(frames[rid]))

            async def reader() -> None:
                loop = asyncio.get_running_loop()
                while not all(d.is_set() for d in done.values()):
                    try:
                        raw = await ws.receive(timeout=read_timeout_s)
                    except asyncio.TimeoutError:
                        _fail_pending(f"no frame from server within {read_timeout_s}s")
                        return
                    if raw.type in (
                        aiohttp.WSMsgType.CLOSE,
                        aiohttp.WSMsgType.CLOSED,
                        aiohttp.WSMsgType.ERROR,
                    ):
                        _fail_pending("connection closed by server")
                        return
                    if raw.type != aiohttp.WSMsgType.TEXT:
                        continue
                    ev = decode_event(loads(raw.data))
                    rid = ev.get("request_id")
                    if rid not in events:
                        continue
                    if ev["type"] == "error" and ev.get("code") == OVERLOADED and retries[rid] < retry_503:
                        retries[rid] += 1
                        resend_tasks.append(loop.create_task(resend(rid, ev.get("retry_after_ms"))))
                        continue
                    events[rid].append(ev)
                    if ev["type"] in ("done", "error"):
                        done[rid].set()

            pump = asyncio.get_running_loop().create_task(reader())
            t0 = monotonic()
            for rid in ids:
                t0s[rid] = monotonic()
                await ws.send_str(dumps(frames[rid]))
            await asyncio.gather(*(d.wait() for d in done.values()))
            wall = monotonic() - t0
            pump.cancel()
            for t in resend_tasks:
                t.cancel()
        finally:
            await ws.close()
    results = [_fold_events(rid, events[rid], t0s[rid], keep_fields) for rid in ids]
    return LoadReport(results=results, wall_s=wall)
