"""Forecast-as-a-service engine: requests-as-members dynamic batching.

The PR-4 ensemble machinery is a request batcher in disguise: vmapped members
are *independent*, so K concurrent forecast requests can ride the member axis
of ONE batched ``iterate`` dispatch instead of K sequential program calls.
The engine holds compiled artifacts hot and turns a stream of websocket-sized
requests into full batches:

1. **Admission** — requests are admitted against a registered
   :class:`ProgramEntry` keyed by the existing
   ``caching.program_fingerprint``: unknown programs 404, stale fingerprints
   409, wrong field shapes/dtypes 413, bad scalars/steps 422.  A request that
   would trigger a recompile is *rejected at the door*, never silently
   stalled behind a trace+jit.  The admission queue is **bounded**: a full
   queue rejects with 503 + ``retry_after_ms`` (computed from the watchdog's
   median dispatch wall and the queue depth) instead of buffering unbounded
   work it cannot finish.
2. **Batching window** — a worker task moves arrivals into the pluggable
   scheduler's backlog (:mod:`serving.scheduler`): on an empty backlog it
   blocks for the first arrival, then keeps collecting until ``window_ms``
   elapses or the backlog covers the present programs' member caps; a
   non-empty backlog dispatches immediately (only already-arrived requests
   join).  The scheduler then forms **per-program windows in urgency order**
   (default ``edf``: earliest deadline first within priority classes —
   FIFO-identical when requests carry neither), distinct programs dispatch
   concurrently, and the surplus stays in the backlog where it is re-ordered
   against newer, possibly more urgent, arrivals every round.  Requests that
   expired while queued are 504'd at pickup without burning a dispatch.
   Under load (state ``DEGRADED``) the window shrinks so queued work drains
   faster.
3. **Padding to tuned member counts** — the batch is padded up to the nearest
   registered member count (by default the counts with a persisted autotune
   ``batch`` record, via :func:`tuned_member_counts`, plus small powers of
   two) by repeating the last request's state.  Padded members compute
   garbage nobody gathers; in exchange every dispatch reuses a warm,
   possibly autotuned, jit artifact.  The loop closes both ways: observed
   ``(batch size → wall)`` records are written back into the tune store
   (:func:`repro.core.autotune.record_batch_observation`), so the counts
   :func:`tuned_member_counts` prefers are learned from real traffic.
4. **Segmented iterate + streaming** — the union of the batch's stream points
   splits the horizon into segments; each segment is one vmapped
   ``Ensemble.iterate`` dispatch, after which per-request member slices are
   gathered (host copies) and streamed as ``step`` events.  Chunking is
   bit-safe: ``iterate(a); iterate(b)`` ≡ ``iterate(a+b)`` ≡ the sequential
   per-request loop, which the contract tests assert to 0 ULP in float64.

Resilience (the failure model, chaos-tested via :mod:`serving.faults`):

* **Deadlines** — a request may carry ``deadline_ms``; expiry is checked at
  window pickup (a request that died in the queue is 504'd before any
  scatter or dispatch is spent on it) and again at every segment boundary,
  so expired requests get a 504-style ``error`` event instead of burning
  further dispatches.
* **Retry-with-bisect** — a failed batched dispatch retries with exponential
  backoff; if it keeps failing and the batch holds more than one request,
  the batch is *bisected* (current member states gathered and re-scattered
  into two half-batches) so one poison request ends up alone, gets its own
  ``error`` event, and its co-batched neighbors still complete — and because
  gather→re-scatter round-trips bit-exactly and ``iterate`` chunks exactly,
  the survivors remain bit-identical to their unfaulted sequential runs.
* **Health states** — ``SERVING`` → ``DEGRADED`` (queue above the watermark:
  sheds per-step statistics and shrinks the batching window) → ``DRAINING``
  (:meth:`ServingEngine.drain`: stop admitting, finish in-flight work, then
  stop the worker) — the graceful-SIGTERM path of the serve CLI.
* **No orphaned requests** — a worker-level failure (e.g. while grouping)
  fails every in-flight request with an ``error`` event and the worker keeps
  running; a worker *death* fails everything queued and the next submission
  respawns it.  Every accepted request terminates.

The engine is pure asyncio + numpy/jax — no websocket dependency; transports
(``serving.server``) and in-process drivers (``serving.client``) sit on top.
"""

from __future__ import annotations

import asyncio
import contextvars
import itertools
import json
import math
from contextlib import nullcontext
from dataclasses import dataclass, field as dc_field
from typing import Any, AsyncIterator, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import autotune, caching
from repro.core.storage import Storage
from repro.ensemble import Ensemble
from repro.ensemble import batch as ens_batch
from repro.obs import metrics as obs_metrics
from repro.obs import slo as obs_slo
from repro.obs import trace as otrace
from repro.obs.export import jax_profiler_span
from repro.obs.flight import FlightRecorder
from repro.obs.trace import monotonic
from repro.program.compile import ProgramObject
from repro.runtime.supervise import StragglerWatchdog

from .faults import FaultInjector, InjectedFault
from .protocol import (
    DEADLINE_EXCEEDED,
    FINGERPRINT_MISMATCH,
    INTERNAL,
    INVALID_VALUE,
    OVERLOADED,
    SHAPE_MISMATCH,
    UNKNOWN_PROGRAM,
    ServingError,
)
from .scheduler import BatchingScheduler, make_scheduler

#: padding targets always available, even with no autotune record on disk
DEFAULT_MEMBER_COUNTS = (1, 2, 4, 8, 16)

#: engine health states
SERVING = "SERVING"
DEGRADED = "DEGRADED"
DRAINING = "DRAINING"

#: per-program counter families: stats() flat key → (family name, help).
#: Every one of these carries a ``program`` label so a multi-program engine
#: is diagnosable per workload on /metrics and /stats.
PROGRAM_COUNTERS = (
    ("requests", "serving_requests_total", "requests admitted"),
    ("batches", "serving_batches_total", "batching windows dispatched"),
    ("dispatches", "serving_dispatches_total", "segment dispatches completed"),
    ("steps_streamed", "serving_steps_streamed_total", "step events emitted"),
    ("padded_members", "serving_padded_members_total",
     "member slots dispatched (padding included)"),
    ("live_members", "serving_live_members_total",
     "request-backed member slots dispatched"),
    ("deadline_expired", "serving_deadline_expired_total",
     "requests expired at window pickup or a segment boundary"),
    ("retries", "serving_retries_total", "scatter/dispatch/gather retries"),
    ("bisects", "serving_bisects_total", "batch bisections after exhausted retries"),
    ("abandoned", "serving_abandoned_total", "requests abandoned by clients"),
)

#: per-program histogram families: entry key → (family name, help)
PROGRAM_HISTOGRAMS = (
    ("occupancy", "serving_batch_occupancy", "live members / padded members per batch"),
    ("dispatch", "serving_dispatch_seconds", "segment dispatch wall seconds"),
    ("queue_wait", "serving_queue_wait_seconds", "submit-to-window-pickup wait seconds"),
    ("latency", "serving_request_latency_seconds", "submit-to-done latency seconds"),
)


def tuned_member_counts(cp, faults: Optional[FaultInjector] = None) -> List[int]:
    """Member counts with a persisted autotune ``batch`` record.

    The Pallas autotuner writes ``<name>_<fp>.tune.json`` next to each
    generated group module (``caching.tuning_path``); records measured on
    member-batched shapes carry the batch extent under ``"batch"``.  Those
    extents are exactly the batch sizes the store holds a measured tile for,
    so the engine prefers padding to them.  An unreadable store (or an
    injected ``tune_read`` fault) degrades gracefully to the default counts —
    tuning data is an optimization, never a liveness dependency."""
    counts = set()
    for obj in getattr(cp, "group_objects", ()):
        path = caching.tuning_path(obj.name, obj.fingerprint)
        try:
            if faults is not None:
                faults.check("tune_read", keys=(obj.name,))
            store = json.loads(path.read_text())
        except (OSError, ValueError, InjectedFault):
            continue
        for rec in store.get("domains", {}).values():
            b = rec.get("batch") if isinstance(rec, dict) else None
            if b:
                counts.add(int(b))
    return sorted(counts)


@dataclass
class ForecastRequest:
    """One admitted request: inputs plus the event queue results stream to."""

    request_id: str
    entry: "ProgramEntry"
    steps: int
    stream_every: int
    fields: Dict[str, np.ndarray]
    scalars: Dict[str, Any]
    want_stats: bool = False
    deadline_ms: Optional[float] = None
    priority: int = 0  # urgency class in [0, engine.priority_classes), 0 most urgent
    seq: int = 0  # admission sequence number — the deterministic tiebreaker
    submitted_at: float = 0.0
    sampled: bool = True  # head-sampling decision, made once at submit
    queue_wait_s: Optional[float] = None  # submit → window pickup, set by the worker
    deadline_at: Optional[float] = None  # monotonic deadline, set at submit
    abandoned: bool = False  # transport saw the client vanish — stop emitting
    terminal: bool = False  # a done/error was posted; later events are dropped
    events: "asyncio.Queue[Dict[str, Any]]" = dc_field(default_factory=asyncio.Queue)

    def post(self, event: Dict[str, Any]) -> None:
        """Deliver one event; a terminal event seals the stream (at-most-one
        ``done``/``error`` per request, no matter how many failure paths
        race) and an abandoned request drops events instead of buffering
        frames nobody will read."""
        if self.terminal:
            return
        if event["type"] in ("done", "error"):
            self.terminal = True
        elif self.abandoned:
            return
        self.events.put_nowait(event)

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline_at is None:
            return False
        return (monotonic() if now is None else now) > self.deadline_at


class ProgramEntry:
    """One registered program held hot: the compiled single-member artifact,
    per-member-count ensembles, and the admission contract requests are
    checked against."""

    def __init__(
        self,
        engine: "ServingEngine",
        prog: ProgramObject,
        *,
        fields: Dict[str, Storage],
        scalars: Dict[str, Any],
        request_fields: Sequence[str],
        stream_fields: Optional[Sequence[str]] = None,
        member_counts: Optional[Sequence[int]] = None,
        max_steps: int = 10_000,
    ):
        if prog.backend not in ("jax", "pallas"):
            raise ServingError(INTERNAL, f"serving requires a jax-family program, not {prog.backend!r}")
        missing = [n for n in prog.field_params if n not in fields]
        if missing:
            raise ServingError(INTERNAL, f"register({prog.name!r}): missing template fields {missing}")
        missing = [n for n in prog.scalar_params if n not in scalars]
        if missing:
            raise ServingError(INTERNAL, f"register({prog.name!r}): missing default scalars {missing}")
        bad = [n for n in request_fields if n not in prog.field_params]
        if bad:
            raise ServingError(INTERNAL, f"register({prog.name!r}): unknown request fields {bad}")
        self.engine = engine
        self.prog = prog
        self.name = prog.name
        self.fields = {n: fields[n] for n in prog.field_params}
        self.scalars = {n: scalars[n] for n in prog.scalar_params}
        self.request_fields = tuple(request_fields)
        self.stream_fields = tuple(stream_fields or request_fields)

        # compile (or hit the cache for) the single-member artifact NOW —
        # admission is a fingerprint check, never a recompile stall later
        cp = prog.compiled(self.fields, self.scalars)
        if cp.iterable_reason is not None:
            raise ServingError(INTERNAL, f"program {prog.name!r} cannot be served: {cp.iterable_reason}")
        self.cp = cp
        self.fingerprint = cp.fingerprint

        # everything the program writes must be member-batched (members would
        # race on one buffer) — same classification the ensemble layer enforces
        written = set(cp.written_buffers) | set(cp.outputs.values())
        written |= {o for o in cp.outputs if o in self.fields}
        self.batched_fields = tuple(
            sorted(set(self.request_fields) | {b for b in written if b in self.fields})
        )
        self.shared_fields = tuple(n for n in prog.field_params if n not in self.batched_fields)

        counts = (
            list(member_counts)
            if member_counts
            else tuned_member_counts(cp, faults=engine.faults) + list(DEFAULT_MEMBER_COUNTS)
        )
        self.member_counts = tuple(sorted({int(c) for c in counts if int(c) >= 1}))
        if not self.member_counts:
            raise ServingError(INTERNAL, f"register({prog.name!r}): empty member_counts")
        self.max_batch = self.member_counts[-1]
        self.max_steps = int(max_steps)
        self.ensembles = {m: Ensemble(prog, m, name=f"{self.name}_serve{m}") for m in self.member_counts}
        # per-program labeled metric children, created eagerly so /metrics
        # shows zeroed families for every registered program from the start
        self.counters, self.hist = engine._program_metrics(self.name)

    def pad_to(self, k: int) -> int:
        """Smallest registered member count holding ``k`` live requests."""
        for m in self.member_counts:
            if m >= k:
                return m
        return self.max_batch

    def admit_fields(self, fields: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        got, want = set(fields), set(self.request_fields)
        if got != want:
            missing, extra = sorted(want - got), sorted(got - want)
            raise ServingError(
                SHAPE_MISMATCH,
                f"program {self.name!r} takes request fields {sorted(want)}"
                + (f"; missing {missing}" if missing else "")
                + (f"; unexpected {extra}" if extra else ""),
            )
        out = {}
        for n in self.request_fields:
            arr = np.asarray(fields[n])
            tmpl = self.fields[n]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ServingError(
                    SHAPE_MISMATCH,
                    f"field {n!r} has shape {tuple(arr.shape)}, program {self.name!r} is compiled "
                    f"for {tuple(tmpl.shape)} — other geometries are not admitted (no recompile)",
                )
            if str(arr.dtype) != str(tmpl.dtype):
                raise ServingError(
                    SHAPE_MISMATCH, f"field {n!r} has dtype {arr.dtype}, program expects {tmpl.dtype}"
                )
            out[n] = arr
        return out

    def admit_scalars(self, scalars: Dict[str, Any]) -> Dict[str, Any]:
        bad = [n for n in scalars if n not in self.scalars]
        if bad:
            raise ServingError(
                INVALID_VALUE, f"unknown scalars {sorted(bad)}; program takes {sorted(self.scalars)}"
            )
        for n, v in scalars.items():
            if np.ndim(v) != 0:
                raise ServingError(INVALID_VALUE, f"scalar {n!r} must be a number, got shape {np.shape(v)}")
        merged = dict(self.scalars)
        merged.update({n: float(v) for n, v in scalars.items()})
        return merged

    def warm(self, chunk: int = 1) -> None:
        """Pre-trace/jit every member count so the first real batch pays
        dispatch cost only.  ``chunk`` should match the serving segment
        length (``stream_every``) when known — the iterate jit is keyed on
        the step count."""
        sample = {n: np.asarray(self.fields[n].data) for n in self.request_fields}
        for m in self.member_counts:
            storages = self._batch_storages([sample], m)
            self.ensembles[m].iterate(
                int(chunk), *[storages[n] for n in self.prog.field_params], **self.scalars
            )

    def _batch_storages(
        self, states: List[Dict[str, np.ndarray]], m: int, *, full_state: bool = False
    ) -> Dict[str, Storage]:
        """Scatter K requests into member slots of fresh batched storages.

        A fresh batch (``full_state=False``) scatters request fields onto the
        member axis and broadcasts written workspace fresh per batch (never
        reused — a batch must not see a previous batch's scratch).  A
        *resumed* batch (``full_state=True``, the retry-with-bisect path)
        scatters every batched field from the members' gathered mid-horizon
        states, so the re-formed half-batch continues bit-exactly where the
        failed dispatch left off.  Shared read-only fields pass through as
        the registered template storages either way, which the ensemble layer
        broadcasts without materializing copies and never writes back."""
        storages: Dict[str, Storage] = {}
        scattered = self.batched_fields if full_state else self.request_fields
        for n in self.prog.field_params:
            tmpl = self.fields[n]
            if n in scattered:
                storages[n] = ens_batch.scatter_members([s[n] for s in states], m, template=tmpl)
            elif n in self.batched_fields:
                storages[n] = ens_batch.broadcast(tmpl, m)
            else:
                storages[n] = tmpl
        return storages

    def gather_state(self, storages: Dict[str, Storage], i: int) -> Dict[str, np.ndarray]:
        """Member ``i``'s complete batched state as host copies — everything
        needed to resume its horizon in a fresh batch (bisect path)."""
        return {n: ens_batch.gather_member(storages[n], i) for n in self.batched_fields}

    def describe(self) -> Dict[str, Any]:
        return {
            "program": self.name,
            "backend": self.prog.backend,
            "fingerprint": self.fingerprint,
            "request_fields": {
                n: {"shape": list(self.fields[n].shape), "dtype": str(self.fields[n].dtype)}
                for n in self.request_fields
            },
            "stream_fields": list(self.stream_fields),
            "scalars": {n: float(v) for n, v in self.scalars.items()},
            "member_counts": list(self.member_counts),
            "max_steps": self.max_steps,
        }


def _segment_plan(requests: Sequence[ForecastRequest]) -> List[int]:
    """Split the batch horizon at the union of every request's stream points
    (multiples of its ``stream_every`` plus its final step), so each segment
    is one fused dispatch and every emission lands on a segment boundary."""
    points = sorted(
        {
            t
            for r in requests
            for t in itertools.chain(range(r.stream_every, r.steps + 1, r.stream_every), (r.steps,))
        }
    )
    segments, prev = [], 0
    for t in points:
        segments.append(t - prev)
        prev = t
    return segments


def _field_stats(arr: np.ndarray) -> Dict[str, float]:
    return {"min": float(arr.min()), "max": float(arr.max()), "mean": float(arr.mean())}


class ServingEngine:
    """The asyncio compute server core: admission, batching, streaming,
    and the resilience policies (backpressure, deadlines, retry-with-bisect,
    health states) that keep it operable under faults and overload."""

    def __init__(
        self,
        *,
        window_ms: float = 2.0,
        straggler_factor: float = 3.0,
        max_queue: int = 128,
        degraded_watermark: float = 0.5,
        retry_attempts: int = 3,
        retry_backoff_ms: float = 20.0,
        faults: Optional[FaultInjector] = None,
        tracer: Optional[otrace.Tracer] = None,
        metrics: Optional[obs_metrics.MetricsRegistry] = None,
        jax_profile: bool = False,
        slos: Optional[Sequence[obs_slo.Objective]] = None,
        autoscaler: Optional[obs_slo.Autoscaler] = None,
        flight: Optional[FlightRecorder] = None,
        scheduler: Union[str, BatchingScheduler, None] = None,
        priority_classes: int = 3,
    ):
        self.window_s = float(window_ms) / 1e3
        self.max_queue = int(max_queue)
        self.degraded_watermark = float(degraded_watermark)
        self.retry_attempts = max(1, int(retry_attempts))
        self.retry_backoff_s = float(retry_backoff_ms) / 1e3
        self.faults = faults if faults is not None else FaultInjector.from_env()
        self._programs: Dict[str, ProgramEntry] = {}
        self._queue: "asyncio.Queue[ForecastRequest]" = asyncio.Queue()
        self._worker: Optional[asyncio.Task] = None
        self._request_ids = itertools.count()
        self._batch_seq = itertools.count()
        self._dispatch_seq = itertools.count()
        self._submit_seq = itertools.count()
        self._inflight = 0
        self._draining = False
        self.scheduler = make_scheduler(scheduler)
        self.priority_classes = max(1, int(priority_classes))
        # best observed us/step per (program, batch size) — gates tune-store
        # write-backs so the hot path rewrites the store only on improvement
        self._batch_best: Dict[Tuple[str, int], float] = {}
        self.watchdog = StragglerWatchdog(factor=straggler_factor)
        # a fixed tracer wins; otherwise spans follow the contextvar routing
        # (capture() overrides, REPRO_TRACE/configure() for the process default)
        self._tracer = tracer
        self.jax_profile = bool(jax_profile)
        # every operational counter lives in the registry; stats() is a view
        # of it, and the transport serves to_prometheus() on GET /metrics
        self.metrics = metrics if metrics is not None else obs_metrics.MetricsRegistry()
        reg = self.metrics
        # per-program counters/histograms (PROGRAM_COUNTERS/_HISTOGRAMS) are
        # created at registration and live on each ProgramEntry; only the
        # genuinely engine-global instruments stay unlabeled here
        self._c: Dict[str, obs_metrics.Counter] = {
            "rejected_overloaded": reg.counter(
                "serving_rejected_overloaded_total", "503 backpressure rejections"
            ),
            "worker_failures": reg.counter(
                "serving_worker_failures_total", "batching-worker failures survived"
            ),
        }
        reg.gauge(
            "serving_queue_depth",
            "requests waiting for dispatch (admission queue + scheduler backlog)",
            fn=self.queue_depth,
        )
        reg.gauge(
            "serving_inflight",
            "requests inside a batching window or dispatch",
            fn=lambda: self._inflight,
        )
        for st in (SERVING, DEGRADED, DRAINING):
            reg.gauge(
                "serving_state",
                "engine health state (1 marks the current state)",
                fn=lambda s=st: float(self.state == s),
                state=st,
            )
        self._h_window = reg.histogram(
            "serving_window_requests", "requests collected per batching window"
        )
        # SLO evaluation + the autoscaling signal read the same registry the
        # counters above write; breaches trigger a flight-recorder dump
        self.slo = obs_slo.SloEngine(
            reg, list(slos or ()), tracer=self._trace, on_breach=self._on_slo_breach
        )
        # latency objectives evaluate over windows scaled to the batching
        # window, so a breach recovery is observable within one evaluation
        # cycle of good traffic instead of waiting out the 5-minute default
        self.slo.wire_batch_window(self.window_s)
        self.autoscaler = autoscaler if autoscaler is not None else obs_slo.Autoscaler()
        self.flight = flight if flight is not None else FlightRecorder.from_env()
        if self.flight is not None:
            self.flight.bind(
                tracer=self._trace,
                metrics=reg,
                stats=self.stats,
                slo=self.slo,
                config={
                    "window_ms": self.window_s * 1e3,
                    "scheduler": self.scheduler.name,
                    "priority_classes": self.priority_classes,
                    "max_queue": self.max_queue,
                    "degraded_watermark": self.degraded_watermark,
                    "retry_attempts": self.retry_attempts,
                    "retry_backoff_ms": self.retry_backoff_s * 1e3,
                },
            )

    # -- telemetry plumbing --------------------------------------------------

    def _trace(self) -> otrace.Tracer:
        return self._tracer if self._tracer is not None else otrace.current_tracer()

    def _span(self, name: str, **kwargs: Any):
        return self._trace().span(name, category="serving", **kwargs)

    def _tevent(self, name: str, **kwargs: Any) -> None:
        self._trace().event(name, category="serving", **kwargs)

    def _program_metrics(
        self, program: str
    ) -> Tuple[Dict[str, obs_metrics.Counter], Dict[str, obs_metrics.Histogram]]:
        """The labeled children every registered program gets (cached on its
        ProgramEntry so the hot path never rebuilds a label key)."""
        reg = self.metrics
        counters = {
            key: reg.counter(fam, help_, program=program)
            for key, fam, help_ in PROGRAM_COUNTERS
        }
        hists = {
            key: reg.histogram(fam, help_, program=program)
            for key, fam, help_ in PROGRAM_HISTOGRAMS
        }
        return counters, hists

    def _sched_decision(self, decision: str) -> obs_metrics.Counter:
        """Scheduler decision counters (``serving_scheduler_decisions_total``
        labeled by policy + decision): windows formed, windows whose dispatch
        order differs from arrival order, concurrent-program rounds, and
        requests expired at pickup."""
        return self.metrics.counter(
            "serving_scheduler_decisions_total",
            "batching-scheduler decisions",
            scheduler=self.scheduler.name,
            decision=decision,
        )

    def _priority_hist(self, program: str, priority: int) -> obs_metrics.Histogram:
        """Per-priority-class latency (its own family, not extra labels on
        ``serving_request_latency_seconds`` — the existing summary's roll-up
        reads would double-count a second label dimension)."""
        return self.metrics.histogram(
            "serving_priority_latency_seconds",
            "submit-to-done latency seconds per priority class",
            program=program,
            priority=str(priority),
        )

    def _post_error(self, req: ForecastRequest, code: int, reason: str) -> None:
        """The one chokepoint every terminal error flows through: counted in
        ``serving_errors_total{program=,code=}`` (what the SLO engine burns
        budget against), the request id force-sampled so the tail of a
        failing story survives head sampling, then the sealed error post."""
        if req.terminal:
            return
        tracer = self._trace()
        if tracer.enabled:
            tracer.force_sample(req.request_id)
        self.metrics.counter(
            "serving_errors_total",
            "requests terminated by an error event",
            program=req.entry.name,
            code=str(code),
        ).inc()
        req.post({"type": "error", "code": code, "reason": reason, "request_id": req.request_id})

    def _on_slo_breach(self, status: Dict[str, Any]) -> None:
        self._flight_dump(f"slo_breach:{status['objective']}", extra={"breach": status})

    def _flight_dump(self, reason: str, extra: Optional[Dict[str, Any]] = None) -> None:
        if self.flight is not None:
            self.flight.dump(reason, extra=extra)

    def autoscale_signal(self, now: Optional[float] = None) -> Dict[str, Any]:
        """The ``GET /autoscale`` payload: evaluate the SLOs, then apply the
        documented desired-replica rule (queue depth + batch capacity +
        latency-vs-SLO pressure + active breaches, hysteresis-damped)."""
        slo_status = self.slo.evaluate(now=now)
        max_batch = max((e.max_batch for e in self._programs.values()), default=1)
        rec = self.autoscaler.recommend(
            queue_depth=self.queue_depth(),
            inflight=self._inflight,
            max_batch=max_batch,
            latency_ratio=self.slo.latency_pressure(),
            breaching=slo_status["breaching"],
        )
        rec["slo"] = slo_status
        return rec

    # -- health state --------------------------------------------------------

    def queue_depth(self) -> int:
        """Requests waiting for dispatch: the admission queue plus the
        scheduler's backlog (arrivals the worker has pooled but not yet taken
        into a window) — the quantity backpressure, the DEGRADED watermark,
        and the autoscaler all key on."""
        return self._queue.qsize() + self.scheduler.backlog()

    @property
    def state(self) -> str:
        """``SERVING`` → ``DEGRADED`` (queue past the watermark — shed
        optional work) → ``DRAINING`` (reject new, finish in-flight)."""
        if self._draining:
            return DRAINING
        if self.queue_depth() >= max(1, math.ceil(self.degraded_watermark * self.max_queue)):
            return DEGRADED
        return SERVING

    def _retry_after_ms(self) -> float:
        """How long an overload-rejected client should back off: the median
        dispatch wall (watchdog) times the number of batches queued ahead.

        Before any dispatch has been recorded the watchdog median is 0.0 (and
        it must never be NaN-poisoned by an empty sample set), so the window
        length stands in as the only latency scale the engine knows yet."""
        med_s = self.watchdog.stats.median_s
        if not med_s or math.isnan(med_s):
            med_s = max(self.window_s, 1e-3)
        cap = max((e.max_batch for e in self._programs.values()), default=1)
        pending = self.queue_depth() + self._inflight
        batches_ahead = max(1, math.ceil(max(pending, 1) / cap))
        return med_s * batches_ahead * 1e3

    # -- registration ------------------------------------------------------

    def register(
        self,
        prog: ProgramObject,
        *,
        fields: Dict[str, Storage],
        scalars: Dict[str, Any],
        request_fields: Sequence[str],
        stream_fields: Optional[Sequence[str]] = None,
        member_counts: Optional[Sequence[int]] = None,
        max_steps: int = 10_000,
        warm: bool = False,
        warm_chunk: int = 1,
    ) -> ProgramEntry:
        """Compile ``prog`` on the template ``fields``/``scalars`` and hold it
        hot.  Only registered (program, geometry) pairs are ever admitted."""
        entry = ProgramEntry(
            self,
            prog,
            fields=fields,
            scalars=scalars,
            request_fields=request_fields,
            stream_fields=stream_fields,
            member_counts=member_counts,
            max_steps=max_steps,
        )
        self._programs[entry.name] = entry
        if warm:
            entry.warm(warm_chunk)
        return entry

    def catalog(self) -> List[Dict[str, Any]]:
        return [e.describe() for e in self._programs.values()]

    # -- admission + submission --------------------------------------------

    def admit(
        self,
        program: str,
        fields: Dict[str, np.ndarray],
        scalars: Optional[Dict[str, Any]] = None,
        *,
        steps: int = 1,
        stream_every: int = 1,
        fingerprint: Optional[str] = None,
        request_id: Optional[str] = None,
        stats: bool = False,
        deadline_ms: Optional[float] = None,
        priority: Optional[int] = None,
    ) -> ForecastRequest:
        entry = self._programs.get(program)
        if entry is None:
            raise ServingError(
                UNKNOWN_PROGRAM, f"unknown program {program!r}; serving {sorted(self._programs)}"
            )
        if fingerprint is not None and fingerprint != entry.fingerprint:
            raise ServingError(
                FINGERPRINT_MISMATCH,
                f"fingerprint {fingerprint} does not match served artifact {entry.fingerprint} "
                f"for program {program!r} — refresh the catalog",
            )
        try:
            steps, stream_every = int(steps), int(stream_every)
        except (TypeError, ValueError):
            raise ServingError(INVALID_VALUE, "steps and stream_every must be integers") from None
        if not 1 <= steps <= entry.max_steps:
            raise ServingError(INVALID_VALUE, f"steps must be in [1, {entry.max_steps}], got {steps}")
        if stream_every < 1:
            raise ServingError(INVALID_VALUE, f"stream_every must be >= 1, got {stream_every}")
        if deadline_ms is not None:
            try:
                deadline_ms = float(deadline_ms)
            except (TypeError, ValueError):
                raise ServingError(INVALID_VALUE, "deadline_ms must be a number") from None
            if not deadline_ms > 0:
                raise ServingError(INVALID_VALUE, f"deadline_ms must be > 0, got {deadline_ms}")
        if priority is None:
            # the "normal" class: below the most urgent (0) whenever more
            # than one class exists, so explicit urgency means something
            priority = min(1, self.priority_classes - 1)
        else:
            if isinstance(priority, bool) or not isinstance(priority, (int, np.integer)):
                raise ServingError(
                    INVALID_VALUE, f"priority must be an integer, got {priority!r}"
                )
            priority = int(priority)
            if not 0 <= priority < self.priority_classes:
                raise ServingError(
                    INVALID_VALUE,
                    f"priority must be in [0, {self.priority_classes}), got {priority}",
                )
        return ForecastRequest(
            request_id=request_id or f"req-{next(self._request_ids)}",
            entry=entry,
            steps=steps,
            stream_every=stream_every,
            fields=entry.admit_fields(fields),
            scalars=entry.admit_scalars(dict(scalars or {})),
            want_stats=bool(stats),
            deadline_ms=deadline_ms,
            priority=priority,
        )

    def submit(self, *args: Any, **kwargs: Any) -> ForecastRequest:
        """Admit and enqueue (synchronous — admission errors raise here, so a
        rejected request never occupies the batching window).  Backpressure
        rejections (503 + ``retry_after_ms``) also raise here: a full queue
        never buffers work the engine cannot finish in time."""
        if self._draining:
            raise ServingError(
                OVERLOADED,
                "engine is draining — not admitting new requests",
                retry_after_ms=self._retry_after_ms(),
            )
        if self.queue_depth() >= self.max_queue:
            self._c["rejected_overloaded"].inc()
            self._tevent(
                "serving.reject", reason="overloaded", queue_depth=self.queue_depth()
            )
            raise ServingError(
                OVERLOADED,
                f"admission queue full ({self.max_queue} requests)",
                retry_after_ms=self._retry_after_ms(),
            )
        tracer = self._trace()
        t_admit = monotonic()
        try:
            req = self.admit(*args, **kwargs)
        except ServingError as e:
            # rejected admissions still leave a trace: forced, so 4xx
            # stories survive head sampling
            tracer.add_span(
                "serving.admit", t_admit, monotonic(), category="serving",
                force=True, error=f"ServingError: {e.reason}", code=e.code,
            )
            raise
        # the head-sampling decision is made ONCE here and rides the request;
        # the admit span is recorded retroactively so a sampled-out request
        # pays one hash check instead of a span allocation
        req.sampled = tracer.sampling.decide(req.request_id)
        if req.sampled:
            tracer.add_span(
                "serving.admit", t_admit, monotonic(), category="serving",
                trace_ids=(req.request_id,), program=req.entry.name, steps=req.steps,
            )
        req.submitted_at = monotonic()
        if req.deadline_ms is not None:
            req.deadline_at = req.submitted_at + req.deadline_ms / 1e3
        req.seq = next(self._submit_seq)
        req.entry.counters["requests"].inc()
        self._ensure_worker()
        self._queue.put_nowait(req)
        req.post(
            {
                "type": "accepted",
                "request_id": req.request_id,
                "program": req.entry.name,
                "fingerprint": req.entry.fingerprint,
                "steps": req.steps,
                "stream_every": req.stream_every,
            }
        )
        return req

    async def stream(self, req: ForecastRequest) -> AsyncIterator[Dict[str, Any]]:
        """Yield this request's events until its terminal ``done``/``error``."""
        while True:
            ev = await req.events.get()
            yield ev
            if ev["type"] in ("done", "error"):
                return

    async def forecast(self, *args: Any, **kwargs: Any) -> AsyncIterator[Dict[str, Any]]:
        """Submit + stream in one call (the in-process client convenience)."""
        req = self.submit(*args, **kwargs)
        async for ev in self.stream(req):
            yield ev

    # -- the batching worker ------------------------------------------------

    def _ensure_worker(self) -> None:
        if self._worker is None or self._worker.done():
            self._worker = asyncio.get_running_loop().create_task(self._run_worker())
            self._worker.add_done_callback(self._worker_died)

    def _worker_died(self, task: asyncio.Task) -> None:
        """Failsafe for the orphaned-request hang: if the worker task ever
        dies with an exception (it should survive everything), fail every
        queued request instead of leaving them waiting forever; the next
        submission respawns the worker."""
        if task.cancelled() or task.exception() is None:
            return
        self._c["worker_failures"].inc()
        exc = task.exception()
        self._fail_all_queued(f"worker died: {type(exc).__name__}: {exc}")
        if self._worker is task:
            self._worker = None
        # the black box: dump spans/metrics/stats at the moment of death,
        # after the queued requests were failed (so their errors are counted)
        self._flight_dump(
            "worker_death", extra={"error": f"{type(exc).__name__}: {exc}"}
        )

    def _fail_all_queued(self, reason: str) -> None:
        for req in self.scheduler.flush():
            self._post_error(req, INTERNAL, reason)
        while True:
            try:
                req = self._queue.get_nowait()
            except asyncio.QueueEmpty:
                return
            self._post_error(req, INTERNAL, reason)

    def _fail_requests(self, requests: Sequence[ForecastRequest], code: int, reason: str) -> None:
        for r in requests:
            self._post_error(r, code, reason)

    def _pool_admit(self, req: ForecastRequest) -> bool:
        """Move one arrival from the admission queue into the scheduler's
        backlog — unless it is already dead: abandoned/terminal requests are
        dropped, and a request whose deadline expired while queued is 504'd
        right here, before any window slot or dispatch is spent on it."""
        if not self._still_wanted(req):
            return False
        if req.expired():
            self._expire_at_pickup(req)
            return False
        self.scheduler.push(req)
        return True

    def _expire_at_pickup(self, req: ForecastRequest, now: Optional[float] = None) -> None:
        """The 504-at-pickup path: the request died waiting in the queue, so
        it terminates without burning a scatter or dispatch (the satellite
        bugfix — previously an expired request still rode a full first
        segment before ``_mark_expired`` caught it)."""
        now = monotonic() if now is None else now
        req.entry.counters["deadline_expired"].inc()
        self._sched_decision("expired_at_pickup").inc()
        self._tevent(
            "serving.deadline",
            trace_ids=(req.request_id,),
            force=True,
            deadline_ms=req.deadline_ms,
            waited_ms=(now - req.submitted_at) * 1e3,
            at="pickup",
        )
        self._post_error(
            req,
            DEADLINE_EXCEEDED,
            f"deadline of {req.deadline_ms:.0f} ms expired after "
            f"{(now - req.submitted_at) * 1e3:.0f} ms in queue — not dispatched",
        )

    def _sweep_expired(self) -> None:
        """Purge the backlog of requests that died waiting (expired,
        abandoned, or already terminal) before windows form."""
        now = monotonic()
        dead = self.scheduler.sweep(lambda r: r.terminal or r.abandoned or r.expired(now))
        for req in dead:
            if self._still_wanted(req) and req.expired(now):
                self._expire_at_pickup(req, now)

    def _picked_up(self, req: ForecastRequest) -> None:
        """Queue-wait accounting at the moment the worker pops a request:
        the wait becomes a histogram sample and a retroactive span (nothing
        brackets it live, so it is recorded from its two endpoints)."""
        now = monotonic()
        if not req.submitted_at:
            return
        req.queue_wait_s = now - req.submitted_at
        req.entry.hist["queue_wait"].observe(req.queue_wait_s)
        tracer = self._trace()
        # the cached head decision gates the retro span; forced ids (a
        # request already in error territory) are kept regardless
        if tracer.enabled and (req.sampled or tracer.sampling.is_forced(req.request_id)):
            tracer.add_span(
                "serving.queue",
                req.submitted_at,
                now,
                category="serving",
                trace_ids=(req.request_id,),
            )

    async def _run_worker(self) -> None:
        while True:
            sched = self.scheduler
            fresh = False
            if not sched.backlog():
                # idle: block for the first arrival, then open a window
                if not self._pool_admit(await self._queue.get()):
                    continue
                fresh = True
            picked: List[ForecastRequest] = []
            try:
                loop = asyncio.get_running_loop()
                # DEGRADED sheds batching latency: a quarter window drains the
                # queue faster at the cost of occupancy
                window = self.window_s * (0.25 if self.state == DEGRADED else 1.0)
                with self._span(
                    "serving.window", window_s=window, scheduler=sched.name
                ) as wsp:
                    if fresh:
                        deadline = loop.time() + window
                        while sched.backlog() < sched.window_cap():
                            remaining = deadline - loop.time()
                            if remaining <= 0:
                                break
                            try:
                                req = await asyncio.wait_for(self._queue.get(), remaining)
                            except asyncio.TimeoutError:
                                break
                            self._pool_admit(req)
                    # everything already handed off joins the pool regardless
                    # of the cap — the cap only bounds how long we WAIT for
                    # more, never what the ordering policy gets to see (a
                    # leftover backlog therefore dispatches immediately: only
                    # already-arrived requests join, no second window wait)
                    while True:
                        try:
                            self._pool_admit(self._queue.get_nowait())
                        except asyncio.QueueEmpty:
                            break
                    self._sweep_expired()
                    windows = sched.take(monotonic())
                    picked = [r for _, chunk in windows for r in chunk]
                    for r in picked:
                        self._inflight += 1
                        self._picked_up(r)
                        wsp.link(r.request_id)
                    wsp.set("requests", len(picked))
                    wsp.set("windows", len(windows))
                if not picked:
                    continue
                self._h_window.observe(len(picked))
                self._count_decisions(windows)
                # distinct programs' windows dispatch CONCURRENTLY (they hold
                # independent jit artifacts); _run_group contains per-window
                # failures so one program's poison never fails another's batch
                await asyncio.gather(
                    *(self._run_group(entry, chunk) for entry, chunk in windows)
                )
            except asyncio.CancelledError:
                self._fail_requests(picked + sched.flush(), INTERNAL, "engine shutting down")
                raise
            except Exception as e:  # noqa: BLE001 — window/scheduling failures must not strand requests
                self._c["worker_failures"].inc()
                self._fail_requests(
                    picked + sched.flush(),
                    INTERNAL,
                    f"worker failure: {type(e).__name__}: {e}",
                )
            finally:
                self._inflight -= len(picked)

    async def _run_group(self, entry: ProgramEntry, chunk: List[ForecastRequest]) -> None:
        """One program's window: any failure terminates exactly this chunk's
        requests and the worker (plus the other programs' windows) survives."""
        try:
            await self._run_batch(entry, chunk)
        except asyncio.CancelledError:
            raise
        except ServingError as e:
            self._fail_requests(chunk, e.code, e.reason)
        except Exception as e:  # noqa: BLE001 — the worker must survive any batch
            self._fail_requests(chunk, INTERNAL, f"{type(e).__name__}: {e}")

    def _count_decisions(
        self, windows: List[Tuple[ProgramEntry, List[ForecastRequest]]]
    ) -> None:
        self._sched_decision("window").inc(len(windows))
        if len(windows) > 1:
            self._sched_decision("concurrent_programs").inc()
        # "reordered" = the policy actually changed an outcome this round: the
        # pickup order differs from arrival order, or a picked request
        # overtook an older one still waiting in the backlog
        seqs = [r.seq for _, chunk in windows for r in chunk]
        oldest = self.scheduler.oldest_waiting()
        if seqs and (seqs != sorted(seqs) or (oldest is not None and max(seqs) > oldest)):
            self._sched_decision("reordered").inc()

    # -- batch execution: segments, deadlines, retry-with-bisect -------------

    async def _run_batch(self, entry: ProgramEntry, requests: List[ForecastRequest]) -> None:
        batch_id = next(self._batch_seq)
        entry.counters["batches"].inc()
        pairs = [(r, dict(r.fields)) for r in requests]
        # ONE batch span links every co-batched request; the scatter/dispatch/
        # gather spans and any retry/bisect events nest inside it
        with self._span(
            "serving.batch",
            trace_ids=[r.request_id for r in requests],
            batch_id=batch_id,
            program=entry.name,
            requests=len(requests),
        ):
            await self._run_span(entry, pairs, 0, None, initial=True, batch_id=batch_id)

    async def _run_span(
        self,
        entry: ProgramEntry,
        pairs: List[Tuple[ForecastRequest, Dict[str, np.ndarray]]],
        t0: int,
        segments: Optional[List[int]],
        *,
        initial: bool,
        batch_id: int,
    ) -> None:
        """Run one scattered membership from absolute step ``t0`` through
        ``segments``.  The initial span covers the whole batch from step 0;
        bisected spans resume half-batches mid-horizon from gathered states."""
        loop = asyncio.get_running_loop()
        pairs = [p for p in pairs if self._still_wanted(p[0])]
        if not pairs:
            return
        reqs = [r for r, _ in pairs]
        if segments is None:
            segments = _segment_plan(reqs)
        k = len(pairs)
        m = entry.pad_to(k)
        ens = entry.ensembles[m]
        if initial:
            entry.counters["live_members"].inc(k)
            entry.counters["padded_members"].inc(m)
            entry.hist["occupancy"].observe(k / m)
        batch_info = {"id": batch_id, "members": m, "requests": k, "occupancy": k / m}

        try:
            with self._span(
                "serving.scatter",
                trace_ids=[r.request_id for r in reqs],
                members=m,
                resumed=not initial,
            ):
                storages = await self._retrying(
                    "scatter",
                    [r.request_id for r in reqs],
                    lambda: entry._batch_storages([s for _, s in pairs], m, full_state=not initial),
                    counters=entry.counters,
                )
        except Exception as e:  # noqa: BLE001 — scatter failure: bisect like a failed dispatch
            await self._bisect_or_fail(entry, pairs, t0, segments, e, batch_id, None)
            return

        args = [storages[n] for n in entry.prog.field_params]
        scalars = _merge_scalars(entry, reqs, m)

        t = t0
        for si, seg in enumerate(segments):
            live = self._mark_expired(pairs)
            if not live:
                return
            try:
                t1 = monotonic()
                profiled = (
                    jax_profiler_span(f"serving.dispatch[{entry.name}]")
                    if self.jax_profile
                    else nullcontext()
                )
                with self._span(
                    "serving.dispatch",
                    trace_ids=[r.request_id for r, _ in live],
                    batch_id=batch_id,
                    segment=si,
                    steps=seg,
                    members=m,
                    requests=len(live),
                ), profiled:
                    # run_in_executor does not propagate contextvars, so pin
                    # the resolved tracer (and the open dispatch span) into a
                    # context snapshot the executor thread runs under — the
                    # ensemble.dispatch/iterate spans then land in the same
                    # tracer, nested under serving.dispatch, instead of the
                    # usually-disabled process default
                    with otrace.use_tracer(self._trace()):
                        run_ctx = contextvars.copy_context()
                    await self._retrying(
                        "dispatch",
                        [r.request_id for r, _ in live],
                        lambda seg=seg: loop.run_in_executor(
                            None, run_ctx.run, lambda: ens.iterate(seg, *args, **scalars)
                        ),
                        is_async=True,
                        counters=entry.counters,
                    )
                dt = monotonic() - t1
                self.watchdog.record(next(self._dispatch_seq), dt)
                entry.hist["dispatch"].observe(dt)
                entry.counters["dispatches"].inc()
                self._observe_batch_shape(entry, m, seg, dt)
            except Exception as e:  # noqa: BLE001 — dispatch exhausted its retries
                await self._bisect_or_fail(entry, live, t, segments[si:], e, batch_id, storages)
                return
            t += seg
            for i, (r, _) in enumerate(pairs):
                if not self._still_wanted(r):
                    continue
                if t > r.steps or (t % r.stream_every != 0 and t != r.steps):
                    continue
                await self._emit_step(entry, storages, r, i, t, batch_info)
        for r, _ in pairs:
            if not self._still_wanted(r):
                continue
            latency_s = monotonic() - r.submitted_at
            entry.hist["latency"].observe(latency_s)
            self._priority_hist(entry.name, r.priority).observe(latency_s)
            self._tevent(
                "serving.done", trace_ids=(r.request_id,), latency_s=latency_s, steps=r.steps
            )
            done_event = {
                "type": "done",
                "request_id": r.request_id,
                "steps": r.steps,
                "batch": dict(batch_info),
                "latency_s": latency_s,
            }
            if r.queue_wait_s is not None:
                done_event["queue_wait_s"] = r.queue_wait_s
            r.post(done_event)

    def _observe_batch_shape(self, entry: ProgramEntry, m: int, steps: int, dt: float) -> None:
        """Feed the observed (batch size → wall) back into the tune store so
        :func:`tuned_member_counts` — and with it tuned-count padding — learns
        from real traffic.  Gated on improvement: only a new batch size, or a
        ≥2% better per-step wall, rewrites the store (the merge itself is an
        atomic read-merge-write inside :mod:`repro.core.autotune`, so
        concurrent engines don't clobber each other's records)."""
        if steps <= 0 or dt <= 0:
            return
        us_per_step = dt / steps * 1e6
        key = (entry.name, m)
        best = self._batch_best.get(key)
        if best is not None and us_per_step >= best * 0.98:
            return
        self._batch_best[key] = us_per_step if best is None else min(best, us_per_step)
        for obj in getattr(entry.cp, "group_objects", ()):
            try:
                autotune.record_batch_observation(obj.name, obj.fingerprint, m, us_per_step)
            except Exception:  # noqa: BLE001 — tune feedback is never a liveness dependency
                pass

    def _still_wanted(self, r: ForecastRequest) -> bool:
        if r.terminal:
            return False
        if r.abandoned:
            r.entry.counters["abandoned"].inc()
            r.terminal = True  # nobody is listening — seal it so it counts once
            return False
        return True

    def _mark_expired(
        self, pairs: List[Tuple[ForecastRequest, Dict[str, np.ndarray]]]
    ) -> List[Tuple[ForecastRequest, Dict[str, np.ndarray]]]:
        """Deadline enforcement at a segment boundary: expired requests get
        their 504-style error NOW instead of burning another dispatch; the
        still-live members of the batch are returned."""
        now = monotonic()
        live = []
        for r, s in pairs:
            if not self._still_wanted(r):
                continue
            if r.expired(now):
                r.entry.counters["deadline_expired"].inc()
                self._tevent(
                    "serving.deadline",
                    trace_ids=(r.request_id,),
                    force=True,
                    deadline_ms=r.deadline_ms,
                    waited_ms=(now - r.submitted_at) * 1e3,
                )
                self._post_error(
                    r,
                    DEADLINE_EXCEEDED,
                    f"deadline of {r.deadline_ms:.0f} ms expired "
                    f"after {(now - r.submitted_at) * 1e3:.0f} ms",
                )
                continue
            live.append((r, s))
        return live

    async def _retrying(self, site: str, keys: Sequence[str], thunk, *, is_async: bool = False,
                        counters: Optional[Dict[str, obs_metrics.Counter]] = None):
        """Run ``thunk`` under the fault injector's ``site`` check with
        exponential-backoff retries.  The last failure propagates; the caller
        decides between bisect (batches) and a per-request error (gathers).
        ``counters`` is the owning program's labeled set (retries are
        per-program); retry events are force-sampled — a request that hit a
        retry has entered tail-latency territory and its story is kept."""
        attempt = 0
        while True:
            try:
                self.faults.check(site, keys)
                result = thunk()
                return await result if is_async else result
            except asyncio.CancelledError:
                raise
            except Exception as e:  # noqa: BLE001 — injected and real faults retry alike
                attempt += 1
                if attempt >= self.retry_attempts:
                    raise
                if counters is not None:
                    counters["retries"].inc()
                self._tevent(
                    "serving.retry",
                    trace_ids=keys,
                    force=True,
                    site=site,
                    attempt=attempt,
                    error=f"{type(e).__name__}: {e}",
                )
                await asyncio.sleep(self.retry_backoff_s * 2 ** (attempt - 1))

    async def _bisect_or_fail(
        self,
        entry: ProgramEntry,
        pairs: List[Tuple[ForecastRequest, Dict[str, np.ndarray]]],
        t0: int,
        segments: List[int],
        error: Exception,
        batch_id: int,
        storages: Optional[Dict[str, Storage]],
    ) -> None:
        """A span failed past its retries.  Alone → that request errors.
        Together → gather current member states and recurse on each half, so
        a poison request is isolated while its neighbors complete."""
        live = [(i, r, s) for i, (r, s) in enumerate(pairs) if self._still_wanted(r)]
        if not live:
            return
        if len(live) == 1:
            _, r, _ = live[0]
            self._tevent(
                "serving.request_failed",
                trace_ids=(r.request_id,),
                force=True,
                error=f"{type(error).__name__}: {error}",
            )
            self._post_error(
                r,
                INTERNAL,
                f"dispatch failed after {self.retry_attempts} attempts: "
                f"{type(error).__name__}: {error}",
            )
            return
        entry.counters["bisects"].inc()
        self._tevent(
            "serving.bisect",
            trace_ids=[r.request_id for _, r, _ in live],
            force=True,
            requests=len(live),
            resume_step=t0,
            error=f"{type(error).__name__}: {error}",
        )
        if storages is not None:
            # resume from the batch's current (step-t0) states, not the inputs
            resumed = [(r, entry.gather_state(storages, i)) for i, r, _ in live]
        else:
            # scatter itself failed — re-split the states we were handed
            resumed = [(r, s) for _, r, s in live]
        # a half-span is "initial" (request fields only, fresh workspace) iff
        # its states are request-shaped; resumed states carry every batched field
        initial = all(set(s) == set(entry.request_fields) for _, s in resumed)
        half = (len(resumed) + 1) // 2
        for part in (resumed[:half], resumed[half:]):
            if not part:
                continue
            await self._run_span(entry, part, t0, list(segments), initial=initial, batch_id=batch_id)

    async def _emit_step(
        self,
        entry: ProgramEntry,
        storages: Dict[str, Storage],
        r: ForecastRequest,
        i: int,
        t: int,
        batch_info: Dict[str, Any],
    ) -> None:
        """Gather member ``i`` and stream a ``step`` event; a gather that
        fails past its retries errors only this request (the batch and its
        other members keep going)."""
        try:
            with self._span("serving.gather", trace_id=r.request_id, step=t, member=i):
                gathered = await self._retrying(
                    "gather",
                    [r.request_id],
                    lambda: {
                        f: ens_batch.gather_member(storages[f], i) for f in entry.stream_fields
                    },
                    counters=entry.counters,
                )
        except Exception as e:  # noqa: BLE001
            self._post_error(
                r,
                INTERNAL,
                f"gather failed after {self.retry_attempts} attempts: "
                f"{type(e).__name__}: {e}",
            )
            return
        ev: Dict[str, Any] = {
            "type": "step",
            "request_id": r.request_id,
            "step": t,
            "fields": gathered,
            "batch": dict(batch_info),
        }
        # DEGRADED sheds optional work: per-step statistics are dropped first
        if r.want_stats and self.state != DEGRADED:
            ev["stats"] = {f: _field_stats(a) for f, a in gathered.items()}
        r.post(ev)
        entry.counters["steps_streamed"].inc()

    # -- lifecycle / introspection ------------------------------------------

    def stats(self) -> Dict[str, Any]:
        """The operational snapshot — a *view* of the metrics registry (every
        counter here is also a Prometheus series on ``GET /metrics``).  Flat
        keys are engine-wide sums across programs (the pre-label contract the
        clients and benches read); ``per_program`` carries the labeled
        breakdown."""
        reg = self.metrics
        out: Dict[str, Any] = {
            key: int(reg.sum_value(fam)) for key, fam, _ in PROGRAM_COUNTERS
        }
        out["errors"] = int(reg.sum_value("serving_errors_total"))
        for k, c in self._c.items():
            out[k] = int(c.value)
        out["programs"] = sorted(self._programs)
        out["per_program"] = {
            name: {
                **{
                    key: int(reg.sum_value(fam, program=name))
                    for key, fam, _ in PROGRAM_COUNTERS
                },
                "errors": int(reg.sum_value("serving_errors_total", program=name)),
            }
            for name in sorted(self._programs)
        }
        out["state"] = self.state
        out["queue_depth"] = self.queue_depth()
        out["inflight"] = self._inflight
        out["scheduler"] = {
            "policy": self.scheduler.name,
            "backlog": self.scheduler.backlog(),
            "priority_classes": self.priority_classes,
            "decisions": {
                labels["decision"]: int(c.value)
                for labels, c in reg.read(
                    "serving_scheduler_decisions_total", scheduler=self.scheduler.name
                )
            },
            "priority_latency_p99_s": reg.quantiles_by(
                "serving_priority_latency_seconds", 0.99, "priority"
            ),
        }
        padded = out["padded_members"]
        out["mean_occupancy"] = out["live_members"] / padded if padded else None
        out["straggler"] = {
            "dispatches": self.watchdog.stats.steps,
            "stragglers": self.watchdog.stats.stragglers,
            "median_s": self.watchdog.stats.median_s,
        }
        if self.slo.objectives:
            out["slo"] = self.slo.status()
        if self.faults.enabled:
            out["faults"] = self.faults.stats()
        return out

    async def drain(self, timeout_s: Optional[float] = None) -> bool:
        """Graceful shutdown: stop admitting (new submits 503), let the
        worker finish everything queued and in flight, then stop it.  Returns
        True when fully drained, False on timeout (remaining work is failed)."""
        self._draining = True
        deadline = None if timeout_s is None else monotonic() + timeout_s
        while self.queue_depth() or self._inflight:
            if deadline is not None and monotonic() > deadline:
                self._fail_all_queued("engine drain timed out")
                await self.aclose()
                return False
            await asyncio.sleep(0.005)
        await self.aclose()
        return True

    async def aclose(self) -> None:
        if self._worker is not None:
            self._worker.cancel()
            try:
                await self._worker
            except asyncio.CancelledError:
                pass
            self._worker = None
        self._fail_all_queued("engine closed")

    async def __aenter__(self) -> "ServingEngine":
        return self

    async def __aexit__(self, *exc: Any) -> None:
        await self.aclose()


def _merge_scalars(entry: ProgramEntry, requests: List[ForecastRequest], m: int) -> Dict[str, Any]:
    """Per-request scalar overrides become per-member scalar arrays (length
    ``m``, padded like the fields); a scalar every request agrees on stays
    shared so the common case hits the all-shared jit specialization."""
    out: Dict[str, Any] = {}
    for name, default in entry.scalars.items():
        vals = [r.scalars.get(name, default) for r in requests]
        if all(v == vals[0] for v in vals[1:]):
            out[name] = vals[0]
        else:
            out[name] = np.asarray(vals + [vals[-1]] * (m - len(vals)), dtype=np.float64)
    return out
