"""Forecast-as-a-service engine: requests-as-members dynamic batching.

The PR-4 ensemble machinery is a request batcher in disguise: vmapped members
are *independent*, so K concurrent forecast requests can ride the member axis
of ONE batched ``iterate`` dispatch instead of K sequential program calls.
The engine holds compiled artifacts hot and turns a stream of websocket-sized
requests into full batches:

1. **Admission** — requests are admitted against a registered
   :class:`ProgramEntry` keyed by the existing
   ``caching.program_fingerprint``: unknown programs 404, stale fingerprints
   409, wrong field shapes/dtypes 413, bad scalars/steps 422.  A request that
   would trigger a recompile is *rejected at the door*, never silently
   stalled behind a trace+jit.
2. **Batching window** — a worker task takes the first queued request, then
   keeps collecting until ``window_ms`` elapses (or the max member count is
   reached).  Requests for the same program form one batch.
3. **Padding to tuned member counts** — the batch is padded up to the nearest
   registered member count (by default the counts with a persisted autotune
   ``batch`` record, via :func:`tuned_member_counts`, plus small powers of
   two) by repeating the last request's state.  Padded members compute
   garbage nobody gathers; in exchange every dispatch reuses a warm,
   possibly autotuned, jit artifact.
4. **Segmented iterate + streaming** — the union of the batch's stream points
   splits the horizon into segments; each segment is one vmapped
   ``Ensemble.iterate`` dispatch, after which per-request member slices are
   gathered (host copies) and streamed as ``step`` events.  Chunking is
   bit-safe: ``iterate(a); iterate(b)`` ≡ ``iterate(a+b)`` ≡ the sequential
   per-request loop, which the contract tests assert to 0 ULP in float64.

The engine is pure asyncio + numpy/jax — no websocket dependency; transports
(``serving.server``) and in-process drivers (``serving.client``) sit on top.
"""

from __future__ import annotations

import asyncio
import itertools
import json
import time
from dataclasses import dataclass, field as dc_field
from typing import Any, AsyncIterator, Dict, List, Optional, Sequence

import numpy as np

from repro.core import caching
from repro.core.storage import Storage
from repro.ensemble import Ensemble
from repro.ensemble import batch as ens_batch
from repro.program.compile import ProgramObject
from repro.runtime.loop import StragglerWatchdog

from .protocol import (
    FINGERPRINT_MISMATCH,
    INTERNAL,
    INVALID_VALUE,
    SHAPE_MISMATCH,
    UNKNOWN_PROGRAM,
    ServingError,
)

#: padding targets always available, even with no autotune record on disk
DEFAULT_MEMBER_COUNTS = (1, 2, 4, 8, 16)


def tuned_member_counts(cp) -> List[int]:
    """Member counts with a persisted autotune ``batch`` record.

    The Pallas autotuner writes ``<name>_<fp>.tune.json`` next to each
    generated group module (``caching.tuning_path``); records measured on
    member-batched shapes carry the batch extent under ``"batch"``.  Those
    extents are exactly the batch sizes the store holds a measured tile for,
    so the engine prefers padding to them."""
    counts = set()
    for obj in getattr(cp, "group_objects", ()):
        path = caching.tuning_path(obj.name, obj.fingerprint)
        try:
            store = json.loads(path.read_text())
        except (OSError, ValueError):
            continue
        for rec in store.get("domains", {}).values():
            b = rec.get("batch") if isinstance(rec, dict) else None
            if b:
                counts.add(int(b))
    return sorted(counts)


@dataclass
class ForecastRequest:
    """One admitted request: inputs plus the event queue results stream to."""

    request_id: str
    entry: "ProgramEntry"
    steps: int
    stream_every: int
    fields: Dict[str, np.ndarray]
    scalars: Dict[str, Any]
    want_stats: bool = False
    submitted_at: float = 0.0
    events: "asyncio.Queue[Dict[str, Any]]" = dc_field(default_factory=asyncio.Queue)

    def post(self, event: Dict[str, Any]) -> None:
        self.events.put_nowait(event)


class ProgramEntry:
    """One registered program held hot: the compiled single-member artifact,
    per-member-count ensembles, and the admission contract requests are
    checked against."""

    def __init__(
        self,
        engine: "ServingEngine",
        prog: ProgramObject,
        *,
        fields: Dict[str, Storage],
        scalars: Dict[str, Any],
        request_fields: Sequence[str],
        stream_fields: Optional[Sequence[str]] = None,
        member_counts: Optional[Sequence[int]] = None,
        max_steps: int = 10_000,
    ):
        if prog.backend not in ("jax", "pallas"):
            raise ServingError(INTERNAL, f"serving requires a jax-family program, not {prog.backend!r}")
        missing = [n for n in prog.field_params if n not in fields]
        if missing:
            raise ServingError(INTERNAL, f"register({prog.name!r}): missing template fields {missing}")
        missing = [n for n in prog.scalar_params if n not in scalars]
        if missing:
            raise ServingError(INTERNAL, f"register({prog.name!r}): missing default scalars {missing}")
        bad = [n for n in request_fields if n not in prog.field_params]
        if bad:
            raise ServingError(INTERNAL, f"register({prog.name!r}): unknown request fields {bad}")
        self.engine = engine
        self.prog = prog
        self.name = prog.name
        self.fields = {n: fields[n] for n in prog.field_params}
        self.scalars = {n: scalars[n] for n in prog.scalar_params}
        self.request_fields = tuple(request_fields)
        self.stream_fields = tuple(stream_fields or request_fields)

        # compile (or hit the cache for) the single-member artifact NOW —
        # admission is a fingerprint check, never a recompile stall later
        cp = prog.compiled(self.fields, self.scalars)
        if cp.iterable_reason is not None:
            raise ServingError(INTERNAL, f"program {prog.name!r} cannot be served: {cp.iterable_reason}")
        self.cp = cp
        self.fingerprint = cp.fingerprint

        # everything the program writes must be member-batched (members would
        # race on one buffer) — same classification the ensemble layer enforces
        written = set(cp.written_buffers) | set(cp.outputs.values())
        written |= {o for o in cp.outputs if o in self.fields}
        self.batched_fields = tuple(
            sorted(set(self.request_fields) | {b for b in written if b in self.fields})
        )
        self.shared_fields = tuple(n for n in prog.field_params if n not in self.batched_fields)

        counts = list(member_counts) if member_counts else tuned_member_counts(cp) + list(DEFAULT_MEMBER_COUNTS)
        self.member_counts = tuple(sorted({int(c) for c in counts if int(c) >= 1}))
        if not self.member_counts:
            raise ServingError(INTERNAL, f"register({prog.name!r}): empty member_counts")
        self.max_batch = self.member_counts[-1]
        self.max_steps = int(max_steps)
        self.ensembles = {
            m: Ensemble(prog, m, name=f"{self.name}_serve{m}") for m in self.member_counts
        }

    def pad_to(self, k: int) -> int:
        """Smallest registered member count holding ``k`` live requests."""
        for m in self.member_counts:
            if m >= k:
                return m
        return self.max_batch

    def admit_fields(self, fields: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        got, want = set(fields), set(self.request_fields)
        if got != want:
            missing, extra = sorted(want - got), sorted(got - want)
            raise ServingError(
                SHAPE_MISMATCH,
                f"program {self.name!r} takes request fields {sorted(want)}"
                + (f"; missing {missing}" if missing else "")
                + (f"; unexpected {extra}" if extra else ""),
            )
        out = {}
        for n in self.request_fields:
            arr = np.asarray(fields[n])
            tmpl = self.fields[n]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ServingError(
                    SHAPE_MISMATCH,
                    f"field {n!r} has shape {tuple(arr.shape)}, program {self.name!r} is compiled "
                    f"for {tuple(tmpl.shape)} — other geometries are not admitted (no recompile)",
                )
            if str(arr.dtype) != str(tmpl.dtype):
                raise ServingError(
                    SHAPE_MISMATCH, f"field {n!r} has dtype {arr.dtype}, program expects {tmpl.dtype}"
                )
            out[n] = arr
        return out

    def admit_scalars(self, scalars: Dict[str, Any]) -> Dict[str, Any]:
        bad = [n for n in scalars if n not in self.scalars]
        if bad:
            raise ServingError(
                INVALID_VALUE, f"unknown scalars {sorted(bad)}; program takes {sorted(self.scalars)}"
            )
        for n, v in scalars.items():
            if np.ndim(v) != 0:
                raise ServingError(INVALID_VALUE, f"scalar {n!r} must be a number, got shape {np.shape(v)}")
        merged = dict(self.scalars)
        merged.update({n: float(v) for n, v in scalars.items()})
        return merged

    def warm(self, chunk: int = 1) -> None:
        """Pre-trace/jit every member count so the first real batch pays
        dispatch cost only.  ``chunk`` should match the serving segment
        length (``stream_every``) when known — the iterate jit is keyed on
        the step count."""
        sample = {n: np.asarray(self.fields[n].data) for n in self.request_fields}
        for m in self.member_counts:
            storages = self._batch_storages([sample], m)
            self.ensembles[m].iterate(
                int(chunk), *[storages[n] for n in self.prog.field_params], **self.scalars
            )

    def _batch_storages(self, request_fields: List[Dict[str, np.ndarray]], m: int) -> Dict[str, Storage]:
        """Scatter K requests into member slots of fresh batched storages.

        Request fields stack (+ pad) onto the member axis; written workspace
        is broadcast fresh per batch (never reused — a batch must not see a
        previous batch's scratch); shared read-only fields pass through as
        the registered template storages, which the ensemble layer broadcasts
        without materializing copies and never writes back."""
        storages: Dict[str, Storage] = {}
        for n in self.prog.field_params:
            tmpl = self.fields[n]
            if n in self.request_fields:
                storages[n] = ens_batch.scatter_members([rf[n] for rf in request_fields], m, template=tmpl)
            elif n in self.batched_fields:
                storages[n] = ens_batch.broadcast(tmpl, m)
            else:
                storages[n] = tmpl
        return storages

    def describe(self) -> Dict[str, Any]:
        return {
            "program": self.name,
            "backend": self.prog.backend,
            "fingerprint": self.fingerprint,
            "request_fields": {
                n: {"shape": list(self.fields[n].shape), "dtype": str(self.fields[n].dtype)}
                for n in self.request_fields
            },
            "stream_fields": list(self.stream_fields),
            "scalars": {n: float(v) for n, v in self.scalars.items()},
            "member_counts": list(self.member_counts),
            "max_steps": self.max_steps,
        }


def _segment_plan(requests: Sequence[ForecastRequest]) -> List[int]:
    """Split the batch horizon at the union of every request's stream points
    (multiples of its ``stream_every`` plus its final step), so each segment
    is one fused dispatch and every emission lands on a segment boundary."""
    points = sorted(
        {
            t
            for r in requests
            for t in itertools.chain(range(r.stream_every, r.steps + 1, r.stream_every), (r.steps,))
        }
    )
    segments, prev = [], 0
    for t in points:
        segments.append(t - prev)
        prev = t
    return segments


def _field_stats(arr: np.ndarray) -> Dict[str, float]:
    return {"min": float(arr.min()), "max": float(arr.max()), "mean": float(arr.mean())}


class ServingEngine:
    """The asyncio compute server core: admission, batching, streaming."""

    def __init__(self, *, window_ms: float = 2.0, straggler_factor: float = 3.0):
        self.window_s = float(window_ms) / 1e3
        self._programs: Dict[str, ProgramEntry] = {}
        self._queue: "asyncio.Queue[ForecastRequest]" = asyncio.Queue()
        self._worker: Optional[asyncio.Task] = None
        self._request_ids = itertools.count()
        self.watchdog = StragglerWatchdog(factor=straggler_factor)
        self._stats: Dict[str, Any] = {
            "requests": 0,
            "batches": 0,
            "dispatches": 0,
            "steps_streamed": 0,
            "padded_members": 0,
            "live_members": 0,
        }

    # -- registration ------------------------------------------------------

    def register(
        self,
        prog: ProgramObject,
        *,
        fields: Dict[str, Storage],
        scalars: Dict[str, Any],
        request_fields: Sequence[str],
        stream_fields: Optional[Sequence[str]] = None,
        member_counts: Optional[Sequence[int]] = None,
        max_steps: int = 10_000,
        warm: bool = False,
        warm_chunk: int = 1,
    ) -> ProgramEntry:
        """Compile ``prog`` on the template ``fields``/``scalars`` and hold it
        hot.  Only registered (program, geometry) pairs are ever admitted."""
        entry = ProgramEntry(
            self,
            prog,
            fields=fields,
            scalars=scalars,
            request_fields=request_fields,
            stream_fields=stream_fields,
            member_counts=member_counts,
            max_steps=max_steps,
        )
        self._programs[entry.name] = entry
        if warm:
            entry.warm(warm_chunk)
        return entry

    def catalog(self) -> List[Dict[str, Any]]:
        return [e.describe() for e in self._programs.values()]

    # -- admission + submission --------------------------------------------

    def admit(
        self,
        program: str,
        fields: Dict[str, np.ndarray],
        scalars: Optional[Dict[str, Any]] = None,
        *,
        steps: int = 1,
        stream_every: int = 1,
        fingerprint: Optional[str] = None,
        request_id: Optional[str] = None,
        stats: bool = False,
    ) -> ForecastRequest:
        entry = self._programs.get(program)
        if entry is None:
            raise ServingError(
                UNKNOWN_PROGRAM, f"unknown program {program!r}; serving {sorted(self._programs)}"
            )
        if fingerprint is not None and fingerprint != entry.fingerprint:
            raise ServingError(
                FINGERPRINT_MISMATCH,
                f"fingerprint {fingerprint} does not match served artifact {entry.fingerprint} "
                f"for program {program!r} — refresh the catalog",
            )
        try:
            steps, stream_every = int(steps), int(stream_every)
        except (TypeError, ValueError):
            raise ServingError(INVALID_VALUE, "steps and stream_every must be integers") from None
        if not 1 <= steps <= entry.max_steps:
            raise ServingError(INVALID_VALUE, f"steps must be in [1, {entry.max_steps}], got {steps}")
        if stream_every < 1:
            raise ServingError(INVALID_VALUE, f"stream_every must be >= 1, got {stream_every}")
        return ForecastRequest(
            request_id=request_id or f"req-{next(self._request_ids)}",
            entry=entry,
            steps=steps,
            stream_every=stream_every,
            fields=entry.admit_fields(fields),
            scalars=entry.admit_scalars(dict(scalars or {})),
            want_stats=bool(stats),
        )

    def submit(self, *args: Any, **kwargs: Any) -> ForecastRequest:
        """Admit and enqueue (synchronous — admission errors raise here, so a
        rejected request never occupies the batching window)."""
        req = self.admit(*args, **kwargs)
        req.submitted_at = time.perf_counter()
        self._stats["requests"] += 1
        self._ensure_worker()
        self._queue.put_nowait(req)
        req.post(
            {
                "type": "accepted",
                "request_id": req.request_id,
                "program": req.entry.name,
                "fingerprint": req.entry.fingerprint,
                "steps": req.steps,
                "stream_every": req.stream_every,
            }
        )
        return req

    async def stream(self, req: ForecastRequest) -> AsyncIterator[Dict[str, Any]]:
        """Yield this request's events until its terminal ``done``/``error``."""
        while True:
            ev = await req.events.get()
            yield ev
            if ev["type"] in ("done", "error"):
                return

    async def forecast(self, *args: Any, **kwargs: Any) -> AsyncIterator[Dict[str, Any]]:
        """Submit + stream in one call (the in-process client convenience)."""
        req = self.submit(*args, **kwargs)
        async for ev in self.stream(req):
            yield ev

    # -- the batching worker ------------------------------------------------

    def _ensure_worker(self) -> None:
        if self._worker is None or self._worker.done():
            self._worker = asyncio.get_running_loop().create_task(self._run_worker())

    async def _run_worker(self) -> None:
        while True:
            first = await self._queue.get()
            batch = [first]
            loop = asyncio.get_running_loop()
            deadline = loop.time() + self.window_s
            cap = max(e.max_batch for e in self._programs.values())
            while len(batch) < cap:
                remaining = deadline - loop.time()
                if remaining <= 0:
                    break
                try:
                    batch.append(await asyncio.wait_for(self._queue.get(), remaining))
                except asyncio.TimeoutError:
                    break
            groups: Dict[str, List[ForecastRequest]] = {}
            for r in batch:
                groups.setdefault(r.entry.name, []).append(r)
            for reqs in groups.values():
                entry = reqs[0].entry
                for i in range(0, len(reqs), entry.max_batch):
                    chunk = reqs[i : i + entry.max_batch]
                    try:
                        await self._run_batch(entry, chunk)
                    except ServingError as e:
                        for r in chunk:
                            r.post(
                                {
                                    "type": "error",
                                    "code": e.code,
                                    "reason": e.reason,
                                    "request_id": r.request_id,
                                }
                            )
                    except Exception as e:  # noqa: BLE001 — the worker must survive any batch
                        for r in chunk:
                            r.post(
                                {
                                    "type": "error",
                                    "code": INTERNAL,
                                    "reason": f"{type(e).__name__}: {e}",
                                    "request_id": r.request_id,
                                }
                            )

    async def _run_batch(self, entry: ProgramEntry, requests: List[ForecastRequest]) -> None:
        loop = asyncio.get_running_loop()
        k = len(requests)
        m = entry.pad_to(k)
        ens = entry.ensembles[m]
        batch_id = self._stats["batches"]
        self._stats["batches"] += 1
        self._stats["live_members"] += k
        self._stats["padded_members"] += m
        batch_info = {"id": batch_id, "members": m, "requests": k, "occupancy": k / m}

        storages = entry._batch_storages([r.fields for r in requests], m)
        scalars = _merge_scalars(entry, requests, m)
        args = [storages[n] for n in entry.prog.field_params]

        t = 0
        for seg in _segment_plan(requests):
            t0 = time.perf_counter()
            await loop.run_in_executor(None, lambda seg=seg: ens.iterate(seg, *args, **scalars))
            self.watchdog.record(self._stats["dispatches"], time.perf_counter() - t0)
            self._stats["dispatches"] += 1
            t += seg
            for i, r in enumerate(requests):
                if t > r.steps or (t % r.stream_every != 0 and t != r.steps):
                    continue
                gathered = {
                    f: ens_batch.gather_member(storages[f], i) for f in entry.stream_fields
                }
                ev: Dict[str, Any] = {
                    "type": "step",
                    "request_id": r.request_id,
                    "step": t,
                    "fields": gathered,
                    "batch": dict(batch_info),
                }
                if r.want_stats:
                    ev["stats"] = {f: _field_stats(a) for f, a in gathered.items()}
                r.post(ev)
                self._stats["steps_streamed"] += 1
        for r in requests:
            r.post(
                {
                    "type": "done",
                    "request_id": r.request_id,
                    "steps": r.steps,
                    "batch": dict(batch_info),
                    "latency_s": time.perf_counter() - r.submitted_at,
                }
            )

    # -- lifecycle / introspection ------------------------------------------

    def stats(self) -> Dict[str, Any]:
        out = dict(self._stats)
        out["programs"] = sorted(self._programs)
        out["mean_occupancy"] = (
            self._stats["live_members"] / self._stats["padded_members"]
            if self._stats["padded_members"]
            else None
        )
        out["straggler"] = {
            "dispatches": self.watchdog.stats.steps,
            "stragglers": self.watchdog.stats.stragglers,
            "median_s": self.watchdog.stats.median_s,
        }
        return out

    async def aclose(self) -> None:
        if self._worker is not None:
            self._worker.cancel()
            try:
                await self._worker
            except asyncio.CancelledError:
                pass
            self._worker = None

    async def __aenter__(self) -> "ServingEngine":
        return self

    async def __aexit__(self, *exc: Any) -> None:
        await self.aclose()


def _merge_scalars(entry: ProgramEntry, requests: List[ForecastRequest], m: int) -> Dict[str, Any]:
    """Per-request scalar overrides become per-member scalar arrays (length
    ``m``, padded like the fields); a scalar every request agrees on stays
    shared so the common case hits the all-shared jit specialization."""
    out: Dict[str, Any] = {}
    for name, default in entry.scalars.items():
        vals = [r.scalars.get(name, default) for r in requests]
        if all(v == vals[0] for v in vals[1:]):
            out[name] = vals[0]
        else:
            out[name] = np.asarray(vals + [vals[-1]] * (m - len(vals)), dtype=np.float64)
    return out
