"""Deterministic fault injection for the serving path.

The resilience layer (retry-with-bisect, worker restart, request abandonment,
tune-store degradation) is only trustworthy if its failure handling is
*exercised*, and failures must be reproducible to be debuggable.  This module
is the one chaos source every failure-prone site checks:

* ``dispatch``  — before a batched ``Ensemble.iterate`` dispatch
* ``scatter``   — while scattering request fields into member slots
* ``gather``    — while gathering a member's state back out for streaming
* ``ws_send``   — while writing a frame to a websocket
* ``tune_read`` — while reading the persisted autotune store at registration

Faults are **deterministic**: the n-th check at a site fails iff a keyed
blake2b hash of ``(seed, site, n)`` lands under ``rate`` — no RNG state, no
wall clock, so a failing run replays exactly under the same seed, and a
*retry* of a failed dispatch advances the per-site counter and (at rate < 1)
eventually succeeds.  ``poison`` keys are the exception: a check whose
``keys`` include a poisoned id fails *every* attempt — that is what drives
the engine's bisect until the poisoned request is alone and can be failed
individually.

Off by default.  Armed either explicitly (``FaultInjector(sites=...,
rate=...)`` passed to :class:`~repro.serving.engine.ServingEngine`) or from
the environment — the CI chaos matrix sets::

    REPRO_FAULT_SITES=dispatch,gather   # comma-separated sites (required)
    REPRO_FAULT_RATE=0.15               # per-check failure probability
    REPRO_FAULT_SEED=1234               # replay seed (default 0)
    REPRO_FAULT_POISON=req-3,req-9      # always-fail keys (optional)

``InjectedFault`` deliberately subclasses ``RuntimeError``, not
``ServingError``: injected faults must travel the same recovery paths as
real infrastructure failures, never the admission-rejection path.
"""

from __future__ import annotations

import hashlib
import os
from typing import Any, Dict, FrozenSet, Iterable, Mapping, Optional, Sequence

#: every site the engine/transport threads a check through
SITES = ("dispatch", "scatter", "gather", "ws_send", "tune_read")

_ENV_SITES = "REPRO_FAULT_SITES"
_ENV_RATE = "REPRO_FAULT_RATE"
_ENV_SEED = "REPRO_FAULT_SEED"
_ENV_POISON = "REPRO_FAULT_POISON"


class InjectedFault(RuntimeError):
    """An injected infrastructure failure (NOT an admission rejection)."""

    def __init__(self, site: str, detail: str):
        super().__init__(f"injected fault at {site}: {detail}")
        self.site = site
        self.detail = detail


def _unit_hash(seed: int, site: str, n: int) -> float:
    """Deterministic uniform in [0, 1): keyed blake2b, stable across
    processes and platforms (unlike ``hash()``)."""
    digest = hashlib.blake2b(f"{seed}:{site}:{n}".encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big") / 2**64


class FaultInjector:
    """Seeded, site-addressed, counter-deterministic fault source.

    ``check(site, keys=...)`` raises :class:`InjectedFault` when the die says
    so; it is a no-op for sites the injector is not armed at, so threading
    checks through hot paths costs one set lookup when chaos is off.
    """

    def __init__(
        self,
        *,
        sites: Iterable[str] = (),
        rate: float = 0.0,
        seed: int = 0,
        poison: Iterable[str] = (),
    ):
        sites = frozenset(sites)
        unknown = sites - set(SITES)
        if unknown:
            raise ValueError(f"unknown fault sites {sorted(unknown)}; known: {SITES}")
        if not 0.0 <= float(rate) <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {rate}")
        self.sites: FrozenSet[str] = sites
        self.rate = float(rate)
        self.seed = int(seed)
        self.poison: FrozenSet[str] = frozenset(str(k) for k in poison)
        self._counters: Dict[str, int] = {}
        self.injected: Dict[str, int] = {}

    @property
    def enabled(self) -> bool:
        return bool(self.sites) and (self.rate > 0.0 or bool(self.poison))

    def armed(self, site: str) -> bool:
        return site in self.sites and (self.rate > 0.0 or bool(self.poison))

    def check(self, site: str, keys: Sequence[Any] = ()) -> None:
        """Maybe raise an :class:`InjectedFault` at ``site``.

        ``keys`` identify what the operation is acting on (request ids for a
        dispatch, one id for a gather); a poisoned key fails deterministically
        on EVERY attempt, while rate-based faults advance a per-site counter
        so retries see fresh dice."""
        if site not in self.sites:
            return
        if self.poison:
            for k in keys:
                if str(k) in self.poison:
                    self.injected[site] = self.injected.get(site, 0) + 1
                    raise InjectedFault(site, f"poisoned key {k!r}")
        if self.rate <= 0.0:
            return
        n = self._counters.get(site, 0)
        self._counters[site] = n + 1
        if _unit_hash(self.seed, site, n) < self.rate:
            self.injected[site] = self.injected.get(site, 0) + 1
            raise InjectedFault(site, f"check #{n} (seed {self.seed}, rate {self.rate})")

    def stats(self) -> Dict[str, Any]:
        return {
            "sites": sorted(self.sites),
            "rate": self.rate,
            "seed": self.seed,
            "poison": sorted(self.poison),
            "checks": dict(self._counters),
            "injected": dict(self.injected),
        }

    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None) -> "FaultInjector":
        """The env-armed injector (disabled when ``REPRO_FAULT_SITES`` is
        unset/empty) — what a :class:`ServingEngine` builds by default, so a
        CI chaos leg arms every engine in the process without code changes."""
        env = os.environ if env is None else env
        sites = tuple(s.strip() for s in env.get(_ENV_SITES, "").split(",") if s.strip())
        if not sites:
            return cls()
        rate = float(env.get(_ENV_RATE, "0.1"))
        seed = int(env.get(_ENV_SEED, "0"))
        poison = tuple(p.strip() for p in env.get(_ENV_POISON, "").split(",") if p.strip())
        return cls(sites=sites, rate=rate, seed=seed, poison=poison)
