"""Forecast-as-a-service: compiled programs held hot, requests batched as
ensemble members, per-step state streamed back.

BEYOND PAPER.  The paper argues that embedding the stencil DSL in Python
buys "integration in complex workflows"; this package cashes that in by
*serving* compiled programs the way operational centers run them — a
persistent compute server instead of a batch script::

    from repro.serving import ServingEngine
    from repro.stencils.forecast import build_forecast_step, make_forecast_fields

    engine = ServingEngine(window_ms=2.0)
    fields, scalars = make_forecast_fields("jax", (48, 48, 16))
    step = build_forecast_step("jax", (48, 48, 16))
    engine.register(step, fields=fields, scalars=scalars, request_fields=("phi",))
    # async context: engine.forecast("forecast_step", {"phi": state}, steps=10)

Modules: ``engine`` (admission + dynamic batching onto the ensemble member
axis, plus the resilience policies: backpressure, deadlines, retry-with-
bisect, health states), ``faults`` (deterministic fault injection for chaos
tests), ``protocol`` (JSON/base64 wire format, bit-exact float64), ``server``
(aiohttp websocket transport, optional dependency), ``client`` (in-process
and websocket drivers + the deterministic load generator).

The contract: serving K concurrent requests through one vmapped batch is
bit-identical (float64) to K sequential per-request program runs
(tests/test_serving.py locks it against the PR-4 member-loop oracle) — and
that identity survives dispatch failures, because retry-with-bisect resumes
half-batches from exactly-gathered member states (tests/test_serving_faults.py).
"""

from . import client, faults, protocol
from .client import LoadReport, RequestResult, RequestSpec, drive_engine, drive_server, percentile
from .engine import (
    DEFAULT_MEMBER_COUNTS,
    DEGRADED,
    DRAINING,
    SERVING,
    ForecastRequest,
    ProgramEntry,
    ServingEngine,
    tuned_member_counts,
)
from .faults import FaultInjector, InjectedFault
from .scheduler import BatchingScheduler, EdfScheduler, FifoScheduler, make_scheduler
from .protocol import (
    DEADLINE_EXCEEDED,
    OVERLOADED,
    ServingError,
    decode_array,
    encode_array,
)

__all__ = [
    "BatchingScheduler",
    "DEADLINE_EXCEEDED",
    "DEFAULT_MEMBER_COUNTS",
    "DEGRADED",
    "DRAINING",
    "EdfScheduler",
    "FaultInjector",
    "FifoScheduler",
    "ForecastRequest",
    "InjectedFault",
    "LoadReport",
    "OVERLOADED",
    "ProgramEntry",
    "RequestResult",
    "RequestSpec",
    "SERVING",
    "ServingEngine",
    "ServingError",
    "client",
    "decode_array",
    "drive_engine",
    "drive_server",
    "encode_array",
    "faults",
    "make_scheduler",
    "percentile",
    "protocol",
    "tuned_member_counts",
]
