"""Stencil application library, written in the GTScript DSL.

Contains the paper's two evaluation motifs (horizontal diffusion with flux
limiter, implicit vertical advection) plus a library of reusable operators,
mirroring how the paper's isentropic model (Tasmania) composes stencils.
"""

from . import forecast, hdiff, library, vadv
from .forecast import build_forecast_step, make_forecast_fields
from .hdiff import build_hdiff, hdiff_defs
from .library import (
    avg_x,
    avg_y,
    fwd_avg_z,
    gradx,
    grady,
    laplacian,
)
from .vadv import build_vadv, vadv_defs

__all__ = [
    "library",
    "forecast",
    "hdiff",
    "vadv",
    "build_forecast_step",
    "make_forecast_fields",
    "laplacian",
    "gradx",
    "grady",
    "avg_x",
    "avg_y",
    "fwd_avg_z",
    "build_hdiff",
    "build_vadv",
    "hdiff_defs",
    "vadv_defs",
]
