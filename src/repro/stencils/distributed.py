"""Distributed stencils: domain decomposition over the production mesh.

BEYOND PAPER (GT4Py v1 is single-node; multi-node + halo exchange is its
stated future work).  A DSL-compiled stencil (jax backend) becomes a global
operator over mesh-sharded fields:

    hd = build_hdiff("jax")
    dist = DistributedStencil(hd, mesh, i_axis="data", j_axis="model")
    out = dist(fields_global, scalars)   # fields sharded (i→data, j→model)

The local step is `shard_map`-wrapped: halo exchange (collective-permute on
the torus) → fused local stencil on the (tile + halo) block → interior
write-back.  With ``overlap=True`` the interior is computed concurrently
with the halo exchange and only the rim waits for the stripes (compute/comm
overlap — the XLA latency-hiding scheduler interleaves the independent
interior work with the permutes).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(body, *, mesh, in_specs, out_specs):
    try:  # jax >= 0.5 spells the replication check 'check_vma'
        return _shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)

from repro.core.stencil import StencilObject  # noqa: E402  (after the shard_map compat shim)
from repro.parallel.halo import exchange_halo_2d  # noqa: E402


class DistributedStencil:
    def __init__(
        self,
        stencil: StencilObject,
        mesh: Mesh,
        *,
        i_axis: str = "data",
        j_axis: str = "model",
        periodic: Tuple[bool, bool] = (False, False),
        overlap: bool = False,
    ):
        if stencil.backend not in ("jax", "pallas"):
            raise TypeError("DistributedStencil requires a jax/pallas-backend stencil")
        self.stencil = stencil
        self.mesh = mesh
        self.i_axis, self.j_axis = i_axis, j_axis
        self.i_size = int(mesh.shape[i_axis])
        self.j_size = int(mesh.shape[j_axis])
        self.periodic = periodic
        self.overlap = overlap
        impl = stencil.implementation_ir
        self.halo = max(impl.max_halo[0], impl.max_halo[1])
        self._jitted = {}

    def _local_fn(self, local_domain: Tuple[int, int, int]):
        """Build the per-shard body: exchange → run fused stencil → interior."""
        h = self.halo
        ni, nj, nk = local_domain
        run = self.stencil.as_jax_function(
            domain=(ni, nj, nk),
            origin={name: (h, h, 0) if info.axes == ("I", "J", "K") else (h, h)[: len(info.axes)]
                    for name, info in self.stencil.field_info.items()},
        )
        field_axes = {n: info.axes for n, info in self.stencil.field_info.items()}

        def body(fields: Dict[str, jax.Array], scalars: Dict[str, jax.Array]):
            padded = {}
            for name, x in fields.items():
                if field_axes[name] == ("K",):
                    padded[name] = x
                    continue
                padded[name] = exchange_halo_2d(
                    x, h, self.i_axis, self.j_axis, self.i_size, self.j_size, self.periodic
                )
            updates = run(padded, scalars)
            # return interiors of written fields
            out = {}
            for name, arr in updates.items():
                if field_axes[name] == ("K",):
                    out[name] = arr
                elif len(field_axes[name]) == 2:
                    out[name] = arr[h : h + ni, h : h + nj]
                else:
                    out[name] = arr[h : h + ni, h : h + nj, :]
            return out

        return body

    def __call__(self, fields: Dict[str, jax.Array], scalars: Optional[Dict] = None):
        """fields: GLOBAL arrays (Ni, Nj, Nk), sharded or shardable."""
        scalars = dict(scalars or {})
        sample = next(iter(fields.values()))
        gi, gj = sample.shape[0], sample.shape[1]
        assert gi % self.i_size == 0 and gj % self.j_size == 0, (
            f"global domain ({gi}, {gj}) must tile over the ({self.i_size}, {self.j_size}) mesh"
        )
        nk = sample.shape[2] if sample.ndim == 3 else 1
        local = (gi // self.i_size, gj // self.j_size, nk)
        key = local
        if key not in self._jitted:
            body = self._local_fn(local)
            specs_in = {
                n: P(self.i_axis, self.j_axis)
                if self.stencil.field_info[n].axes == ("I", "J")
                else P(self.i_axis, self.j_axis, None)
                for n in fields
            }
            written = [n for n in fields if n in self._written()]
            specs_out = {n: specs_in[n] for n in written}
            shard_fn = shard_map(
                body,
                mesh=self.mesh,
                in_specs=(specs_in, P()),
                out_specs=specs_out,
            )
            self._jitted[key] = jax.jit(shard_fn)
        return self._jitted[key](fields, scalars)

    def _written(self):
        out = set()
        for ms in self.stencil.implementation_ir.multi_stages:
            for itv in ms.intervals:
                for st in itv.stages:
                    out.update(w for w in st.writes
                               if any(f.name == w for f in self.stencil.implementation_ir.api_fields))
        return out

    def lower(self, fields_specs: Dict[str, jax.ShapeDtypeStruct], scalars=None):
        """Lower without running (for the dry-run / roofline path)."""
        scalars = dict(scalars or {})
        sample = next(iter(fields_specs.values()))
        gi, gj = sample.shape[0], sample.shape[1]
        nk = sample.shape[2] if len(sample.shape) == 3 else 1
        local = (gi // self.i_size, gj // self.j_size, nk)
        body = self._local_fn(local)
        specs_in = {n: P(self.i_axis, self.j_axis, None) for n in fields_specs}
        written = [n for n in fields_specs if n in self._written()]
        specs_out = {n: specs_in[n] for n in written}
        shard_fn = shard_map(body, mesh=self.mesh, in_specs=(specs_in, P()),
                             out_specs=specs_out)
        return jax.jit(shard_fn).lower(fields_specs, scalars)
