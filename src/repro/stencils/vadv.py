"""Implicit vertical advection (Thomas solver) — the paper's Fig. 3 (right).

A sequential-vertical motif: a FORWARD elimination sweep followed by a
BACKWARD substitution sweep, with per-interval specialization at the domain
boundaries — exactly the pattern the paper uses to motivate
``computation(FORWARD/BACKWARD)`` + ``interval``.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.gtscript import Field, BACKWARD, FORWARD, PARALLEL, computation, interval
from repro.core.stencil import build_retyped


def vadv_defs(
    a: Field[np.float64],
    b: Field[np.float64],
    c: Field[np.float64],
    d: Field[np.float64],
    out: Field[np.float64],
):
    """Solve the tridiagonal system (a, b, c)·out = d along each column."""
    with computation(FORWARD):
        with interval(0, 1):
            cp = c / b
            dp = d / b
        with interval(1, None):
            denom = b - a * cp[0, 0, -1]
            cp = c / denom
            dp = (d - a * dp[0, 0, -1]) / denom
    with computation(BACKWARD):
        with interval(-1, None):
            out = dp
        with interval(0, -1):
            out = dp - cp * out[0, 0, 1]


def vadv_system_defs(
    w: Field[np.float64],
    phi: Field[np.float64],
    a: Field[np.float64],
    b: Field[np.float64],
    c: Field[np.float64],
    d: Field[np.float64],
    *,
    dt: np.float64,
    dz: np.float64,
):
    """Assemble the implicit vertical-advection system for velocity ``w``
    acting on ``phi`` (Crank–Nicolson), producing tridiagonal coefficients.
    """
    with computation(PARALLEL), interval(1, -1):
        gcv = 0.25 * (w[0, 0, 1] + w[0, 0, 0]) * dt / dz
        gcv_m = 0.25 * (w[0, 0, 0] + w[0, 0, -1]) * dt / dz
        a = -gcv_m
        c = gcv
        b = 1.0 + gcv - gcv_m
        d = phi[0, 0, 0] - gcv * (phi[0, 0, 1] - phi[0, 0, 0]) + gcv_m * (phi[0, 0, 0] - phi[0, 0, -1])
    with computation(PARALLEL), interval(0, 1):
        gcv = 0.25 * (w[0, 0, 1] + w[0, 0, 0]) * dt / dz
        a = 0.0
        c = gcv
        b = 1.0 + gcv
        d = phi[0, 0, 0] - gcv * (phi[0, 0, 1] - phi[0, 0, 0])
    with computation(PARALLEL), interval(-1, None):
        gcv_m = 0.25 * (w[0, 0, 0] + w[0, 0, -1]) * dt / dz
        a = -gcv_m
        c = 0.0
        b = 1.0 - gcv_m
        d = phi[0, 0, 0] + gcv_m * (phi[0, 0, 0] - phi[0, 0, -1])


def vadv_boundary_defs(
    wcon: Field[np.float64],
    phi: Field[np.float64],
    flux_bot: Field[np.float64],
    flux_top: Field[np.float64],
    acc: Field[np.float64],
    res: Field[np.float64],
    *,
    weight: np.float64,
):
    """Boundary-specialized vertical sweep pair — the interval-splitting
    motif: both sweeps seed/close at a domain boundary with carry-free
    bodies (and boundary-only flux outputs), so ``interval_splitting`` peels
    them into vectorized PARALLEL blocks and the interior ``fori_loop``
    stops carrying the boundary fluxes.  The PARALLEL assembly deliberately
    spells the same product two ways (``phi * wcon`` / ``wcon * phi``) —
    the reassociation → CSE motif.
    """
    with computation(PARALLEL), interval(...):
        p = phi * wcon + phi
        q = wcon * phi + phi[1, 0, 0]
        src = 0.5 * (p + q)
    with computation(FORWARD):
        with interval(0, 1):
            flux_bot = 0.25 * (wcon[0, 0, 1] + wcon[0, 0, 0]) * src
            acc = src + flux_bot
        with interval(1, None):
            acc = src + weight * acc[0, 0, -1]
    with computation(BACKWARD):
        with interval(-1, None):
            flux_top = 0.25 * (wcon[0, 0, 0] + wcon[0, 0, -1]) * acc
            res = acc + flux_top
        with interval(0, -1):
            res = acc + weight * res[0, 0, 1]


@functools.lru_cache(maxsize=None)
def build_vadv(backend: str = "numpy", dtype: str = "float64", **opts):
    return build_retyped(vadv_defs, backend, dtype, **opts)


@functools.lru_cache(maxsize=None)
def build_vadv_boundary(backend: str = "numpy", dtype: str = "float64", **opts):
    return build_retyped(vadv_boundary_defs, backend, dtype, **opts)


@functools.lru_cache(maxsize=None)
def build_vadv_system(backend: str = "numpy", dtype: str = "float64", **opts):
    return build_retyped(vadv_system_defs, backend, dtype, **opts)
