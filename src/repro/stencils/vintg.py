"""Exponentially-weighted vertical column integrals (optical-depth motif).

A pair of first-order vertical recurrences — downward (FORWARD) and upward
(BACKWARD) — of the kind radiation / microphysics columns run everywhere:
``acc(k) = decay * acc(k-1) + rho(k) * w(k)``.

The accumulator temporaries live entirely inside their sweep and are only
read one plane behind it, so ``analysis.sequential_carry_plan`` classifies
them as depth-1 *window* fields: the jax/pallas backends carry a single
rolling 2-D plane through the ``fori_loop`` instead of materializing the
full (ni, nj, nk) array — the k-blocking that frees VMEM for larger tiles.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.gtscript import BACKWARD, FORWARD, Field, computation, interval
from repro.core.stencil import build_retyped

DEFAULT_DECAY = 0.9


def vintg_defs(
    rho: Field[np.float64],
    w: Field[np.float64],
    out_dn: Field[np.float64],
    out_up: Field[np.float64],
    *,
    decay: np.float64,
):
    """Downward and upward decaying column integrals of ``rho * w``."""
    with computation(FORWARD):
        with interval(0, 1):
            acc_dn = rho * w
            out_dn = acc_dn
        with interval(1, None):
            acc_dn = decay * acc_dn[0, 0, -1] + rho * w
            out_dn = acc_dn
    with computation(BACKWARD):
        with interval(-1, None):
            acc_up = rho * w
            out_up = acc_up
        with interval(0, -1):
            acc_up = decay * acc_up[0, 0, 1] + rho * w
            out_up = acc_up


@functools.lru_cache(maxsize=None)
def build_vintg(backend: str = "numpy", dtype: str = "float64", **opts):
    return build_retyped(vintg_defs, backend, dtype, **opts)
