"""A compact forecast step for the serving path: upwind advection + Euler
update + diffusive smoothing, wired into one rotation-closed ``@program``.

This is the demo payload the forecast server (``repro.serving``) registers
and the load generator drives — three jax-family stencils whose output
binding rotates ``phi``/``phi_new``, so ``iterate(n)`` fuses n steps into one
``lax.fori_loop`` dispatch and an :class:`~repro.ensemble.Ensemble` batches
concurrent requests over the member axis.  The same step (different sizes)
backs the serving contract tests and the ``serving_throughput`` bench case.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import gtscript, storage
from repro.core.gtscript import PARALLEL, Field, computation, interval
from repro.core.storage import Storage
from repro.program import program

from .library import laplacian

HALO = 1
FIELD_NAMES = ("phi", "u", "v", "adv", "phi_star", "phi_new")
REQUEST_FIELDS = ("phi",)
DEFAULT_SCALARS: Dict[str, float] = {"dx": 1.0, "dy": 1.0, "dt": 0.1, "alpha": 0.05}


def advect_defs(
    phi: Field[np.float64],
    u: Field[np.float64],
    v: Field[np.float64],
    adv: Field[np.float64],
    *,
    dx: np.float64,
    dy: np.float64,
):
    with computation(PARALLEL), interval(...):
        fx = (phi[0, 0, 0] - phi[-1, 0, 0]) / dx if u > 0.0 else (phi[1, 0, 0] - phi[0, 0, 0]) / dx
        fy = (phi[0, 0, 0] - phi[0, -1, 0]) / dy if v > 0.0 else (phi[0, 1, 0] - phi[0, 0, 0]) / dy
        adv = -(u * fx + v * fy)


def euler_defs(phi: Field[np.float64], adv: Field[np.float64], out: Field[np.float64], *, dt: np.float64):
    with computation(PARALLEL), interval(...):
        out = phi + dt * adv


def diffuse_defs(phi: Field[np.float64], out: Field[np.float64], *, alpha: np.float64):
    with computation(PARALLEL), interval(...):
        out = phi + alpha * laplacian(phi)


def build_forecast_step(backend: str, domain: Tuple[int, int, int], *, name: str = "forecast_step", **opts):
    """The three-stencil step as a rotation-closed ``@program`` object."""
    build = gtscript.stencil(backend=backend, **opts)
    advect, euler, diffuse = build(advect_defs), build(euler_defs), build(diffuse_defs)
    dom = tuple(int(d) for d in domain)

    @program(backend=backend, name=name)
    def forecast_step(phi, u, v, adv, phi_star, phi_new, *, dx, dy, dt, alpha):
        advect(phi, u, v, adv, dx=dx, dy=dy, domain=dom)
        euler(phi, adv, phi_star, dt=dt, domain=dom)
        diffuse(phi_star, phi_new, alpha=alpha, domain=dom)
        return {"phi": phi_new, "phi_new": phi}

    return forecast_step


def make_forecast_fields(
    backend: str, domain: Tuple[int, int, int], *, seed: int = 0
) -> Tuple[Dict[str, Storage], Dict[str, float]]:
    """Template fields (gaussian tracer blob + steady winds + workspace) and
    default scalars, shaped ``domain + 2·HALO`` horizontally."""
    ni, nj, nk = (int(d) for d in domain)
    shape = (ni + 2 * HALO, nj + 2 * HALO, nk)
    x = np.linspace(-1.0, 1.0, shape[0])[:, None, None]
    y = np.linspace(-1.0, 1.0, shape[1])[None, :, None]
    z = np.linspace(0.0, 1.0, shape[2])[None, None, :]
    rng = np.random.default_rng(seed)
    blob = np.exp(-8.0 * (x**2 + y**2)) * (1.0 + 0.1 * z)
    phi = blob + 1e-3 * rng.normal(size=shape)
    mk = lambda a: storage.from_array(np.ascontiguousarray(a), backend=backend, default_origin=(HALO, HALO, 0))  # noqa: E731
    fields = {
        "phi": mk(phi),
        "u": mk(np.full(shape, 0.8)),
        "v": mk(np.full(shape, -0.4)),
        "adv": mk(np.zeros(shape)),
        "phi_star": mk(np.zeros(shape)),
        "phi_new": mk(np.zeros(shape)),
    }
    return fields, dict(DEFAULT_SCALARS)


def request_state(domain: Tuple[int, int, int], *, seed: int) -> np.ndarray:
    """A per-request initial ``phi`` (perturbed blob) shaped like the template
    — what a serving client ships in its ``forecast`` message."""
    fields, _ = make_forecast_fields("numpy", domain, seed=seed)
    return np.asarray(fields["phi"].data).copy()
