"""Reusable GTScript functions (inlined at compile time, paper Fig. 1 line 3)."""

from __future__ import annotations

from repro.core import gtscript


@gtscript.function
def laplacian(phi):
    """5-point horizontal Laplacian."""
    return -4.0 * phi[0, 0, 0] + (phi[-1, 0, 0] + phi[1, 0, 0] + phi[0, -1, 0] + phi[0, 1, 0])


@gtscript.function
def gradx(phi):
    """Forward difference along I."""
    return phi[1, 0, 0] - phi[0, 0, 0]


@gtscript.function
def grady(phi):
    """Forward difference along J."""
    return phi[0, 1, 0] - phi[0, 0, 0]


@gtscript.function
def gradx_c(phi):
    """Centered difference along I."""
    return 0.5 * (phi[1, 0, 0] - phi[-1, 0, 0])


@gtscript.function
def grady_c(phi):
    """Centered difference along J."""
    return 0.5 * (phi[0, 1, 0] - phi[0, -1, 0])


@gtscript.function
def avg_x(phi):
    return 0.5 * (phi[1, 0, 0] + phi[0, 0, 0])


@gtscript.function
def avg_y(phi):
    return 0.5 * (phi[0, 1, 0] + phi[0, 0, 0])


@gtscript.function
def fwd_avg_z(phi):
    return 0.5 * (phi[0, 0, 1] + phi[0, 0, 0])


@gtscript.function
def upwind_flux_x(phi, vel):
    """First-order upwind flux along I."""
    return vel * (phi[0, 0, 0] if vel > 0.0 else phi[1, 0, 0])


@gtscript.function
def upwind_flux_y(phi, vel):
    return vel * (phi[0, 0, 0] if vel > 0.0 else phi[0, 1, 0])


@gtscript.function
def smagorinsky_factor(u, v):
    """Deformation-based Smagorinsky diffusion factor (squared strain)."""
    du_dx = 0.5 * (u[1, 0, 0] - u[-1, 0, 0])
    dv_dy = 0.5 * (v[0, 1, 0] - v[0, -1, 0])
    du_dy = 0.5 * (u[0, 1, 0] - u[0, -1, 0])
    dv_dx = 0.5 * (v[1, 0, 0] - v[-1, 0, 0])
    shear = du_dy + dv_dx
    stretch = du_dx - dv_dy
    return sqrt(stretch * stretch + shear * shear)  # noqa: F821  (gtscript native)
