"""Horizontal diffusion with flux limiter — the paper's Fig. 1 / Fig. 3 (left).

A multi-stage PARALLEL stencil: laplacian-of-laplacian, limited fluxes, and
the field update — the classic COSMO hdiff motif.  All eight intermediate
stages are temporaries; on the pallas backend the whole pipeline fuses into
one VMEM-resident kernel (halo 3).
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.gtscript import Field, PARALLEL, computation, interval
from repro.core.stencil import build_retyped

from .library import gradx, grady, laplacian, smagorinsky_factor

DEFAULT_LIM = 0.01


def hdiff_defs(in_phi: Field[np.float64], out_phi: Field[np.float64], *, alpha: np.float64):
    from __externals__ import LIM

    with computation(PARALLEL), interval(...):
        # laplacian-of-laplacian
        lap = laplacian(in_phi)
        bilap = laplacian(lap)
        # x- and y-fluxes of the biharmonic term
        flux_x = gradx(bilap)
        flux_y = grady(bilap)
        # gradient of the input field
        grad_x = gradx(in_phi)
        grad_y = grady(in_phi)
        # simple flux limiter
        fx = flux_x if flux_x * grad_x > LIM else LIM
        fy = flux_y if flux_y * grad_y > LIM else LIM
        # update
        out_phi = in_phi + alpha * (gradx(fx[-1, 0, 0]) + grady(fy[0, -1, 0]))


HALO = 3  # compile-time known read extent of in_phi


def hdiff_smag_defs(
    u: Field[np.float64],
    v: Field[np.float64],
    out_u: Field[np.float64],
    out_v: Field[np.float64],
    *,
    dt: np.float64,
):
    """Horizontal diffusion with a Smagorinsky coefficient (COSMO motif).

    The deformation factor inlines with its ``stretch`` / ``shear`` chains
    each appearing twice (``stretch * stretch + shear * shear``) — the
    repeated-subexpression shape the ``cross_stage_cse`` pass eliminates.
    """
    from __externals__ import CS

    with computation(PARALLEL), interval(...):
        smag = CS * smagorinsky_factor(u, v)
        lap_u = laplacian(u)
        lap_v = laplacian(v)
        out_u = u + dt * smag * lap_u
        out_v = v + dt * smag * lap_v


DEFAULT_CS = 0.15


@functools.lru_cache(maxsize=None)
def build_hdiff(backend: str = "numpy", lim: float = DEFAULT_LIM, dtype: str = "float64", **opts):
    return build_retyped(hdiff_defs, backend, dtype, externals={"LIM": lim}, **opts)


@functools.lru_cache(maxsize=None)
def build_hdiff_smag(backend: str = "numpy", cs: float = DEFAULT_CS, dtype: str = "float64", **opts):
    return build_retyped(hdiff_smag_defs, backend, dtype, externals={"CS": cs}, **opts)
