"""Gradient-norm utilities."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32))) for leaf in leaves))


def clip_by_global_norm(tree, max_norm: float):
    """Returns (clipped tree, pre-clip norm)."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda leaf: (leaf.astype(jnp.float32) * scale).astype(leaf.dtype), tree), norm
