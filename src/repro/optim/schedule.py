"""LR schedules as pure functions of the (traced) step."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, base_lr: float, total_steps: int, final_frac: float = 0.1):
    t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return base_lr * (final_frac + (1.0 - final_frac) * cos)


def linear_warmup_cosine(step, base_lr: float, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    warm = base_lr * (step.astype(jnp.float32) + 1.0) / max(warmup_steps, 1)
    cos = cosine_schedule(step - warmup_steps, base_lr, max(total_steps - warmup_steps, 1),
                          final_frac)
    return jnp.where(step < warmup_steps, warm, cos)
