"""AdamW with decoupled weight decay, fp32 master state, bf16-safe updates.

State layout mirrors the parameter pytree (m, v per leaf) so the same
logical-axis shardings apply — optimizer states shard exactly like their
parameters (ZeRO-1 falls out of the 'data'-axis rules if configured).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # pytree like params (f32)
    v: Any  # pytree like params (f32)


def adamw_init(params: Any) -> OptState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(
    params: Any,
    grads: Any,
    state: OptState,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_scale: Optional[jax.Array] = None,
):
    """Returns (new_params, new_state). ``lr`` may be a traced scalar."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        if grad_scale is not None:
            g32 = g32 * grad_scale
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * jnp.square(g32)
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + eps)
        # decoupled weight decay on matrices only (ndim >= 2), standard practice
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v)
