"""Optimizer substrate (built from scratch — no optax in this environment)."""

from .adamw import OptState, adamw_init, adamw_update
from .schedule import cosine_schedule, linear_warmup_cosine
from .clip import global_norm, clip_by_global_norm

__all__ = [
    "OptState",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "linear_warmup_cosine",
    "global_norm",
    "clip_by_global_norm",
]
