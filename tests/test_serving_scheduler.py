"""Deadline-aware batching scheduler tests (repro.serving.scheduler + the
engine's backlog/window rewrite, PR 10).

What is locked here:

* **determinism** — the same backlog yields the same windows, twice, for
  every policy (each sort key ends in the admission sequence);
* **EDF beats FIFO where it must** — under a blend of tight- and
  loose-deadline requests at equal load, EDF dispatches the tight ones first
  and strictly reduces the deadline-expired count (here: 3 → 0);
* **the pickup bugfix** — a request that is already dead at window pickup is
  504'd WITHOUT burning a dispatch (zero batches, zero dispatches);
* **the window-cap bugfix** — collection is capped by the programs actually
  present in the backlog, never the largest *registered* program (and an
  empty backlog caps at 0 instead of crashing);
* **the feedback loop** — served batch shapes land in the autotune store as
  ``serving|batch=N`` records and registration reads them back;
* priority admission validation (422s) and the SLO batch-window wiring
  (latency breaches recover within batching-window timescales where the
  5-minute SRE defaults would still page).
"""

import asyncio
import json
import math
from types import SimpleNamespace

import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import autotune, caching
from repro.obs import metrics as obs_metrics
from repro.obs import slo as obs_slo
from repro.serving import RequestSpec, ServingEngine, ServingError, drive_engine
from repro.serving.engine import tuned_member_counts
from repro.serving.protocol import parse_forecast
from repro.serving.scheduler import (
    BatchingScheduler,
    EdfScheduler,
    FifoScheduler,
    make_scheduler,
)
from repro.stencils.forecast import build_forecast_step, make_forecast_fields, request_state

DOM = (10, 8, 4)


@pytest.fixture(scope="module")
def step():
    return build_forecast_step("jax", DOM, name="sched_step")


@pytest.fixture(scope="module")
def templates():
    return make_forecast_fields("jax", DOM)


def make_engine(step, templates, **kw):
    fields, scalars = templates
    kw.setdefault("window_ms", 25.0)
    member_counts = kw.pop("member_counts", (1, 2, 4))
    eng = ServingEngine(**kw)
    eng.register(
        step,
        fields=fields,
        scalars=scalars,
        request_fields=("phi",),
        member_counts=member_counts,
        max_steps=100,
    )
    return eng


def drive(engine, specs, **kw):
    async def go():
        async with engine:
            return await drive_engine(engine, specs, **kw)

    return asyncio.run(go())


# ---------------------------------------------------------------------------
# scheduler unit layer: policy order, windows, caps — no engine, no clock
# ---------------------------------------------------------------------------

_ENTRIES = {}


def fake_req(seq, program="p", max_batch=4, priority=1, deadline_at=None):
    entry = _ENTRIES.setdefault((program, max_batch), SimpleNamespace(name=program, max_batch=max_batch))
    return SimpleNamespace(seq=seq, entry=entry, priority=priority, deadline_at=deadline_at)


def window_ids(windows):
    return [(entry.name, [r.seq for r in chunk]) for entry, chunk in windows]


def test_make_scheduler_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_SCHEDULER", raising=False)
    assert isinstance(make_scheduler(None), EdfScheduler)  # the default
    assert isinstance(make_scheduler("fifo"), FifoScheduler)
    assert isinstance(make_scheduler("EDF"), EdfScheduler)
    inst = FifoScheduler()
    assert make_scheduler(inst) is inst  # instance passthrough
    monkeypatch.setenv("REPRO_SCHEDULER", "fifo")
    assert isinstance(make_scheduler(None), FifoScheduler)
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("lifo")


def test_same_backlog_same_windows_twice():
    """Determinism: identical pushes yield identical windows, per policy."""
    reqs = [
        fake_req(3, priority=0, deadline_at=9.0),
        fake_req(0),
        fake_req(2, priority=0, deadline_at=1.0),
        fake_req(1, deadline_at=0.5),
        fake_req(4, max_batch=2, program="q"),
    ]
    for cls in (FifoScheduler, EdfScheduler):
        rounds = []
        for _ in range(2):
            sched = cls()
            for r in reqs:
                sched.push(r)
            rounds.append(window_ids(sched.take(0.0)))
        assert rounds[0] == rounds[1]


def test_fifo_is_arrival_order_and_edf_degenerates_to_it():
    """With no deadlines and one priority class, EDF *is* FIFO."""
    for cls in (FifoScheduler, EdfScheduler):
        sched = cls()
        for seq in (2, 0, 1, 3):
            sched.push(fake_req(seq))
        assert window_ids(sched.take(0.0)) == [("p", [0, 1, 2, 3])]
        assert sched.backlog() == 0


def test_edf_orders_by_priority_then_deadline_then_seq():
    sched = EdfScheduler()
    sched.push(fake_req(0, priority=1))  # no deadline: last in class 1
    sched.push(fake_req(1, priority=0, deadline_at=5.0))
    sched.push(fake_req(2, priority=0, deadline_at=2.0))
    sched.push(fake_req(3, priority=1, deadline_at=1.0))
    assert window_ids(sched.take(0.0)) == [("p", [2, 1, 3, 0])]
    # seq breaks exact ties
    sched.push(fake_req(7, priority=0, deadline_at=3.0))
    sched.push(fake_req(5, priority=0, deadline_at=3.0))
    assert window_ids(sched.take(0.0)) == [("p", [5, 7])]
    assert sched.sort_key(fake_req(9))[1] == math.inf


def test_window_cap_counts_only_present_programs():
    """The over-collection bugfix: the cap is the sum of max_batch over the
    programs IN the backlog — 0 when empty, never max() over the registry."""
    sched = FifoScheduler()
    assert sched.window_cap() == 0  # empty backlog, no ValueError
    for seq in range(5):
        sched.push(fake_req(seq, program="small", max_batch=2))
    assert sched.window_cap() == 2
    sched.push(fake_req(9, program="big", max_batch=8))
    assert sched.window_cap() == 10


def test_take_caps_per_program_and_surplus_recompetes():
    sched = EdfScheduler()
    for seq in range(5):
        sched.push(fake_req(seq, program="a", max_batch=2))
    sched.push(fake_req(5, program="b", max_batch=1, priority=0))
    # one window per program, each at most max_batch; surplus stays pooled
    assert window_ids(sched.take(0.0)) == [("b", [5]), ("a", [0, 1])]
    assert sched.backlog() == 3
    # a late tight-deadline arrival overtakes the queued surplus next round
    sched.push(fake_req(6, program="a", max_batch=2, priority=0, deadline_at=1.0))
    assert window_ids(sched.take(0.0)) == [("a", [6, 2])]
    assert window_ids(sched.take(0.0)) == [("a", [3, 4])]
    assert sched.take(0.0) == []


def test_sweep_and_flush_empty_the_backlog():
    sched = FifoScheduler()
    for seq in range(4):
        sched.push(fake_req(seq))
    dead = sched.sweep(lambda r: r.seq % 2 == 0)
    assert [r.seq for r in dead] == [0, 2] and sched.backlog() == 2
    assert [r.seq for r in sched.flush()] == [1, 3]
    assert sched.backlog() == 0 and sched.flush() == []


# ---------------------------------------------------------------------------
# the tentpole property: EDF strictly reduces deadline expiries vs FIFO
# ---------------------------------------------------------------------------

SERVICE_S = 0.06  # fake per-window service time; 7 loose windows ≥ 0.42 s


def _run_deadline_mix(step, templates, policy):
    """Equal load, two policies: 7 loose requests submitted BEFORE 3 tight
    ones (priority 0, 400 ms deadline), member_counts=(1,) so every window
    serializes.  The fake runner sleeps a fixed service time per window —
    asyncio.sleep never undershoots, so under FIFO the first tight pickup
    happens at ≥ 7×0.06 = 0.42 s > 0.40 s: all three MUST expire.  Under EDF
    the tights ride the first three windows (~0.18 s nominal, wide margin)."""
    eng = make_engine(step, templates, scheduler=policy, window_ms=2.0, member_counts=(1,))
    dispatched = []

    async def fake_run_batch(entry, requests):
        dispatched.append([r.request_id for r in requests])
        await asyncio.sleep(SERVICE_S)
        for r in requests:
            r.post({"type": "done", "request_id": r.request_id, "steps": r.steps})

    eng._run_batch = fake_run_batch
    phi = request_state(DOM, seed=1)

    async def go():
        outcomes = {}

        async def wait_terminal(req):
            while True:
                ev = await req.events.get()
                if ev["type"] in ("done", "error"):
                    outcomes[req.request_id] = ev
                    return

        async with eng:
            reqs = [
                eng.submit("sched_step", {"phi": phi}, steps=1, request_id=f"loose-{i}")
                for i in range(7)
            ]
            reqs += [
                eng.submit(
                    "sched_step", {"phi": phi}, steps=1, request_id=f"tight-{i}",
                    deadline_ms=400.0, priority=0,
                )
                for i in range(3)
            ]
            await asyncio.wait_for(asyncio.gather(*(wait_terminal(r) for r in reqs)), timeout=30.0)
        return outcomes

    outcomes = asyncio.run(go())
    return outcomes, dispatched, eng.stats()


def test_edf_strictly_reduces_deadline_expiries_vs_fifo(step, templates):
    fifo_out, fifo_disp, fifo_stats = _run_deadline_mix(step, templates, "fifo")
    edf_out, edf_disp, edf_stats = _run_deadline_mix(step, templates, "edf")

    # FIFO: every tight request dies in the queue — 504 at pickup, and the
    # expiry never burned a dispatch slot (the dispatch log has no tight id)
    tights = [f"tight-{i}" for i in range(3)]
    assert fifo_stats["deadline_expired"] == 3
    for rid in tights:
        assert fifo_out[rid]["type"] == "error" and fifo_out[rid]["code"] == 504
        assert "not dispatched" in fifo_out[rid]["reason"]
    assert not {rid for w in fifo_disp for rid in w} & set(tights)
    assert fifo_stats["scheduler"]["decisions"]["expired_at_pickup"] == 3

    # EDF at the SAME load: the tights ride the first three windows and all
    # ten requests finish — strictly fewer expiries (3 → 0)
    assert edf_stats["deadline_expired"] == 0
    assert [w[0] for w in edf_disp[:3]] == tights
    assert all(ev["type"] == "done" for ev in edf_out.values())
    assert edf_stats["deadline_expired"] < fifo_stats["deadline_expired"]
    assert edf_stats["scheduler"]["policy"] == "edf"
    assert edf_stats["scheduler"]["decisions"]["reordered"] >= 1


def test_same_load_same_windows_twice(step, templates):
    """Engine-level determinism: the identical submission schedule produces
    the identical dispatch order, run twice (the seq tiebreaker at work)."""
    runs = [_run_deadline_mix(step, templates, "edf")[1] for _ in range(2)]
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# the pickup bugfix: dead-on-arrival requests never reach a dispatch
# ---------------------------------------------------------------------------


def test_expired_while_queued_is_504_with_zero_dispatches(step, templates):
    """A request whose budget is gone before the worker picks it up gets its
    504 at window pickup — no scatter, no batch, no dispatch burned."""
    eng = make_engine(step, templates, window_ms=1.0)

    async def go():
        async with eng:
            req = eng.submit(
                "sched_step", {"phi": request_state(DOM, seed=1)}, steps=5,
                deadline_ms=1e-4,  # ~100 ns of budget: dead by pickup, always
            )
            while True:
                ev = await asyncio.wait_for(req.events.get(), timeout=10.0)
                if ev["type"] in ("done", "error"):
                    return ev

    ev = asyncio.run(go())
    assert ev["type"] == "error" and ev["code"] == 504
    assert "not dispatched" in ev["reason"]
    s = eng.stats()
    assert s["deadline_expired"] == 1
    assert s["batches"] == 0 and s["dispatches"] == 0  # the regression
    assert s["scheduler"]["decisions"]["expired_at_pickup"] == 1


def test_live_deadline_still_enforced_at_segment_boundary(step, templates):
    """The pickup check must not replace the mid-horizon check: a request
    alive at pickup but out of budget between segments still 504s there."""
    eng = make_engine(step, templates, window_ms=1.0)
    spec = RequestSpec(
        "sched_step", {"phi": request_state(DOM, seed=2)}, steps=50,
        stream_every=1, deadline_ms=50.0,
    )
    rep = drive(eng, [spec])
    res = rep.results[0]
    if not res.ok:  # jit warmth decides which boundary; expiry code is fixed
        assert res.error_code == 504
        assert eng.stats()["dispatches"] >= 1  # it DID run before expiring


# ---------------------------------------------------------------------------
# the window-cap bugfix at engine level: no over-collection for small programs
# ---------------------------------------------------------------------------


def test_windows_capped_by_present_program_not_registry(step, templates):
    """With a big-cap program registered but idle, a burst for the small-cap
    program must chunk at ITS max_batch — the old cap used the registry-wide
    max and over-collected."""
    fields, scalars = templates
    eng = ServingEngine(window_ms=25.0)
    eng.register(
        step, fields=fields, scalars=scalars, request_fields=("phi",),
        member_counts=(1, 2), max_steps=100,
    )
    big = build_forecast_step("jax", DOM, name="big_step")
    eng.register(
        big, fields=fields, scalars=scalars, request_fields=("phi",),
        member_counts=(1, 2, 4, 8), max_steps=100,
    )
    specs = [
        RequestSpec("sched_step", {"phi": request_state(DOM, seed=i + 1)}, steps=1)
        for i in range(5)
    ]
    rep = drive(eng, specs)
    assert all(res.ok and res.members <= 2 for res in rep.results)
    assert eng.stats()["batches"] == 3  # 2 + 2 + 1, no registry-wide fill


# ---------------------------------------------------------------------------
# the feedback loop: observed batch shapes land in the tune store
# ---------------------------------------------------------------------------


def _tune_paths(entry):
    return [caching.tuning_path(o.name, o.fingerprint) for o in entry.cp.group_objects]


def test_served_batches_feed_the_tune_store(step, templates):
    eng = make_engine(step, templates)
    entry = eng._programs["sched_step"]
    paths = _tune_paths(entry)
    assert paths, "forecast program should expose group objects"
    for p in paths:
        p.unlink(missing_ok=True)
    try:
        specs = [
            RequestSpec("sched_step", {"phi": request_state(DOM, seed=i + 1)}, steps=2)
            for i in range(2)
        ]
        rep = drive(eng, specs)
        assert all(r.ok for r in rep.results)
        store = json.loads(paths[0].read_text())
        batch_recs = {
            k: v for k, v in store["domains"].items() if k.startswith("serving|batch=")
        }
        assert batch_recs, f"no serving batch records in {store['domains'].keys()}"
        rec = next(iter(batch_recs.values()))
        assert rec["source"] == "serving" and rec["count"] >= 1
        assert rec["us_per_step"] > 0
        # registration reads the observation back as a padding target
        assert rec["batch"] in tuned_member_counts(entry.cp)
        # stats surface the loop: per-priority p99 + decision counters exist
        s = eng.stats()["scheduler"]
        assert s["decisions"]["window"] >= 1
        assert "1" in s["priority_latency_p99_s"]  # default priority class
    finally:
        for p in paths:
            p.unlink(missing_ok=True)


def test_record_batch_observation_merges_best_and_count(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_GT_CACHE", str(tmp_path))
    autotune.record_batch_observation("grp", "fp0", 4, 120.0)
    autotune.record_batch_observation("grp", "fp0", 4, 90.0)   # better: wins
    autotune.record_batch_observation("grp", "fp0", 4, 200.0)  # worse: count only
    path = caching.tuning_path("grp", "fp0")
    store = json.loads(path.read_text())
    rec = store["domains"]["serving|batch=4"]
    assert rec == {"batch": 4, "us_per_step": 90.0, "count": 3, "source": "serving"}
    # a second engine observing concurrently merges instead of clobbering
    autotune.record_batch_observation("grp", "fp0", 8, 70.0)
    store = json.loads(path.read_text())
    assert set(store["domains"]) == {"serving|batch=4", "serving|batch=8"}


# ---------------------------------------------------------------------------
# priority admission + protocol plumbing
# ---------------------------------------------------------------------------


def test_priority_validation_and_defaults(step, templates):
    eng = make_engine(step, templates)  # priority_classes defaults to 3
    phi = request_state(DOM, seed=1)
    assert eng.admit("sched_step", {"phi": phi}).priority == 1  # "normal"
    assert eng.admit("sched_step", {"phi": phi}, priority=0).priority == 0
    assert eng.admit("sched_step", {"phi": phi}, priority=np.int64(2)).priority == 2
    for bad in (True, "high", 1.5, 3, -1):
        with pytest.raises(ServingError) as ei:
            eng.admit("sched_step", {"phi": phi}, priority=bad)
        assert ei.value.code == 422
    solo = make_engine(step, templates, priority_classes=1)
    assert solo.admit("sched_step", {"phi": phi}).priority == 0
    assert solo.priority_classes == 1  # floor at one class


def test_priority_rides_the_wire_protocol():
    frame = {
        "type": "forecast", "program": "p",
        "fields": {}, "priority": 2, "deadline_ms": 100.0,
    }
    kw = parse_forecast(frame)
    assert kw["priority"] == 2 and kw["deadline_ms"] == 100.0
    assert parse_forecast({"type": "forecast", "program": "p", "fields": {}})["priority"] is None
    assert RequestSpec("p", {}, priority=0).priority == 0


# ---------------------------------------------------------------------------
# SLO coupling: latency burn windows scale with the batching window
# ---------------------------------------------------------------------------


def test_wire_batch_window_scales_latency_rules_only(step, templates):
    reg = obs_metrics.MetricsRegistry()
    lat = obs_slo.Objective("l", "p", obs_slo.LATENCY_P99, 0.1)
    avail = obs_slo.Objective("a", "p", obs_slo.AVAILABILITY, 0.999)
    slo = obs_slo.SloEngine(reg, [lat, avail])
    assert slo.rules_for(lat) == slo.rules  # unwired: defaults everywhere
    slo.wire_batch_window(0.002)
    fast, slow = slo.rules_for(lat)
    assert (fast.name, slow.name) == ("batch_fast", "batch_slow")
    assert fast.short_s == 0.25  # floored: 2 ms × 64 ≪ min_short_s
    assert slo.rules_for(avail) == slo.rules  # availability keeps SRE defaults
    wide = obs_slo.SloEngine(reg).wire_batch_window(1.0)
    assert wide._latency_rules[0].short_s == 64.0  # unfloored scaling
    # the engine wires its own window at construction
    eng = make_engine(step, templates, window_ms=4.0)
    (efast, _) = eng.slo.rules_for(lat)
    assert efast.short_s == pytest.approx(max(eng.window_s * 64.0, 0.25))


def test_wired_rules_recover_where_default_rules_still_page():
    """The point of the coupling: after traffic goes good, the batch-scaled
    short windows age the bad samples out within seconds — the 5-minute SRE
    defaults would still be paging at the same instant."""
    reg = obs_metrics.MetricsRegistry()
    req = reg.counter("serving_requests_total", "", program="p")
    hist = reg.histogram("serving_request_latency_seconds", "", program="p")

    def build(wired):
        slo = obs_slo.SloEngine(reg, [obs_slo.Objective("lat", "p", obs_slo.LATENCY_P99, 0.1)])
        return slo.wire_batch_window(0.004) if wired else slo

    wired, default = build(True), build(False)
    for s in (wired, default):
        s.sample(now=0.0)
    req.inc(10)
    hist.observe(0.5)  # p99 ≫ target: those 10 requests are bad
    assert wired.evaluate(now=0.1)["breaching"]
    assert default.evaluate(now=0.1)["breaching"]
    # recovery: p99 back under target, a little good traffic
    for _ in range(600):
        hist.observe(0.01)
    req.inc(20)
    for s in (wired, default):
        s.sample(now=0.2)
    # a few seconds later every wired short window excludes the bad burst...
    later = 0.2 + wired._latency_rules[1].short_s * 4.0
    assert not wired.evaluate(now=later)["breaching"]
    # ...while the 300 s/1800 s defaults still see burn 10/30/budget ≈ 33
    assert default.evaluate(now=later)["breaching"]
