"""Distributed halo-exchange stencil + compressed DP all-reduce.

jax fixes the device count at first init, so multi-device tests run in a
subprocess with ``--xla_force_host_platform_device_count=8``.
"""

import json
import os
import subprocess
import sys
import tempfile
import textwrap
from pathlib import Path


SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import repro
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    # run from a real file (not ``python -c``) so inspect.getsource works on
    # stencil definitions in the script — the frontend parses their source
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
        f.write(script)
        path = f.name
    try:
        res = subprocess.run([sys.executable, path], capture_output=True, text=True, timeout=600, env=env)
    finally:
        os.unlink(path)
    if res.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{res.stderr[-3000:]}")
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_distributed_hdiff_matches_single_device():
    out = _run_subprocess(
        """
        from repro.stencils.hdiff import build_hdiff
        from repro.stencils.distributed import DistributedStencil
        from repro.core import storage

        NI, NJ, NK, H = 64, 32, 5, 3
        rng = np.random.default_rng(0)
        inner = rng.normal(size=(NI, NJ, NK))

        # single-device reference via the numpy backend (zero halo boundary)
        padded = np.zeros((NI + 2*H, NJ + 2*H, NK))
        padded[H:-H, H:-H, :] = inner
        st_np = build_hdiff("numpy")
        i_s = storage.from_array(padded, default_origin=(H, H, 0))
        o_s = storage.zeros(padded.shape, default_origin=(H, H, 0))
        st_np(i_s, o_s, alpha=np.float64(0.05), domain=(NI, NJ, NK))
        ref = o_s.to_numpy()[H:-H, H:-H, :]

        # distributed over a (4, 2) mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        dist = DistributedStencil(build_hdiff("jax"), mesh)
        fields = {"in_phi": jnp.asarray(inner), "out_phi": jnp.zeros_like(jnp.asarray(inner))}
        out = dist(fields, {"alpha": np.float64(0.05)})
        err = float(np.abs(np.asarray(out["out_phi"]) - ref).max())
        print(json.dumps({"err": err}))
        """
    )
    assert out["err"] < 1e-12


def test_distributed_periodic_shift():
    """Periodic halo exchange: a pure i-shift stencil wraps around."""
    out = _run_subprocess(
        """
        from repro.core import gtscript
        from repro.core.gtscript import Field, PARALLEL, computation, interval
        from repro.stencils.distributed import DistributedStencil

        def shift_defs(a: Field[np.float64], o: Field[np.float64]):
            with computation(PARALLEL), interval(...):
                o = a[-1, 0, 0]

        st = gtscript.stencil(backend="jax")(shift_defs)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        dist = DistributedStencil(st, mesh, periodic=(True, True))
        NI, NJ, NK = 16, 8, 3
        rng = np.random.default_rng(1)
        x = rng.normal(size=(NI, NJ, NK))
        out = dist({"a": jnp.asarray(x), "o": jnp.zeros((NI, NJ, NK))}, {})
        got = np.asarray(out["o"])
        ref = np.roll(x, 1, axis=0)   # o[i] = a[i-1] with periodic wrap
        err = float(np.abs(got - ref).max())
        print(json.dumps({"err": err}))
        """
    )
    assert out["err"] < 1e-12


def test_halo_collectives_present_in_hlo():
    """The distributed stencil lowers to collective-permute (ICI traffic)."""
    out = _run_subprocess(
        """
        from repro.stencils.hdiff import build_hdiff
        from repro.stencils.distributed import DistributedStencil

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        dist = DistributedStencil(build_hdiff("jax"), mesh)
        specs = {
            "in_phi": jax.ShapeDtypeStruct((64, 32, 4), jnp.float64),
            "out_phi": jax.ShapeDtypeStruct((64, 32, 4), jnp.float64),
        }
        lowered = dist.lower(specs, {"alpha": np.float64(0.05)})
        txt = lowered.compile().as_text()
        print(json.dumps({"n_permute": txt.count("collective-permute")}))
        """
    )
    assert out["n_permute"] >= 4  # 2 stripes × 2 directions minimum


def test_compressed_dp_allreduce_close_to_exact():
    out = _run_subprocess(
        """
        from functools import partial
        from repro.runtime.compression import dp_allreduce_compressed

        try:
            shard_map = jax.shard_map
        except AttributeError:
            from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = rng.normal(size=(8, 64, 32)).astype(np.float32)

        @partial(shard_map, mesh=mesh,
                 in_specs=jax.sharding.PartitionSpec("data"),
                 out_specs=jax.sharding.PartitionSpec())
        def reduce_compressed(x):
            local = x[0]
            return dp_allreduce_compressed({"g": local}, "data")["g"][None]

        got = np.asarray(reduce_compressed(jnp.asarray(g)))[0]
        exact = g.mean(axis=0)
        rel = float(np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9))
        print(json.dumps({"rel": rel}))
        """
    )
    assert out["rel"] < 0.05  # int8 quantization error bound
