"""Property tests for member-batched Storage (repro.ensemble.batch).

Invariants: prepending the ensemble member axis ``N`` must preserve the
TPU (8, 128) trailing-dim alignment padding, the ``default_origin``
semantics, and the copy-free ``__array__`` / member-view behaviour of the
unbatched allocation — the member axis is transparent to everything the
single-member toolchain computed.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dependency"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import storage  # noqa: E402
from repro.core.storage import ALIGNMENT_TPU, _aligned_shape  # noqa: E402
from repro.ensemble import batch  # noqa: E402

_members = st.integers(1, 9)
_dim = st.integers(1, 40)
_shape3 = st.tuples(_dim, _dim, st.integers(1, 17))
_halo = st.integers(0, 3)


def _round_up(x, m):
    return -(-x // m) * m


@settings(max_examples=25, deadline=None)
@given(members=_members, shape=_shape3)
def test_member_axis_preserves_alignment_padding(members, shape):
    """The aligned allocation pads the SAME trailing dims batched and
    unbatched: the member axis is leading and never folded into the tile."""
    single = storage.zeros(shape, backend="numpy", alignment=True)
    batched = batch.zeros(members, shape, backend="numpy", alignment=True)
    assert single.aligned_shape == (
        shape[0],
        _round_up(shape[1], ALIGNMENT_TPU[0]),
        _round_up(shape[2], ALIGNMENT_TPU[1]),
    )
    assert batched.aligned_shape == (members,) + single.aligned_shape
    # logical shapes unchanged; the data is a view into the padded base
    assert single.shape == shape
    assert batched.shape == (members,) + shape
    assert batched.data.base is not None
    assert batched.data.base.shape == batched.aligned_shape


@settings(max_examples=25, deadline=None)
@given(members=_members, shape=_shape3, h=_halo)
def test_member_axis_preserves_default_origin(members, shape, h):
    ni, nj, nk = shape
    single = storage.storage_for_domain((ni, nj, nk), (h, h, 0), backend="numpy")
    batched = storage.storage_for_domain((ni, nj, nk), (h, h, 0), backend="numpy", members=members)
    assert batched.axes == ("N",) + single.axes
    assert batched.default_origin == (0,) + single.default_origin
    assert batched.shape == (members,) + single.shape
    for m in range(members):
        view = batched.member(m)
        assert view.axes == single.axes
        assert view.default_origin == single.default_origin
        assert view.shape == single.shape


@settings(max_examples=25, deadline=None)
@given(members=_members, shape=_shape3)
def test_batched_array_protocol_is_copy_free(members, shape):
    batched = batch.zeros(members, shape, backend="numpy", alignment=True)
    arr = np.asarray(batched)
    assert arr.shape == (members,) + shape
    assert np.shares_memory(arr, batched.data)
    # member views share memory too: writes through a view land in the batch
    if members > 1:
        view = batched.member(1)
        assert np.shares_memory(np.asarray(view), batched.data)
        view[0, 0, 0] = 42.0
        assert batched.data[1, 0, 0, 0] == 42.0
        assert batched.data[0, 0, 0, 0] == 0.0


@settings(max_examples=25, deadline=None)
@given(shape=_shape3)
def test_aligned_write_read_roundtrip(shape):
    """Writes through the aligned view must read back exactly (the view
    never aliases padding)."""
    s = storage.zeros(shape, backend="numpy", alignment=True)
    rng = np.random.default_rng(0)
    data = rng.normal(size=shape)
    s[...] = data
    np.testing.assert_array_equal(np.asarray(s), data)
    # padding stays zero: the logical view exactly tiles the base corner
    base = s.data.base
    assert base[tuple(slice(0, d) for d in shape)].sum() == pytest.approx(data.sum())


@settings(max_examples=15, deadline=None)
@given(members=_members, nk=st.integers(1, 300))
def test_k_only_batched_alignment_pads_lanes_not_members(members, nk):
    """A batched (N, K) field pads K to the lane width; N is never padded."""
    batched = batch.zeros(members, (nk,), axes=("K",), backend="numpy", alignment=True)
    assert batched.aligned_shape == (members, _round_up(nk, ALIGNMENT_TPU[1]))


def test_aligned_shape_helper_edges():
    assert _aligned_shape((), ALIGNMENT_TPU) == ()
    assert _aligned_shape((5,), ALIGNMENT_TPU) == (128,)
    assert _aligned_shape((5, 5), ALIGNMENT_TPU) == (8, 128)
    assert _aligned_shape((3, 5, 5), ALIGNMENT_TPU) == (3, 8, 128)
    # skip_leading: the member axis passes through
    assert _aligned_shape((4, 3, 5, 5), ALIGNMENT_TPU, skip_leading=1) == (4, 3, 8, 128)
    assert _aligned_shape((4, 5), ALIGNMENT_TPU, skip_leading=1) == (4, 128)


def test_jax_backend_records_aligned_shape_without_view():
    s = storage.zeros((5, 6, 7), backend="jax", alignment=True)
    assert s.shape == (5, 6, 7)  # XLA owns device layout: logical allocation
    assert s.aligned_shape == (5, 8, 128)
