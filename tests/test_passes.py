"""Optimization pass pipeline tests (repro.core.passes).

Two layers of guarantees:

* **Differential correctness** — for every stencil in the library (the
  ``stencils/library.py`` operators wrapped in minimal stencils, plus
  hdiff / vadv / vadv_system), outputs are allclose-identical across the
  debug oracle, ``opt_level=0`` (verbatim lowering) and the full default
  pipeline on every backend.
* **The pipeline demonstrably works** — the optimized IR is strictly
  smaller on the paper's two motifs (fewer temporaries on hdiff/vadv, fewer
  multi-stages on vadv_system), per-pass timings surface in ``exec_info``,
  and the cache fingerprint depends on the pass configuration.
"""

import os

import numpy as np
import pytest

from repro.core import analysis, frontend, gtscript, ir, passes, storage

# the CI pass matrix re-runs this file with REPRO_OPT_LEVEL / REPRO_DISABLE_
# PASSES set: differential tests must stay green there (that's the point),
# but assertions about the *default* pipeline's reports/fingerprints don't
# apply when the defaults are shifted
_env_knobs_active = bool(
    os.environ.get("REPRO_OPT_LEVEL") or os.environ.get("REPRO_DISABLE_PASSES")
)
skip_under_env_knobs = pytest.mark.skipif(
    _env_knobs_active, reason="pass-pipeline env knobs active (CI pass matrix)"
)
from repro.core.gtscript import (
    BACKWARD,
    FORWARD,
    PARALLEL,
    Field,
    computation,
    interval,
)
from repro.stencils.library import (
    avg_x,
    avg_y,
    fwd_avg_z,
    gradx,
    gradx_c,
    grady,
    grady_c,
    laplacian,
    smagorinsky_factor,
    upwind_flux_x,
    upwind_flux_y,
)

NI, NJ, NK = 7, 6, 5


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape)


def _analyze(defs, externals=None, name=None):
    return analysis.analyze(
        frontend.parse_stencil_definition(defs, externals=externals or {}, name=name or defs.__name__)
    )


def run_differential(defs, fields_np, scalars, domain, externals=None):
    """debug oracle vs every backend at opt_level 0 and the default level."""
    variants = [
        ("debug", "debug", {}),
        ("numpy@0", "numpy", {"opt_level": 0}),
        ("numpy@default", "numpy", {}),
        ("jax@0", "jax", {"opt_level": 0}),
        ("jax@default", "jax", {}),
        ("pallas@0", "pallas", {"opt_level": 0, "block": (4, 4)}),
        ("pallas@default", "pallas", {"block": (4, 4)}),
    ]
    results = {}
    for key, backend, opts in variants:
        st = gtscript.stencil(backend=backend, externals=externals or {}, **opts)(defs)
        fs = {
            n: storage.from_array(arr.copy(), backend=backend, default_origin=origin)
            for n, (arr, origin) in fields_np.items()
        }
        st(**fs, **scalars, domain=domain)
        results[key] = {n: f.to_numpy() for n, f in fs.items()}
    ref = results["debug"]
    for key, out in results.items():
        for n in ref:
            np.testing.assert_allclose(
                out[n], ref[n], rtol=1e-13, atol=1e-13,
                err_msg=f"{key} disagrees with the debug oracle on {n!r}",
            )
    return results


# ---------------------------------------------------------------------------
# library operators, each wrapped in a minimal stencil
# ---------------------------------------------------------------------------


def _lap_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = laplacian(phi)


def _gradx_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = gradx(phi)


def _grady_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = grady(phi)


def _gradx_c_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = gradx_c(phi)


def _grady_c_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = grady_c(phi)


def _avg_x_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = avg_x(phi)


def _avg_y_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = avg_y(phi)


def _fwd_avg_z_defs(phi: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL):
        with interval(0, -1):
            o = fwd_avg_z(phi)
        with interval(-1, None):
            o = phi


def _upwind_x_defs(phi: Field[np.float64], vel: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = upwind_flux_x(phi, vel)


def _upwind_y_defs(phi: Field[np.float64], vel: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = upwind_flux_y(phi, vel)


def _smag_defs(u: Field[np.float64], v: Field[np.float64], o: Field[np.float64]):
    with computation(PARALLEL), interval(...):
        o = smagorinsky_factor(u, v)


_ONE_FIELD_CASES = [
    _lap_defs, _gradx_defs, _grady_defs, _gradx_c_defs, _grady_c_defs,
    _avg_x_defs, _avg_y_defs, _fwd_avg_z_defs,
]
_TWO_FIELD_CASES = [_upwind_x_defs, _upwind_y_defs, _smag_defs]


@pytest.mark.parametrize("defs", _ONE_FIELD_CASES, ids=lambda d: d.__name__.strip("_"))
def test_library_operator_differential(defs):
    H = 1
    phi = _rand((NI + 2 * H, NJ + 2 * H, NK), seed=1)
    run_differential(
        defs,
        {"phi": (phi, (H, H, 0)), "o": (np.zeros_like(phi), (H, H, 0))},
        {},
        (NI, NJ, NK),
    )


@pytest.mark.parametrize("defs", _TWO_FIELD_CASES, ids=lambda d: d.__name__.strip("_"))
def test_library_operator_two_fields_differential(defs):
    H = 1
    shape = (NI + 2 * H, NJ + 2 * H, NK)
    a = _rand(shape, seed=2)
    b = _rand(shape, seed=3)
    names = ("u", "v") if defs is _smag_defs else ("phi", "vel")
    run_differential(
        defs,
        {
            names[0]: (a, (H, H, 0)),
            names[1]: (b, (H, H, 0)),
            "o": (np.zeros_like(a), (H, H, 0)),
        },
        {},
        (NI, NJ, NK),
    )


# ---------------------------------------------------------------------------
# the paper's two motifs + system assembly
# ---------------------------------------------------------------------------


def test_hdiff_differential():
    from repro.stencils.hdiff import hdiff_defs

    H = 3
    x = _rand((NI + 2 * H, NJ + 2 * H, NK), seed=4)
    run_differential(
        hdiff_defs,
        {"in_phi": (x, (H, H, 0)), "out_phi": (np.zeros_like(x), (H, H, 0))},
        {"alpha": np.float64(0.07)},
        (NI, NJ, NK),
        externals={"LIM": 0.01},
    )


def test_vadv_differential():
    from repro.stencils.vadv import vadv_defs

    rng = np.random.default_rng(5)
    shape = (NI, NJ, NK)
    fields = {
        "a": (rng.normal(size=shape) * 0.1, (0, 0, 0)),
        "b": (2.0 + rng.random(shape), (0, 0, 0)),
        "c": (rng.normal(size=shape) * 0.1, (0, 0, 0)),
        "d": (rng.normal(size=shape), (0, 0, 0)),
        "out": (np.zeros(shape), (0, 0, 0)),
    }
    run_differential(vadv_defs, fields, {}, shape)


def test_vadv_system_differential():
    from repro.stencils.vadv import vadv_system_defs

    rng = np.random.default_rng(6)
    shape = (NI, NJ, NK)
    fields = {
        "w": (rng.normal(size=shape), (0, 0, 0)),
        "phi": (rng.normal(size=shape), (0, 0, 0)),
        "a": (np.zeros(shape), (0, 0, 0)),
        "b": (np.zeros(shape), (0, 0, 0)),
        "c": (np.zeros(shape), (0, 0, 0)),
        "d": (np.zeros(shape), (0, 0, 0)),
    }
    run_differential(
        vadv_system_defs, fields, {"dt": np.float64(0.5), "dz": np.float64(1.5)}, shape
    )


def test_conditionally_overwritten_local_differential():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            t = a * 2.0
            if a > 0.0:
                t = a * 3.0
            o = t + 1.0

    x = _rand((NI, NJ, NK), seed=7)
    run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )
    # t's first write is unconditional → it demotes despite the masked update
    impl = _analyze(defs)
    opt, _ = passes.run_pipeline(impl)
    assert [f.name for f in opt.local_decls] == ["t"]


def test_zero_init_temp_not_demoted_and_correct():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            if a > 0.0:
                t = a * 2.0
            o = t + a

    x = _rand((NI, NJ, NK), seed=8)
    run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )
    impl = _analyze(defs)
    opt, _ = passes.run_pipeline(impl)
    assert not opt.local_decls  # conditional first write must stay a field


# ---------------------------------------------------------------------------
# the pipeline demonstrably does work (acceptance assertions)
# ---------------------------------------------------------------------------


def test_hdiff_optimized_ir_is_smaller():
    from repro.stencils.hdiff import hdiff_defs

    impl0 = _analyze(hdiff_defs, externals={"LIM": 0.01}, name="hdiff")
    opt, report = passes.run_pipeline(impl0)
    assert len(opt.temporaries) < len(impl0.temporaries)
    assert {f.name for f in opt.local_decls} == {"flux_x", "flux_y", "grad_x", "grad_y"}
    assert any(r["pass"] == "temp_demotion" and r["changed"] for r in report)


def test_vadv_optimized_ir_is_smaller():
    from repro.stencils.vadv import vadv_defs

    impl0 = _analyze(vadv_defs, name="vadv")
    opt, _ = passes.run_pipeline(impl0)
    assert len(opt.temporaries) < len(impl0.temporaries)
    assert {f.name for f in opt.local_decls} == {"denom"}


def test_vadv_system_fuses_multistages():
    from repro.stencils.vadv import vadv_system_defs

    impl0 = _analyze(vadv_system_defs, name="vadv_system")
    assert len(impl0.multi_stages) == 3
    opt, report = passes.run_pipeline(impl0)
    assert len(opt.multi_stages) == 1
    assert any(r["pass"] == "multistage_fusion" and r["changed"] for r in report)


@skip_under_env_knobs
def test_pass_timings_in_exec_info():
    from repro.stencils.hdiff import build_hdiff

    hd = build_hdiff("numpy")
    H = 3
    i = storage.from_array(_rand((NI + 2 * H, NJ + 2 * H, NK)), default_origin=(H, H, 0))
    o = storage.zeros((NI + 2 * H, NJ + 2 * H, NK), default_origin=(H, H, 0))
    info = {}
    hd(i, o, alpha=np.float64(0.1), exec_info=info)
    report = info["pass_report"]
    assert report, "pass_report missing from exec_info"
    names = {r["pass"] for r in report}
    assert {"multistage_fusion", "temp_demotion", "dead_temp_pruning"} <= names
    assert all(r["seconds"] >= 0.0 and "before" in r and "after" in r for r in report)


# ---------------------------------------------------------------------------
# individual passes
# ---------------------------------------------------------------------------


def test_interval_merging_merges_identical_bodies():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(FORWARD):
            with interval(0, 2):
                o = a * 2.0
            with interval(2, None):
                o = a * 2.0

    impl0 = _analyze(defs)
    assert sum(len(ms.intervals) for ms in impl0.multi_stages) == 2
    opt, report = passes.run_pipeline(impl0)
    assert sum(len(ms.intervals) for ms in opt.multi_stages) == 1
    merged = opt.multi_stages[0].intervals[0].interval
    assert merged == ir.VerticalInterval.full()
    assert any(r["pass"] == "interval_merging" and r["changed"] for r in report)

    x = _rand((NI, NJ, NK), seed=9)
    run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )


def test_interval_merging_backward():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(BACKWARD):
            with interval(-1, None):
                o = a + 1.0
            with interval(0, -1):
                o = a + 1.0

    impl0 = _analyze(defs)
    opt, _ = passes.run_pipeline(impl0)
    assert sum(len(ms.intervals) for ms in opt.multi_stages) == 1
    x = _rand((NI, NJ, NK), seed=10)
    run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )


def test_interval_merging_keeps_different_bodies():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(FORWARD):
            with interval(0, 2):
                o = a * 2.0
            with interval(2, None):
                o = a * 3.0

    opt, _ = passes.run_pipeline(_analyze(defs))
    assert sum(len(ms.intervals) for ms in opt.multi_stages) == 2


def test_constant_folding_folds_literal_arithmetic():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = a * (2.0 * 3.0 + min(1.0, 4.0)) - 0.0

    impl0 = _analyze(defs)
    opt, report = passes.run_pipeline(impl0)
    (stmt,) = opt.multi_stages[0].intervals[0].stages[0].stmts
    # reassociation canonicalizes commutative operands literal-first
    assert stmt.value == ir.BinOp("*", ir.Literal(7.0, "float"), ir.FieldAccess("a", (0, 0, 0)))
    assert any(r["pass"] == "constant_folding" and r["changed"] for r in report)

    x = _rand((NI, NJ, NK), seed=11)
    run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )


def test_constant_folding_prunes_dead_branch_and_temp():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            t = a * 2.0
            if 1.0 > 2.0:
                o = t
            else:
                o = a

    impl0 = _analyze(defs)
    opt, _ = passes.run_pipeline(impl0)
    # the dead branch was the only consumer of t → t and its stage are gone
    assert not opt.temporaries and not opt.local_decls
    assert sum(len(itv.stages) for ms in opt.multi_stages for itv in ms.intervals) == 1


def test_constant_folding_empty_then_branch():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = a
            if a > 0.0:
                if 1.0 > 2.0:
                    o = a * 5.0
            else:
                o = -a

    # the then-branch folds away entirely; the else must still apply
    x = _rand((NI, NJ, NK), seed=12)
    results = run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )
    ref = np.where(x > 0.0, x, -x)
    np.testing.assert_allclose(results["debug"]["o"], ref)


def test_constant_folding_mod_uses_floored_semantics():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = a + mod(-7.0, 3.0)  # noqa: F821  (gtscript native)

    # np.mod(-7, 3) == 2 (floored); math.fmod would give -1 — the fold and
    # every backend (incl. the debug oracle) must agree on the floored value
    x = _rand((NI, NJ, NK), seed=13)
    results = run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )
    np.testing.assert_allclose(results["debug"]["o"], x + 2.0)

    opt, _ = passes.run_pipeline(_analyze(defs))
    (stmt,) = opt.multi_stages[0].intervals[0].stages[0].stmts
    assert stmt.value == ir.BinOp("+", ir.Literal(2.0, "float"), ir.FieldAccess("a", (0, 0, 0)))


def test_constant_folding_keeps_out_of_range_int_cast():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = a + int(5000000000)  # wraps at runtime in int32 — must not fold

    opt, _ = passes.run_pipeline(_analyze(defs))
    (stmt,) = opt.multi_stages[0].intervals[0].stages[0].stmts
    assert stmt.value.right == ir.Cast("int32", ir.Literal(5000000000, "int"))

    # optimized must match unoptimized on the same backend (the runtime cast
    # wraps; folding it away used to change the value). NB: debug's scalar
    # int() does not wrap — a pre-existing oracle divergence on overflow, so
    # this is deliberately a same-backend differential only.
    x = _rand((NI, NJ, NK), seed=14)
    outs = {}
    for lvl in (0, 3):
        st = gtscript.stencil(backend="numpy", opt_level=lvl)(defs)
        a = storage.from_array(x.copy())
        o = storage.zeros(x.shape)
        st(a, o, domain=(NI, NJ, NK))
        outs[lvl] = o.to_numpy()
    np.testing.assert_array_equal(outs[0], outs[3])


def test_constant_folding_preserves_negative_zero():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = a + 0.0

    # x + 0.0 flips -0.0 to +0.0, so it must NOT fold away (commuting it to
    # 0.0 + x is fine: IEEE addition is commutative bit-for-bit)
    opt, _ = passes.run_pipeline(_analyze(defs))
    (stmt,) = opt.multi_stages[0].intervals[0].stages[0].stmts
    assert stmt.value == ir.BinOp("+", ir.Literal(0.0, "float"), ir.FieldAccess("a", (0, 0, 0)))

    x = np.full((NI, NJ, NK), -0.0)
    results = run_differential(
        defs,
        {"a": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )
    assert not np.signbit(results["numpy@default"]["o"]).any()


def test_dead_temp_pruning_shrinks_extents():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            wide = a[2, 0, 0] + a[-2, 0, 0]
            if False:
                o = wide
            else:
                o = a

    impl0 = _analyze(defs)
    opt, _ = passes.run_pipeline(impl0)
    assert opt.extent_of("a").i == (0, 0)  # the ±2 halo demand died with `wide`


# ---------------------------------------------------------------------------
# cross-stage CSE
# ---------------------------------------------------------------------------


def _cse_detail(report):
    for r in report:
        if r["pass"] == "cross_stage_cse":
            return r.get("detail", {})
    return {}


def test_cse_hoists_shift_equivalent_neighbor_sums():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = (a[1, 0, 0] + a[0, 0, 0]) + (a[0, 0, 0] + a[-1, 0, 0])

    impl0 = _analyze(defs)
    opt, report = passes.run_pipeline(impl0)
    detail = _cse_detail(report)
    assert detail == {"hoisted": 1, "eliminated": 1}
    assert [f.name for f in opt.temporaries if f.name.startswith("_cse")] == ["_cse0"]
    # the two occurrences read the shared temp at shifts (1,0,0) / (0,0,0)
    # and halos stay exactly what the original reads demanded
    assert opt.extent_of("a").i == (-1, 1)

    x = _rand((NI, NJ, NK), seed=20)
    H = 1
    xp = np.pad(x, ((H, H), (H, H), (0, 0)))
    run_differential(
        defs,
        {"a": (xp, (H, H, 0)), "o": (np.zeros_like(xp), (H, H, 0))},
        {},
        (NI, NJ, NK),
    )


def test_cse_vadv_system_eliminates_gcv_chain():
    from repro.stencils.vadv import vadv_system_defs

    impl0 = _analyze(vadv_system_defs, name="vadv_system")
    opt, report = passes.run_pipeline(impl0)
    detail = _cse_detail(report)
    # the 0.25*(w_k + w_k±1)*dt/dz chain and the phi-difference chain each
    # repeat (k-shifted) in the interior interval
    assert detail["hoisted"] == 2 and detail["eliminated"] == 2
    # the k-shifted hoists evaluate in their own vertical interval
    cse_intervals = [
        itv
        for ms in opt.multi_stages
        for itv in ms.intervals
        if any(st.writes[0].startswith("_cse") for st in itv.stages if st.writes)
    ]
    assert cse_intervals, "expected dedicated defining intervals for k-shifted hoists"


def test_cse_hdiff_smag_eliminates_stretch_and_shear():
    from repro.stencils.hdiff import hdiff_smag_defs

    impl0 = _analyze(hdiff_smag_defs, externals={"CS": 0.15}, name="hdiff_smag")
    opt, report = passes.run_pipeline(impl0)
    detail = _cse_detail(report)
    assert detail["hoisted"] == 2 and detail["eliminated"] == 2
    assert opt.extent_of("u").i == (-1, 1)  # CSE must not grow the halo

    H = 1
    shape = (NI + 2 * H, NJ + 2 * H, NK)
    u, v = _rand(shape, seed=21), _rand(shape, seed=22)
    run_differential(
        hdiff_smag_defs,
        {
            "u": (u, (H, H, 0)),
            "v": (v, (H, H, 0)),
            "out_u": (np.zeros(shape), (H, H, 0)),
            "out_v": (np.zeros(shape), (H, H, 0)),
        },
        {"dt": np.float64(0.4)},
        (NI, NJ, NK),
        externals={"CS": 0.15},
    )


def test_cse_respects_intervening_writes():
    def defs(a: Field[np.float64], b: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            t1 = a * a + b
            b = t1 * 2.0
            t2 = a * a + b
            o = t1 + t2

    impl0 = _analyze(defs)
    opt, report = passes.run_pipeline(impl0)
    # `a * a` repeats with no interference and hoists; `a * a + b` repeats
    # too but b is rewritten between the occurrences — it must NOT merge
    detail = _cse_detail(report)
    assert detail["hoisted"] == 1 and detail["eliminated"] == 1
    # zero-offset single-interval hoists demote to stage-locals downstream —
    # the "hoist into stage-local values" endgame
    (cse,) = [f for f in tuple(opt.temporaries) + tuple(opt.local_decls)
              if f.name.startswith("_cse")]
    for ms in opt.multi_stages:
        for itv in ms.intervals:
            for st in itv.stages:
                for stmt in st.stmts:
                    if stmt.target.name == cse.name:
                        assert stmt.value == ir.BinOp(
                            "*", ir.FieldAccess("a", (0, 0, 0)), ir.FieldAccess("a", (0, 0, 0))
                        )

    x = _rand((NI, NJ, NK), seed=23)
    y = _rand((NI, NJ, NK), seed=24)
    run_differential(
        defs,
        {
            "a": (x, (0, 0, 0)),
            "b": (y, (0, 0, 0)),
            "o": (np.zeros_like(x), (0, 0, 0)),
        },
        {},
        (NI, NJ, NK),
    )


def test_cse_skips_sequential_sweeps():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(FORWARD):
            with interval(0, 1):
                o = a * a + a
            with interval(1, None):
                o = a * a + o[0, 0, -1]

    impl0 = _analyze(defs)
    _opt, report = passes.run_pipeline(impl0)
    assert _cse_detail(report) == {"hoisted": 0, "eliminated": 0}


def test_cse_disable_toggle():
    from repro.stencils.vadv import vadv_system_defs

    impl0 = _analyze(vadv_system_defs, name="vadv_system")
    opt, report = passes.run_pipeline(impl0, disable=("cross_stage_cse",))
    assert not any(r["pass"] == "cross_stage_cse" for r in report)
    assert not any(f.name.startswith("_cse") for f in opt.temporaries)


# ---------------------------------------------------------------------------
# configuration / plumbing
# ---------------------------------------------------------------------------


def test_opt_level_0_runs_no_passes():
    from repro.stencils.hdiff import hdiff_defs

    impl0 = _analyze(hdiff_defs, externals={"LIM": 0.01}, name="hdiff")
    out, report = passes.run_pipeline(impl0, opt_level=0)
    assert out == impl0 and report == []


def test_disable_and_enable_passes():
    from repro.stencils.hdiff import hdiff_defs

    impl0 = _analyze(hdiff_defs, externals={"LIM": 0.01}, name="hdiff")
    no_demote, _ = passes.run_pipeline(impl0, disable=("temp_demotion",))
    assert not no_demote.local_decls

    from repro.stencils.vadv import vadv_system_defs

    sys0 = _analyze(vadv_system_defs, name="vadv_system")
    fused_only, report = passes.run_pipeline(sys0, opt_level=0, enable=("multistage_fusion",))
    assert len(fused_only.multi_stages) == 1
    assert [r["pass"] for r in report] == ["multistage_fusion"]

    with pytest.raises(ValueError, match="unknown pass"):
        passes.run_pipeline(impl0, disable=("no_such_pass",))


@skip_under_env_knobs
def test_fingerprint_keyed_on_pass_config():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = a * 2.0

    st0 = gtscript.stencil(backend="numpy", opt_level=0)(defs)
    st3 = gtscript.stencil(backend="numpy")(defs)
    st_no_fold = gtscript.stencil(backend="numpy", disable_passes=("constant_folding",))(defs)
    assert st0.fingerprint != st3.fingerprint
    assert st_no_fold.fingerprint not in (st0.fingerprint, st3.fingerprint)


# ---------------------------------------------------------------------------
# interval splitting (boundary specialization)
# ---------------------------------------------------------------------------


def _split_detail(report):
    for r in report:
        if r["pass"] == "interval_splitting":
            return r.get("detail", {})
    return {}


def test_interval_splitting_peels_vadv_boundary():
    from repro.stencils.vadv import vadv_boundary_defs

    impl0 = _analyze(vadv_boundary_defs, name="vadv_boundary")
    opt, report = passes.run_pipeline(impl0)
    detail = _split_detail(report)
    assert detail["intervals_split"] == 2
    orders = [ms.order.name for ms in opt.multi_stages]
    assert orders == ["PARALLEL", "FORWARD", "PARALLEL", "BACKWARD"]
    # the payoff: the interior sweeps stop carrying the boundary-only flux
    # outputs — half the carried planes of the verbatim lowering
    opt0, _ = passes.run_pipeline(impl0, opt_level=0)
    nk = 16
    planes = lambda im: sum(  # noqa: E731
        p.carried_planes(nk) for p in analysis.sequential_carry_plan(im).values()
    )
    assert planes(opt) == planes(opt0) // 2

    rng = np.random.default_rng(30)
    H = 1
    shape = (NI + 2 * H, NJ + 2 * H, NK)
    fields = {
        "wcon": (rng.normal(size=shape), (H, H, 0)),
        "phi": (rng.normal(size=shape), (H, H, 0)),
        "flux_bot": (rng.normal(size=shape), (H, H, 0)),
        "flux_top": (rng.normal(size=shape), (H, H, 0)),
        "acc": (np.zeros(shape), (H, H, 0)),
        "res": (np.zeros(shape), (H, H, 0)),
    }
    run_differential(
        vadv_boundary_defs, fields, {"weight": np.float64(0.4)}, (NI, NJ, NK)
    )


def test_interval_splitting_converts_carry_free_sweep():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(FORWARD):
            with interval(0, 1):
                o = a * 2.0
            with interval(1, None):
                o = a * 3.0

    opt, report = passes.run_pipeline(_analyze(defs))
    assert _split_detail(report)["parallelized_sweeps"] == 1
    assert all(ms.order == ir.IterationOrder.PARALLEL for ms in opt.multi_stages)


def test_interval_splitting_carry_guard_protects_vintg_windows():
    from repro.stencils.vintg import vintg_defs

    impl0 = _analyze(vintg_defs, name="vintg")
    opt, report = passes.run_pipeline(impl0)
    detail = _split_detail(report)
    # peeling vintg's boundary inits would reclassify the depth-1 window
    # accumulators as full cross-multi-stage carries — the guard refuses
    assert detail["intervals_split"] == 0
    assert detail["rejected_by_carry_guard"] == 2
    plans = analysis.sequential_carry_plan(opt)
    assert all(len(p.window) == 1 for p in plans.values())


def test_interval_splitting_keeps_interior_recurrence():
    from repro.stencils.vadv import vadv_defs

    opt, report = passes.run_pipeline(_analyze(vadv_defs, name="vadv"))
    assert _split_detail(report)["intervals_split"] == 2
    orders = [ms.order.name for ms in opt.multi_stages]
    assert orders == ["PARALLEL", "FORWARD", "PARALLEL", "BACKWARD"]


def test_interval_splitting_retype_roundtrip_float32():
    """Splitting decisions are dtype-independent: the float32 variant of the
    boundary stencil (via ir.retype_definition) splits identically, and its
    optimized numpy output is bit-identical to its own verbatim lowering."""
    from repro.stencils.vadv import build_vadv_boundary, vadv_boundary_defs

    impl64 = _analyze(vadv_boundary_defs, name="vadv_boundary")
    defn32 = ir.retype_definition(
        frontend.parse_stencil_definition(vadv_boundary_defs, externals={}, name="vadv_boundary"),
        {"float64": "float32"},
    )
    impl32 = analysis.analyze(defn32)
    _, rep64 = passes.run_pipeline(impl64)
    _, rep32 = passes.run_pipeline(impl32)
    assert _split_detail(rep64) == _split_detail(rep32)

    H = 1
    rng = np.random.default_rng(31)
    shape = (NI + 2 * H, NJ + 2 * H, NK)
    data = {
        "wcon": rng.normal(size=shape), "phi": rng.normal(size=shape),
        "flux_bot": np.zeros(shape), "flux_top": np.zeros(shape),
        "acc": np.zeros(shape), "res": np.zeros(shape),
    }
    outs = {}
    for lvl in (0, 3):
        st = build_vadv_boundary("numpy", dtype="float32", opt_level=lvl)
        fs = {
            n: storage.from_array(v.astype("float32"), default_origin=(H, H, 0))
            for n, v in data.items()
        }
        st(**fs, weight=np.float32(0.4), domain=(NI, NJ, NK))
        outs[lvl] = {n: f.to_numpy() for n, f in fs.items()}
    for n in outs[0]:
        np.testing.assert_array_equal(outs[0][n], outs[3][n], err_msg=n)


# ---------------------------------------------------------------------------
# algebraic reassociation
# ---------------------------------------------------------------------------


def test_reassociation_commutes_for_cse():
    def defs(u: Field[np.float64], v: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            t1 = u * v + u
            t2 = v * u + v
            o = t1 + t2

    impl0 = _analyze(defs)
    opt, report = passes.run_pipeline(impl0)
    # u*v and v*u share one canonical spelling → CSE hoists the product
    assert _cse_detail(report) == {"hoisted": 1, "eliminated": 1}
    _opt, report_off = passes.run_pipeline(impl0, disable=("algebraic_reassociation",))
    assert _cse_detail(report_off) == {"hoisted": 0, "eliminated": 0}

    x, y = _rand((NI, NJ, NK), seed=32), _rand((NI, NJ, NK), seed=33)
    run_differential(
        defs,
        {"u": (x, (0, 0, 0)), "v": (y, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )


def test_reassociation_exact_mode_only_commutes():
    def defs2(a: Field[np.float64], o: Field[np.float64], *, s: np.float64):
        with computation(PARALLEL), interval(...):
            o = a + (s + a[1, 0, 0])

    impl = _analyze(defs2)
    opt_exact, rep_exact = passes.run_pipeline(impl)
    opt_loose, rep_loose = passes.run_pipeline(impl, exact=False)
    (stmt_e,) = opt_exact.multi_stages[0].intervals[0].stages[0].stmts
    (stmt_l,) = opt_loose.multi_stages[0].intervals[0].stages[0].stmts
    # exact: association untouched (a + (s + a[1,0,0]) keeps its tree)
    assert isinstance(stmt_e.value.right, ir.BinOp)
    # exact=False: the chain flattens left-associated with sorted terms
    assert stmt_l.value == ir.BinOp(
        "+",
        ir.BinOp("+", ir.ScalarRef("s"), ir.FieldAccess("a", (0, 0, 0))),
        ir.FieldAccess("a", (1, 0, 0)),
    )
    detail = next(r["detail"] for r in rep_loose if r["pass"] == "algebraic_reassociation")
    assert detail["reassociated"] >= 1 and detail["exact"] is False


def test_exact_flag_in_fingerprint():
    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            o = a + (a[1, 0, 0] + a[-1, 0, 0])

    st_exact = gtscript.stencil(backend="numpy")(defs)
    st_loose = gtscript.stencil(backend="numpy", exact=False)(defs)
    assert st_exact.fingerprint != st_loose.fingerprint


# ---------------------------------------------------------------------------
# numpy stage tiling
# ---------------------------------------------------------------------------


def test_numpy_tiling_bit_identical_on_odd_domains():
    from repro.stencils.hdiff import hdiff_defs

    H = 3
    ni, nj, nk = 13, 11, 4  # deliberately not tile-divisible
    data = _rand((ni + 2 * H, nj + 2 * H, nk), seed=34)
    outs = {}
    for label, opts in (("untiled", {"tile": None}), ("tiled", {"tile": (5, 4)})):
        st = gtscript.stencil(backend="numpy", externals={"LIM": 0.01}, **opts)(hdiff_defs)
        i = storage.from_array(data.copy(), default_origin=(H, H, 0))
        o = storage.zeros(data.shape, default_origin=(H, H, 0))
        st(i, o, alpha=np.float64(0.07), domain=(ni, nj, nk))
        outs[label] = o.to_numpy()
    np.testing.assert_array_equal(outs["tiled"], outs["untiled"])


def test_numpy_tiling_skips_antidependent_multistage():
    from repro.core.codegen_array import tiling_plan

    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL), interval(...):
            t = a[1, 0, 0] + a[-1, 0, 0]
            o = o + t  # reads its own write target → overlap recompute double-applies

    opt, _ = passes.run_pipeline(_analyze(defs))
    plan = tiling_plan(opt)
    assert plan["tiled_multistages"] == 0 and plan["untileable_multistages"] == 1

    # ... and the emitted module must therefore match untiled bit-for-bit
    x = _rand((NI + 2, NJ + 2, NK), seed=35)
    outs = {}
    for label, opts in (("untiled", {"tile": None}), ("tiled", {"tile": (3, 2)})):
        st = gtscript.stencil(backend="numpy", **opts)(defs)
        a = storage.from_array(x.copy(), default_origin=(1, 1, 0))
        o = storage.from_array(_rand((NI + 2, NJ + 2, NK), seed=36), default_origin=(1, 1, 0))
        st(a, o, domain=(NI, NJ, NK))
        outs[label] = o.to_numpy()
    np.testing.assert_array_equal(outs["tiled"], outs["untiled"])


@skip_under_env_knobs
def test_numpy_tiling_reports_and_fingerprints():
    from repro.stencils.hdiff import hdiff_defs

    st = gtscript.stencil(backend="numpy", externals={"LIM": 0.01})(hdiff_defs)
    rec = next(r for r in st.pass_report if r["pass"] == "numpy_stage_tiling")
    assert rec["changed"] and rec["detail"]["tiled_multistages"] >= 1
    st_off = gtscript.stencil(
        backend="numpy", externals={"LIM": 0.01}, disable_passes=("numpy_stage_tiling",)
    )(hdiff_defs)
    rec_off = next(r for r in st_off.pass_report if r["pass"] == "numpy_stage_tiling")
    assert not rec_off["changed"] and rec_off["detail"]["enabled"] is False
    st_pin = gtscript.stencil(backend="numpy", externals={"LIM": 0.01}, tile=(16, 32))(hdiff_defs)
    assert len({st.fingerprint, st_off.fingerprint, st_pin.fingerprint}) == 3


# ---------------------------------------------------------------------------
# pass invariants: idempotence + pipeline fixpoint
# ---------------------------------------------------------------------------


def _invariant_impls():
    from repro.stencils.hdiff import hdiff_defs
    from repro.stencils.vadv import vadv_boundary_defs, vadv_defs, vadv_system_defs
    from repro.stencils.vintg import vintg_defs

    return [
        _analyze(hdiff_defs, externals={"LIM": 0.01}, name="hdiff"),
        _analyze(vadv_defs, name="vadv"),
        _analyze(vadv_system_defs, name="vadv_system"),
        _analyze(vadv_boundary_defs, name="vadv_boundary"),
        _analyze(vintg_defs, name="vintg"),
    ]


@pytest.mark.parametrize("pass_obj", passes.PIPELINE, ids=lambda p: p.name)
def test_each_pass_is_idempotent(pass_obj):
    for impl in _invariant_impls():
        ctx = passes.PassContext()
        once = pass_obj(impl, ctx)
        twice = pass_obj(once, ctx)
        assert twice == once, f"{pass_obj.name} is not idempotent on {impl.name}"


def test_full_pipeline_converges():
    """Re-running the whole pipeline reaches a fixpoint after at most one
    extra iteration: cross_stage_cse runs *after* reassociation, so the
    ``_cse`` reads it introduces only become operand-order canonical on the
    next round — after which nothing changes again."""
    for impl in _invariant_impls():
        opt, _ = passes.run_pipeline(impl)
        opt2, _ = passes.run_pipeline(opt)
        opt3, report3 = passes.run_pipeline(opt2)
        assert opt3 == opt2, f"pipeline does not converge on {impl.name}"
        assert not any(r["changed"] for r in report3)


def test_fingerprint_stable_iff_config_and_ir_stable():
    """Same definition + same pass config → same fingerprint (cache hit);
    any pass-config change → new fingerprint, even when the optimized IR
    happens to be unchanged (the fingerprint keys on configuration, which
    is what selects the generated module)."""
    from repro.stencils.vadv import vadv_boundary_defs

    a = gtscript.stencil(backend="numpy")(vadv_boundary_defs)
    b = gtscript.stencil(backend="numpy")(vadv_boundary_defs)
    assert a.fingerprint == b.fingerprint
    # constant_folding never fires on this stencil — the optimized IR is
    # identical with it disabled, but the fingerprint must still move
    impl = _analyze(vadv_boundary_defs, name="vadv_boundary")
    with_fold, _ = passes.run_pipeline(impl)
    without_fold, _ = passes.run_pipeline(impl, disable=("constant_folding",))
    assert with_fold == without_fold
    c = gtscript.stencil(backend="numpy", disable_passes=("constant_folding",))(vadv_boundary_defs)
    assert c.fingerprint != a.fingerprint


# ---------------------------------------------------------------------------
# fuzzer-found regressions
# ---------------------------------------------------------------------------


def test_parallel_interval_merging_respects_vertical_deps():
    """Regression (differential fuzzer): two PARALLEL intervals with
    identical bodies where a stage reads another stage's write one level up
    — merging the slabs would let the reader observe planes the original
    interval-by-interval schedule had not yet written."""

    def defs(phi: Field[np.float64], o: Field[np.float64]):
        with computation(PARALLEL):
            with interval(0, 1):
                t = phi * 2.0
                o = t[0, 0, 1] + phi
            with interval(1, None):
                t = phi * 2.0
                o = t[0, 0, 1] + phi

    impl0 = _analyze(defs)
    opt, _ = passes.run_pipeline(impl0)
    # the bodies are identical and adjacent, but must NOT merge
    assert sum(len(ms.intervals) for ms in opt.multi_stages) == 2

    x = _rand((NI, NJ, NK), seed=37)
    run_differential(
        defs,
        {"phi": (x, (0, 0, 0)), "o": (np.zeros_like(x), (0, 0, 0))},
        {},
        (NI, NJ, NK),
    )


def test_min_k_levels_accounts_for_boundary_interval_disjointness():
    """Regression: interval(0, 1) + interval(-1, None) are only disjoint for
    nk >= 2 — at nk == 1 both would execute the same level."""

    def defs(a: Field[np.float64], o: Field[np.float64]):
        with computation(FORWARD):
            with interval(0, 1):
                o = a * 2.0
            with interval(-1, None):
                o = a * 3.0

    impl = _analyze(defs)
    assert impl.min_k_levels == 2
    st = gtscript.stencil(backend="numpy")(defs)
    x = _rand((NI, NJ, 1), seed=38)
    a = storage.from_array(x)
    o = storage.zeros(x.shape)
    with pytest.raises(ValueError, match="vertical levels"):
        st(a, o, domain=(NI, NJ, 1))
