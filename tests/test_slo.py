"""SLO engine + autoscaler contract tests.

The load-bearing assertions:

* burn-rate math follows the multi-window recipe: burn = (bad/total in the
  window) / error budget, a rule fires only when BOTH its windows exceed the
  threshold, and evaluation is clock-injectable so timelines replay;
* breach *transitions* (not steady states) flip the ``serving_slo_breach``
  gauge, emit the ``slo.breach``/``slo.recovered`` trace instants, and invoke
  ``on_breach`` exactly once per edge;
* latency objectives accrue "bad" traffic from request deltas while the
  windowed p99 sits above target;
* the autoscaler's desired-replica rule is the documented one — queue term,
  capped latency term, breach term — immediate on the way up, damped on the
  way down;
* the acceptance chain: seeded chaos → deterministic error counts → the SAME
  breach timeline and the SAME ``/autoscale`` recommendation on two
  identical runs, with the breach dumping a flight bundle.
"""

import asyncio

import pytest

import repro  # noqa: F401
from repro.obs import flight as obs_flight
from repro.obs import metrics as obs_metrics
from repro.obs import slo as obs_slo
from repro.obs import trace as otrace
from repro.obs.slo import (
    AVAILABILITY,
    ERROR_RATE,
    LATENCY_P99,
    Autoscaler,
    BurnRule,
    Objective,
    SloEngine,
)
from repro.serving import FaultInjector, RequestSpec, ServingEngine, drive_engine
from repro.stencils.forecast import build_forecast_step, make_forecast_fields, request_state

DOM = (10, 8, 4)


# ---------------------------------------------------------------------------
# objectives: kinds, budgets
# ---------------------------------------------------------------------------


def test_objective_kinds_and_error_budgets():
    avail = Objective("a", "p", AVAILABILITY, 0.999)
    assert avail.error_budget() == pytest.approx(0.001)
    err = Objective("e", "p", ERROR_RATE, 0.002)
    assert err.error_budget() == pytest.approx(0.002)
    lat = Objective("l", "p", LATENCY_P99, 0.5)
    assert lat.error_budget() == obs_slo.LATENCY_BUDGET
    assert Objective("l2", "p", LATENCY_P99, 0.5, budget=0.05).error_budget() == 0.05
    with pytest.raises(ValueError, match="unknown objective kind"):
        Objective("x", "p", "p50_latency", 0.5)


def test_default_objectives_helper():
    objs = obs_slo.default_objectives("fc", availability=0.99, p99_s=0.25)
    assert [o.kind for o in objs] == [AVAILABILITY, LATENCY_P99]
    assert all(o.program == "fc" for o in objs)
    assert objs[0].target == 0.99 and objs[1].target == 0.25


# ---------------------------------------------------------------------------
# burn-rate math over the sample rings
# ---------------------------------------------------------------------------


def _availability_fixture(rules):
    reg = obs_metrics.MetricsRegistry()
    req = reg.counter("serving_requests_total", "", program="p")
    err = reg.counter("serving_errors_total", "", program="p", code="500")
    slo = SloEngine(reg, [Objective("avail", "p", AVAILABILITY, 0.999)], rules=rules)
    return reg, req, err, slo


def test_burn_rate_is_windowed_bad_fraction_over_budget():
    _, req, err, slo = _availability_fixture((BurnRule("fast", 10.0, 60.0, 14.4),))
    req.inc(100)
    slo.sample(now=0.0)
    req.inc(100)
    err.inc(2)  # 2% bad over the last window against a 0.1% budget → burn 20
    out = aggregate = slo.evaluate(now=10.0)
    (rule,) = aggregate["objectives"][0]["rules"]
    assert rule["short_burn"] == pytest.approx(20.0)
    assert rule["long_burn"] == pytest.approx(20.0)  # window > history → all of it
    assert rule["breaching"] and out["breaching"]


def test_rule_fires_only_when_both_windows_exceed():
    """A short spike over a long quiet stretch must NOT page (the long window
    vetoes); that is the whole point of pairing windows."""
    _, req, err, slo = _availability_fixture((BurnRule("fast", 10.0, 60.0, 14.4),))
    req.inc(100)
    slo.sample(now=0.0)
    req.inc(500)
    slo.sample(now=60.0)  # a long, clean stretch
    req.inc(10)
    err.inc(2)  # then a 20%-bad spike in the last 10 s
    out = slo.evaluate(now=70.0)
    (rule,) = out["objectives"][0]["rules"]
    assert rule["short_burn"] > 14.4
    assert rule["long_burn"] < 14.4
    assert not rule["breaching"] and not out["breaching"]


def test_no_traffic_burns_nothing():
    _, _, _, slo = _availability_fixture(obs_slo.DEFAULT_RULES)
    out = slo.evaluate(now=0.0)
    assert not out["breaching"]
    assert all(
        r["short_burn"] == 0.0 for o in out["objectives"] for r in o["rules"]
    )


def test_latency_objective_accrues_bad_while_p99_above_target():
    reg = obs_metrics.MetricsRegistry()
    req = reg.counter("serving_requests_total", "", program="p")
    hist = reg.histogram("serving_request_latency_seconds", "", program="p")
    slo = SloEngine(
        reg,
        [Objective("lat", "p", LATENCY_P99, 0.1)],
        rules=(BurnRule("fast", 10.0, 60.0, 14.4),),
    )
    slo.sample(now=0.0)
    req.inc(10)
    hist.observe(0.5)  # p99 = 0.5 ≫ 0.1 target: the 10 new requests are "bad"
    out = slo.evaluate(now=10.0)
    (rule,) = out["objectives"][0]["rules"]
    assert rule["short_burn"] == pytest.approx(10 / 10 / obs_slo.LATENCY_BUDGET)
    assert out["breaching"]
    assert slo.latency_pressure() == pytest.approx(5.0)
    # p99 back under target: new traffic stops accruing bad
    for _ in range(600):
        hist.observe(0.01)
    req.inc(1000)
    out = slo.evaluate(now=20.0)
    (rule,) = out["objectives"][0]["rules"]
    assert rule["short_burn"] < 14.4
    assert not out["breaching"]


# ---------------------------------------------------------------------------
# breach transitions: gauges, trace instants, on_breach
# ---------------------------------------------------------------------------


def test_breach_transitions_fire_once_per_edge():
    tracer = otrace.Tracer(enabled=True)
    reg = obs_metrics.MetricsRegistry()
    req = reg.counter("serving_requests_total", "", program="p")
    err = reg.counter("serving_errors_total", "", program="p", code="500")
    breaches = []
    slo = SloEngine(
        reg,
        [Objective("avail", "p", AVAILABILITY, 0.999)],
        rules=(BurnRule("fast", 10.0, 60.0, 14.4),),
        tracer=lambda: tracer,
        on_breach=breaches.append,
    )
    req.inc(100)
    slo.sample(now=0.0)
    req.inc(10)
    err.inc(5)
    slo.evaluate(now=10.0)  # edge: healthy → breaching
    slo.evaluate(now=11.0)  # steady breach — no second alert
    assert len(breaches) == 1 and breaches[0]["objective"] == "avail"
    gauge = reg.gauge("serving_slo_breach", objective="avail", program="p")
    assert gauge.value == 1.0
    burn = reg.gauge(
        "serving_slo_burn_rate", objective="avail", program="p", window="fast_short"
    )
    assert burn.value > 14.4
    # recovery edge
    req.inc(100_000)
    slo.evaluate(now=21.0)
    assert gauge.value == 0.0
    names = [s["name"] for s in tracer.snapshot()]
    assert names.count("slo.breach") == 1 and names.count("slo.recovered") == 1
    assert slo.status()["breaching"] is False


def test_on_breach_failure_does_not_break_evaluation():
    reg = obs_metrics.MetricsRegistry()
    req = reg.counter("serving_requests_total", "", program="p")
    err = reg.counter("serving_errors_total", "", program="p", code="500")

    def explode(_status):
        raise RuntimeError("pager down")

    slo = SloEngine(
        reg,
        [Objective("avail", "p", AVAILABILITY, 0.999)],
        rules=(BurnRule("fast", 10.0, 60.0, 1.0),),
        on_breach=explode,
    )
    slo.sample(now=0.0)
    req.inc(10)
    err.inc(10)
    out = slo.evaluate(now=10.0)  # alerting must never take serving down
    assert out["breaching"]


def test_add_objectives_after_construction():
    reg = obs_metrics.MetricsRegistry()
    slo = SloEngine(reg)
    assert slo.evaluate(now=0.0)["objectives"] == []
    slo.add(*obs_slo.default_objectives("fc"))
    out = slo.evaluate(now=1.0)
    assert [o["objective"] for o in out["objectives"]] == ["fc-availability", "fc-latency"]
    # re-adding by name replaces instead of duplicating
    slo.add(Objective("fc-latency", "fc", LATENCY_P99, 1.0))
    assert len(slo.objectives) == 2


# ---------------------------------------------------------------------------
# the autoscaler rule
# ---------------------------------------------------------------------------


def test_autoscaler_queue_term_scales_up_immediately():
    a = Autoscaler(replicas=1, max_replicas=8, target_utilization=0.75)
    # 24 member-slots of backlog against one replica of capacity 8:
    # utilization 3.0 → queue term 1 * 3 / 0.75 = 4 → desired 4, immediately
    rec = a.recommend(queue_depth=20, inflight=4, max_batch=8)
    assert rec["desired_replicas"] == 4
    assert rec["reason"] == "scale_up:queue"
    assert rec["inputs"]["utilization"] == pytest.approx(3.0)


def test_autoscaler_latency_term_is_capped():
    a = Autoscaler(replicas=2, max_replicas=16, latency_ratio_cap=4.0)
    rec = a.recommend(queue_depth=0, inflight=0, max_batch=8, latency_ratio=100.0)
    # one outlier cannot demand the moon: term = 2 * min(100, 4) = 8
    assert rec["desired_replicas"] == 8
    assert rec["reason"] == "scale_up:latency"
    # pressure ≤ 1 contributes no term at all
    rec = a.recommend(queue_depth=0, inflight=0, max_batch=8, latency_ratio=0.9)
    assert "latency" not in rec["terms"]


def test_autoscaler_breach_term_asks_for_one_more():
    a = Autoscaler(replicas=3, max_replicas=8)
    rec = a.recommend(queue_depth=0, inflight=0, max_batch=8, breaching=True)
    assert rec["desired_replicas"] == 4
    assert rec["reason"] == "scale_up:slo_breach"


def test_autoscaler_scale_down_is_damped_and_stepwise():
    a = Autoscaler(replicas=4, down_stable_evals=3)
    idle = dict(queue_depth=0, inflight=0, max_batch=8)
    assert a.recommend(**idle)["reason"] == "hold:damping(1/3)"
    assert a.recommend(**idle)["reason"] == "hold:damping(2/3)"
    rec = a.recommend(**idle)
    # three consecutive agreements, then exactly ONE step down
    assert rec["reason"] == "scale_down:stable"
    assert rec["desired_replicas"] == 3
    # any scale-up signal resets the streak
    a.recommend(**idle)
    a.recommend(queue_depth=50, inflight=0, max_batch=8)
    assert a.recommend(**idle)["reason"] == "hold:damping(1/3)"


def test_autoscaler_clamps_and_observe_replicas():
    a = Autoscaler(replicas=1, min_replicas=2, max_replicas=4)
    rec = a.recommend(queue_depth=1000, inflight=0, max_batch=1)
    assert rec["desired_replicas"] == 4  # clamped to max
    a.observe_replicas(4)
    assert a.replicas == 4
    rec = a.recommend(queue_depth=0, inflight=0, max_batch=1)
    assert rec["desired_replicas"] == 4  # damped hold, not a jump to min
    assert rec["replicas"] == 4


# ---------------------------------------------------------------------------
# the acceptance chain: seeded chaos → breach → alert → /autoscale, twice
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def step():
    return build_forecast_step("jax", DOM, name="slo_step")


@pytest.fixture(scope="module")
def templates():
    return make_forecast_fields("jax", DOM)


def _chain_once(step, templates, flight_dir):
    """One full run: poison-seeded faults produce a deterministic error
    count; the SLO engine is evaluated on an injected clock; the autoscale
    recommendation is read at the end.  Everything returned must be
    bit-identical across runs."""
    fields, scalars = templates
    tracer = otrace.Tracer(enabled=True, sample_rate=0.5)
    inj = FaultInjector(sites=("dispatch",), rate=0.0, seed=7, poison=("poison-1",))
    eng = ServingEngine(
        window_ms=25.0,
        retry_backoff_ms=1.0,
        faults=inj,
        tracer=tracer,
        slos=[Objective("avail", "slo_step", AVAILABILITY, 0.999)],
        flight=obs_flight.FlightRecorder(flight_dir),
    )
    eng.register(
        step,
        fields=fields,
        scalars=scalars,
        request_fields=("phi",),
        member_counts=(1, 2, 4),
        max_steps=100,
    )
    eng.slo.sample(now=0.0)
    specs = [
        RequestSpec(
            program="slo_step",
            fields={"phi": request_state(DOM, seed=i + 1)},
            steps=4,
            stream_every=2,
            request_id="poison-1" if i == 1 else f"ok-{i}",
        )
        for i in range(4)
    ]

    async def go():
        async with eng:
            return await drive_engine(eng, specs, keep_fields="none")

    report = asyncio.run(go())
    assert sum(not r.ok for r in report.results) == 1  # exactly the poison

    timeline = []
    for t in (10.0, 20.0):
        status = eng.slo.evaluate(now=t)
        timeline.append(
            (
                t,
                status["breaching"],
                [
                    (r["rule"], round(r["short_burn"], 6), round(r["long_burn"], 6),
                     r["breaching"])
                    for o in status["objectives"]
                    for r in o["rules"]
                ],
            )
        )
    rec = eng.autoscale_signal(now=30.0)
    breach_events = [s["name"] for s in tracer.snapshot() if s["name"] == "slo.breach"]
    return {
        "timeline": timeline,
        "desired": rec["desired_replicas"],
        "reason": rec["reason"],
        "breaching": rec["slo"]["breaching"],
        "breach_events": breach_events,
        "errors": eng.stats()["errors"],
        "last_bundle": eng.flight.last_bundle,
    }


def test_breach_to_autoscale_chain_is_deterministic(step, templates, tmp_path):
    a = _chain_once(step, templates, tmp_path / "a")
    b = _chain_once(step, templates, tmp_path / "b")

    # one poisoned request out of four burns 25% of traffic against a 0.1%
    # budget — far past every default rule — so the chain must fire...
    assert a["errors"] == 1
    assert a["timeline"][0][1] is True  # breaching at the first evaluation
    assert a["breaching"] is True
    assert a["reason"] == "scale_up:slo_breach"
    assert a["desired"] == 2
    assert a["breach_events"] == ["slo.breach"]  # one edge, one alert

    # ...and the breach dumped a flight bundle naming the objective
    assert a["last_bundle"] is not None
    bundle = obs_flight.load_bundle(a["last_bundle"])
    assert bundle["reason"] == "slo_breach:avail"
    assert bundle["extra"]["breach"]["objective"] == "avail"

    # the determinism contract: same breach timeline, same recommendation
    for key in ("timeline", "desired", "reason", "breaching", "breach_events", "errors"):
        assert a[key] == b[key], key


def test_engine_stats_and_autoscale_surface_slo(step, templates):
    fields, scalars = templates
    eng = ServingEngine(
        window_ms=25.0,
        slos=obs_slo.default_objectives("slo_step"),
    )
    eng.register(
        step,
        fields=fields,
        scalars=scalars,
        request_fields=("phi",),
        member_counts=(1, 2),
        max_steps=100,
    )
    st = eng.stats()
    assert st["slo"]["breaching"] is False
    assert {o["objective"] for o in st["slo"]["objectives"]} == {
        "slo_step-availability", "slo_step-latency",
    }
    rec = eng.autoscale_signal(now=0.0)
    assert rec["desired_replicas"] == 1
    assert rec["reason"].startswith("hold")
    assert rec["slo"]["breaching"] is False
    text = eng.metrics.to_prometheus()
    assert "# TYPE serving_slo_burn_rate gauge" in text
    assert 'serving_slo_breach{objective="slo_step-availability",program="slo_step"} 0.0' in text
