"""Deterministic stencil-program generator + IR↔JSON corpus serialization.

The backend-differential fuzzer (``test_dsl_property.py``) needs two things
from one generator so hypothesis-found failures can be frozen into CI
regressions verbatim:

* ``make_program(rng, name)`` — a seeded random ``ir.StencilDefinition``
  drawing from eight templates that deliberately cover the pass pipeline's
  attack surface: boundary vertical intervals (degenerate ``interval(0, 1)``
  / ``interval(-1, None)`` edges), FORWARD/BACKWARD recurrences with
  carry-free boundary inits (interval splitting's peel + its carry guard),
  commuted repeated subexpressions (reassociation → CSE), temporaries,
  horizontal offsets up to ±2, if/else (masked writes, zero-init temps),
  and horizontal read-back of written API outputs (the stage-tiling
  legality edge; pallas-incompatible by its static restriction — see
  ``pallas_compatible``).
* ``definition_to_json`` / ``definition_from_json`` — a stable corpus file
  format.  ``python tests/corpus_gen.py`` (re)generates the committed
  ``tests/corpus/prog_*.json`` set from fixed seeds; the corpus runs in CI
  *without* hypothesis installed.

Generated programs are legal by construction (the frontend/analysis checks
are respected, not searched): vertical reads stay inside each interval's
admissible range, sequential reads never look ahead of the sweep, and
temporaries are written before read in program order.  All templates except
``_t_api_feedback`` also respect the pallas written-API-horizontal-read
restriction; the runner gates pallas per program via ``pallas_compatible``.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import ir  # noqa: E402

CORPUS_DIR = Path(__file__).resolve().parent / "corpus"
N_PROGRAMS = 32  # 4 full cycles of the 8 templates
# domain the differential runner uses; generators keep min_k_levels <= NK
NI, NJ, NK = 6, 5, 7
HALO = 6  # ±2 offsets chained through two temporaries

START, END = ir.LevelMarker.START, ir.LevelMarker.END


# ---------------------------------------------------------------------------
# JSON serialization (corpus file format)
# ---------------------------------------------------------------------------


def _expr_to_json(e: ir.Expr):
    if isinstance(e, ir.Literal):
        return {"t": "lit", "v": e.value, "dtype": e.dtype}
    if isinstance(e, ir.ScalarRef):
        return {"t": "scalar", "name": e.name}
    if isinstance(e, ir.FieldAccess):
        return {"t": "fa", "name": e.name, "off": list(e.offset)}
    if isinstance(e, ir.UnaryOp):
        return {"t": "un", "op": e.op, "x": _expr_to_json(e.operand)}
    if isinstance(e, ir.BinOp):
        return {"t": "bin", "op": e.op, "l": _expr_to_json(e.left), "r": _expr_to_json(e.right)}
    if isinstance(e, ir.TernaryOp):
        return {
            "t": "tern",
            "c": _expr_to_json(e.cond),
            "a": _expr_to_json(e.true_expr),
            "b": _expr_to_json(e.false_expr),
        }
    if isinstance(e, ir.NativeCall):
        return {"t": "call", "f": e.func, "args": [_expr_to_json(a) for a in e.args]}
    raise TypeError(f"unserializable expr {type(e)}")


def _expr_from_json(d) -> ir.Expr:
    t = d["t"]
    if t == "lit":
        return ir.Literal(d["v"], d["dtype"])
    if t == "scalar":
        return ir.ScalarRef(d["name"])
    if t == "fa":
        return ir.FieldAccess(d["name"], tuple(d["off"]))
    if t == "un":
        return ir.UnaryOp(d["op"], _expr_from_json(d["x"]))
    if t == "bin":
        return ir.BinOp(d["op"], _expr_from_json(d["l"]), _expr_from_json(d["r"]))
    if t == "tern":
        return ir.TernaryOp(_expr_from_json(d["c"]), _expr_from_json(d["a"]), _expr_from_json(d["b"]))
    if t == "call":
        return ir.NativeCall(d["f"], tuple(_expr_from_json(a) for a in d["args"]))
    raise TypeError(f"unknown expr tag {t!r}")


def _stmt_to_json(s: ir.Stmt):
    if isinstance(s, ir.Assign):
        return {
            "t": "assign",
            "target": [s.target.name, list(s.target.offset)],
            "value": _expr_to_json(s.value),
        }
    if isinstance(s, ir.If):
        return {
            "t": "if",
            "cond": _expr_to_json(s.cond),
            "body": [_stmt_to_json(b) for b in s.body],
            "orelse": [_stmt_to_json(b) for b in s.orelse],
        }
    raise TypeError(f"unserializable stmt {type(s)}")


def _stmt_from_json(d) -> ir.Stmt:
    if d["t"] == "assign":
        name, off = d["target"]
        return ir.Assign(ir.FieldAccess(name, tuple(off)), _expr_from_json(d["value"]))
    if d["t"] == "if":
        return ir.If(
            _expr_from_json(d["cond"]),
            tuple(_stmt_from_json(b) for b in d["body"]),
            tuple(_stmt_from_json(b) for b in d["orelse"]),
        )
    raise TypeError(f"unknown stmt tag {d['t']!r}")


def _bound_to_json(b: ir.AxisBound):
    return [b.level.name, b.offset]


def _bound_from_json(d) -> ir.AxisBound:
    return ir.AxisBound(ir.LevelMarker[d[0]], d[1])


def definition_to_json(defn: ir.StencilDefinition) -> dict:
    return {
        "name": defn.name,
        "fields": [
            {"name": f.name, "dtype": f.dtype, "api": f.is_api} for f in defn.api_fields
        ],
        "scalars": [{"name": s.name, "dtype": s.dtype} for s in defn.scalars],
        "computations": [
            {
                "order": block.order.name,
                "intervals": [
                    {
                        "start": _bound_to_json(ib.interval.start),
                        "end": _bound_to_json(ib.interval.end),
                        "body": [_stmt_to_json(s) for s in ib.body],
                    }
                    for ib in block.intervals
                ],
            }
            for block in defn.computations
        ],
    }


def definition_from_json(d: dict) -> ir.StencilDefinition:
    return ir.StencilDefinition(
        name=d["name"],
        api_fields=tuple(
            ir.FieldDecl(f["name"], f["dtype"], ir.AXES_IJK, is_api=f["api"]) for f in d["fields"]
        ),
        scalars=tuple(ir.ScalarDecl(s["name"], s["dtype"]) for s in d["scalars"]),
        computations=tuple(
            ir.ComputationBlock(
                order=ir.IterationOrder[block["order"]],
                intervals=tuple(
                    ir.IntervalBlock(
                        ir.VerticalInterval(
                            _bound_from_json(ib["start"]), _bound_from_json(ib["end"])
                        ),
                        tuple(_stmt_from_json(s) for s in ib["body"]),
                    )
                    for ib in block["intervals"]
                ),
            )
            for block in d["computations"]
        ),
    )


def load_program(path: Path) -> ir.StencilDefinition:
    return definition_from_json(json.loads(Path(path).read_text()))


# ---------------------------------------------------------------------------
# Random expression generator
# ---------------------------------------------------------------------------


class Leaf:
    """A readable field with its admissible horizontal/vertical offsets."""

    def __init__(self, name: str, h: int = 2, dk: Sequence[int] = (0,)):
        self.name = name
        self.h = h  # max |di|, |dj|
        self.dk = tuple(dk)


def _offset(rng: np.random.Generator, leaf: Leaf) -> Tuple[int, int, int]:
    def h() -> int:
        return int(rng.integers(-leaf.h, leaf.h + 1)) if rng.random() < 0.4 else 0

    dk = int(leaf.dk[rng.integers(len(leaf.dk))])
    return (h(), h(), dk)


def _lit(rng: np.random.Generator) -> ir.Literal:
    return ir.Literal(round(float(rng.uniform(-2.0, 2.0)), 4), "float")


def gen_expr(rng: np.random.Generator, leaves: Sequence[Leaf], depth: int) -> ir.Expr:
    if depth <= 0 or rng.random() < 0.25:
        r = rng.random()
        if r < 0.6 and leaves:
            leaf = leaves[rng.integers(len(leaves))]
            return ir.FieldAccess(leaf.name, _offset(rng, leaf))
        if r < 0.85:
            return _lit(rng)
        return ir.ScalarRef("s")
    c = rng.random()
    a = gen_expr(rng, leaves, depth - 1)
    b = gen_expr(rng, leaves, depth - 1)
    if c < 0.45:
        return ir.BinOp(("+", "-", "*")[rng.integers(3)], a, b)
    if c < 0.60:
        return ir.NativeCall(("min", "max")[rng.integers(2)], (a, b))
    if c < 0.70:
        return ir.UnaryOp("-", a)
    if c < 0.78:
        return ir.NativeCall("abs", (a,))
    if c < 0.88:
        # division guarded away from zero (vectorized where-branches evaluate
        # both sides, so even masked divisions must stay finite)
        return ir.BinOp("/", a, ir.BinOp("+", ir.Literal(1.5, "float"), ir.NativeCall("abs", (b,))))
    return ir.TernaryOp(ir.BinOp(">", a, ir.Literal(0.0, "float")), b, _lit(rng))


def _assign(name: str, value: ir.Expr) -> ir.Assign:
    return ir.Assign(ir.FieldAccess(name, (0, 0, 0)), value)


def _maybe_if(rng: np.random.Generator, leaves: Sequence[Leaf], target: str) -> List[ir.Stmt]:
    """A conditional update of ``target`` (already defined) — masked-write
    machinery on the vectorized backends, real branches on debug."""
    cond = ir.BinOp(">", gen_expr(rng, leaves, 1), ir.Literal(0.0, "float"))
    body = (_assign(target, gen_expr(rng, leaves, 1)),)
    orelse = (_assign(target, gen_expr(rng, leaves, 1)),) if rng.random() < 0.5 else ()
    return [ir.If(cond, body, orelse)]


def _interval(start: ir.AxisBound, end: ir.AxisBound, body: Sequence[ir.Stmt]) -> ir.IntervalBlock:
    return ir.IntervalBlock(ir.VerticalInterval(start, end), tuple(body))


def _definition(name: str, computations, temps=("t1", "t2"), outputs=("out1",)) -> ir.StencilDefinition:
    fields = [ir.FieldDecl(n, "float64") for n in ("in1", "in2")]
    fields += [ir.FieldDecl(n, "float64") for n in outputs]
    fields += [ir.FieldDecl(n, "float64", is_api=False) for n in temps]
    return ir.StencilDefinition(
        name=name,
        api_fields=tuple(fields),
        scalars=(ir.ScalarDecl("s", "float64"),),
        computations=tuple(computations),
    )


# ---------------------------------------------------------------------------
# Program templates
# ---------------------------------------------------------------------------


def _t_parallel_chain(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """PARALLEL temp chain with horizontal offsets and a conditional update."""
    ins = [Leaf("in1"), Leaf("in2")]
    body: List[ir.Stmt] = [_assign("t1", gen_expr(rng, ins, 2))]
    body += [_assign("t2", gen_expr(rng, ins + [Leaf("t1")], 2))]
    body += [_assign("out1", gen_expr(rng, [Leaf("t1"), Leaf("t2"), Leaf("in2")], 1))]
    if rng.random() < 0.7:
        body += _maybe_if(rng, [Leaf("t1", h=1), Leaf("in1", h=1)], "out1")
    comp = ir.ComputationBlock(
        ir.IterationOrder.PARALLEL, (_interval(ir.AxisBound(START), ir.AxisBound(END), body),)
    )
    return _definition(name, [comp])


def _t_parallel_boundary(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """PARALLEL with specialized boundary intervals; the interior reads up and
    down one level, boundary-only writes hit ``out2``."""
    ins_mid = [Leaf("in1", dk=(-1, 0, 1)), Leaf("in2")]
    bottom = [
        _assign("t1", gen_expr(rng, [Leaf("in1", dk=(0, 1, 2))], 1)),
        _assign("out1", gen_expr(rng, [Leaf("t1"), Leaf("in2")], 1)),
        _assign("out2", gen_expr(rng, [Leaf("in1", dk=(0, 1))], 1)),
    ]
    interior = [
        _assign("t1", gen_expr(rng, ins_mid, 2)),
        _assign("out1", gen_expr(rng, [Leaf("t1"), Leaf("in2")], 1)),
    ]
    top = [
        _assign("t1", gen_expr(rng, [Leaf("in1", dk=(-2, -1, 0))], 1)),
        _assign("out1", gen_expr(rng, [Leaf("t1"), Leaf("in2")], 1)),
        _assign("out2", gen_expr(rng, [Leaf("in1", dk=(-1, 0))], 1)),
    ]
    comp = ir.ComputationBlock(
        ir.IterationOrder.PARALLEL,
        (
            _interval(ir.AxisBound(START, 0), ir.AxisBound(START, 1), bottom),
            _interval(ir.AxisBound(START, 1), ir.AxisBound(END, -1), interior),
            _interval(ir.AxisBound(END, -1), ir.AxisBound(END, 0), top),
        ),
    )
    return _definition(name, [comp], temps=("t1",), outputs=("out1", "out2"))


def _t_forward(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """FORWARD recurrence: boundary init (carry-free → peelable), interior
    carrying ``out1[0, 0, -1]`` and a sweep-local temp read one plane back."""
    ins = [Leaf("in1"), Leaf("in2", h=1)]
    init = [
        _assign("t1", gen_expr(rng, ins, 1)),
        _assign("out1", gen_expr(rng, [Leaf("t1"), Leaf("in1", h=1)], 1)),
    ]
    w = round(float(rng.uniform(-0.9, 0.9)), 3)
    step = [
        _assign("t1", gen_expr(rng, ins, 1)),
        _assign(
            "out1",
            ir.BinOp(
                "+",
                gen_expr(rng, [Leaf("t1", dk=(0, -1)), Leaf("in2", h=1)], 1),
                ir.BinOp("*", ir.Literal(w, "float"), ir.FieldAccess("out1", (0, 0, -1))),
            ),
        ),
    ]
    intervals = [
        _interval(ir.AxisBound(START, 0), ir.AxisBound(START, 1), init),
        _interval(ir.AxisBound(START, 1), ir.AxisBound(END, 0), step),
    ]
    comp = ir.ComputationBlock(ir.IterationOrder.FORWARD, tuple(intervals))
    return _definition(name, [comp], temps=("t1",))


def _t_backward(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """BACKWARD recurrence with a carry-free top closure writing ``out2``."""
    ins = [Leaf("in1"), Leaf("in2", h=1)]
    top = [
        _assign("out1", gen_expr(rng, ins, 1)),
        _assign("out2", gen_expr(rng, [Leaf("in1", dk=(-1, 0))], 1)),
    ]
    w = round(float(rng.uniform(-0.9, 0.9)), 3)
    step = [
        _assign(
            "out1",
            ir.BinOp(
                "+",
                gen_expr(rng, ins, 1),
                ir.BinOp("*", ir.Literal(w, "float"), ir.FieldAccess("out1", (0, 0, 1))),
            ),
        ),
    ]
    comp = ir.ComputationBlock(
        ir.IterationOrder.BACKWARD,
        (
            _interval(ir.AxisBound(START, 0), ir.AxisBound(END, -1), step),
            _interval(ir.AxisBound(END, -1), ir.AxisBound(END, 0), top),
        ),
    )
    return _definition(name, [comp], temps=(), outputs=("out1", "out2"))


def _t_mixed(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """PARALLEL assembly (with a deliberately commuted repeated product, the
    reassociation → CSE motif) feeding a FORWARD sweep and a BACKWARD pass."""
    assembly = [
        _assign("t1", ir.BinOp("+", ir.BinOp("*", ir.FieldAccess("in1", (0, 0, 0)), ir.FieldAccess("in2", (0, 0, 0))), gen_expr(rng, [Leaf("in1")], 1))),
        _assign("t2", ir.BinOp("+", ir.BinOp("*", ir.FieldAccess("in2", (0, 0, 0)), ir.FieldAccess("in1", (0, 0, 0))), gen_expr(rng, [Leaf("in2", h=1)], 1))),
    ]
    comp0 = ir.ComputationBlock(
        ir.IterationOrder.PARALLEL,
        (_interval(ir.AxisBound(START), ir.AxisBound(END), assembly),),
    )
    w = round(float(rng.uniform(-0.8, 0.8)), 3)
    fwd = ir.ComputationBlock(
        ir.IterationOrder.FORWARD,
        (
            _interval(
                ir.AxisBound(START, 0),
                ir.AxisBound(START, 1),
                [_assign("out1", gen_expr(rng, [Leaf("t1"), Leaf("t2")], 1))],
            ),
            _interval(
                ir.AxisBound(START, 1),
                ir.AxisBound(END, 0),
                [
                    _assign(
                        "out1",
                        ir.BinOp(
                            "+",
                            gen_expr(rng, [Leaf("t1"), Leaf("t2")], 1),
                            ir.BinOp(
                                "*", ir.Literal(w, "float"), ir.FieldAccess("out1", (0, 0, -1))
                            ),
                        ),
                    )
                ],
            ),
        ),
    )
    bwd = ir.ComputationBlock(
        ir.IterationOrder.BACKWARD,
        (
            _interval(
                ir.AxisBound(START, 0),
                ir.AxisBound(END, -1),
                [
                    _assign(
                        "out2",
                        ir.BinOp(
                            "+",
                            gen_expr(rng, [Leaf("t1")], 1),
                            ir.BinOp(
                                "*", ir.Literal(w, "float"), ir.FieldAccess("out2", (0, 0, 1))
                            ),
                        ),
                    )
                ],
            ),
            _interval(
                ir.AxisBound(END, -1),
                ir.AxisBound(END, 0),
                [_assign("out2", gen_expr(rng, [Leaf("t1"), Leaf("t2")], 1))],
            ),
        ),
    )
    return _definition(name, [comp0, fwd, bwd], outputs=("out1", "out2"))


def _t_carry_free_sweep(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """A FORWARD computation with no actual recurrence (reads inputs only) —
    interval splitting converts it to PARALLEL outright."""
    intervals = [
        _interval(
            ir.AxisBound(START, 0),
            ir.AxisBound(START, 1),
            [_assign("out1", gen_expr(rng, [Leaf("in1", dk=(0, 1)), Leaf("in2")], 2))],
        ),
        _interval(
            ir.AxisBound(START, 1),
            ir.AxisBound(END, 0),
            [_assign("out1", gen_expr(rng, [Leaf("in1", dk=(-1, 0)), Leaf("in2")], 2))],
        ),
    ]
    comp = ir.ComputationBlock(ir.IterationOrder.FORWARD, tuple(intervals))
    return _definition(name, [comp], temps=())


def _t_conditional(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """Zero-initialized temporary (conditional first write) + masked updates."""
    ins = [Leaf("in1", h=1), Leaf("in2", h=1)]
    body: List[ir.Stmt] = [
        ir.If(
            ir.BinOp(">", gen_expr(rng, ins, 1), ir.Literal(0.0, "float")),
            (_assign("t1", gen_expr(rng, ins, 1)),),
        ),
        _assign("out1", ir.BinOp("+", ir.FieldAccess("t1", (0, 0, 0)), gen_expr(rng, ins, 1))),
    ]
    body += _maybe_if(rng, [Leaf("in2", h=1)], "out1")
    comp = ir.ComputationBlock(
        ir.IterationOrder.PARALLEL, (_interval(ir.AxisBound(START), ir.AxisBound(END), body),)
    )
    return _definition(name, [comp], temps=("t1",))


def _t_api_feedback(rng: np.random.Generator, name: str) -> ir.StencilDefinition:
    """Writes an API output, then reads it back at horizontal offsets through
    a temp chain — legal on debug/numpy/jax (pallas statically rejects
    written-API horizontal reads, see ``pallas_compatible``).  This is the
    class ``numpy_stage_tiling`` must refuse to tile: API fields are written
    with zero compute extent, so an offset/extended read would reach into a
    neighboring tile's not-yet-written data (the miscompile the review of
    this fuzzer caught)."""
    ins = [Leaf("in1"), Leaf("in2", h=1)]
    # the offset read-back of out1 is the load-bearing access — guaranteed,
    # not left to the expression draw
    feedback = ir.BinOp(
        "+",
        ir.FieldAccess("out1", (1, 0, 0)),
        ir.FieldAccess("out1", (-1, int(rng.integers(-1, 2)), 0)),
    )
    body: List[ir.Stmt] = [
        _assign("out1", gen_expr(rng, ins, 2)),
        _assign("t1", ir.BinOp("+", feedback, gen_expr(rng, [Leaf("out1", h=1), Leaf("in1", h=1)], 1))),
        # the t1 read is guaranteed too: a draw that ignored t1 would prune
        # the whole feedback chain as dead and blind the case
        _assign(
            "out2",
            ir.BinOp(
                "+",
                ir.FieldAccess("t1", (int(rng.integers(-1, 2)), 1, 0)),
                gen_expr(rng, [Leaf("t1", h=1), Leaf("out1", h=0)], 1),
            ),
        ),
    ]
    comp = ir.ComputationBlock(
        ir.IterationOrder.PARALLEL, (_interval(ir.AxisBound(START), ir.AxisBound(END), body),)
    )
    return _definition(name, [comp], temps=("t1",), outputs=("out1", "out2"))


TEMPLATES = (
    _t_parallel_chain,
    _t_parallel_boundary,
    _t_forward,
    _t_backward,
    _t_mixed,
    _t_carry_free_sweep,
    _t_conditional,
    _t_api_feedback,
)


def pallas_compatible(defn: ir.StencilDefinition) -> bool:
    """The pallas backend statically rejects written API fields read at
    nonzero horizontal offsets — the differential runner skips pallas for
    corpus programs exercising that (numpy/jax/debug-only) pattern."""
    api = {f.name for f in defn.api_fields if f.is_api}
    written: set = set()
    reads: Dict[str, set] = {}
    for block in defn.computations:
        for ib in block.intervals:
            for s in ib.body:
                written.update(w for w in ir.stmt_writes(s) if w in api)
                for rname, off in ir.stmt_reads(s):
                    reads.setdefault(rname, set()).add(off)
    return not any(
        (off[0], off[1]) != (0, 0) for n in written for off in reads.get(n, ())
    )


def make_program(rng: np.random.Generator, name: str, template: Optional[int] = None) -> ir.StencilDefinition:
    idx = int(rng.integers(len(TEMPLATES))) if template is None else template % len(TEMPLATES)
    return TEMPLATES[idx](rng, name)


def make_corpus(n: int = N_PROGRAMS) -> Dict[str, ir.StencilDefinition]:
    """The deterministic corpus: ``n`` programs cycling the templates with
    fixed seeds — regenerating yields byte-identical JSON."""
    out: Dict[str, ir.StencilDefinition] = {}
    for i in range(n):
        name = f"prog_{i:02d}"
        rng = np.random.default_rng(1000 + i)
        out[name] = make_program(rng, name, template=i)
    return out


def main() -> None:
    CORPUS_DIR.mkdir(exist_ok=True)
    for name, defn in make_corpus().items():
        path = CORPUS_DIR / f"{name}.json"
        path.write_text(json.dumps(definition_to_json(defn), indent=1) + "\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
