"""Compiled-program tests: fusion, bit-identity vs the eager path, rotation,
iterate, caching, and the generated orchestrator artifact."""

import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import gtscript, storage
from repro.core.gtscript import Field, PARALLEL, computation, interval
from repro.program import ProgramError, program
from repro.stencils.library import laplacian
from repro.stencils.vadv import vadv_defs


# ---------------------------------------------------------------------------
# the miniature climate step (examples/climate_model.py motif)
# ---------------------------------------------------------------------------


def diffuse_defs(phi: Field[np.float64], out: Field[np.float64], *, alpha: np.float64):
    with computation(PARALLEL), interval(...):
        out = phi + alpha * laplacian(phi)


def advect_defs(
    phi: Field[np.float64],
    u: Field[np.float64],
    v: Field[np.float64],
    adv: Field[np.float64],
    *,
    dx: np.float64,
    dy: np.float64,
):
    with computation(PARALLEL), interval(...):
        fx = (phi[0, 0, 0] - phi[-1, 0, 0]) / dx if u > 0.0 else (phi[1, 0, 0] - phi[0, 0, 0]) / dx
        fy = (phi[0, 0, 0] - phi[0, -1, 0]) / dy if v > 0.0 else (phi[0, 1, 0] - phi[0, 0, 0]) / dy
        adv = -(u * fx + v * fy)


def wsystem_defs(
    w: Field[np.float64],
    phi: Field[np.float64],
    a: Field[np.float64],
    b: Field[np.float64],
    c: Field[np.float64],
    d: Field[np.float64],
    *,
    dtdz: np.float64,
):
    with computation(PARALLEL):
        with interval(1, -1):
            gcv = 0.25 * (w[0, 0, 1] + w[0, 0, 0]) * dtdz
            gcm = 0.25 * (w[0, 0, 0] + w[0, 0, -1]) * dtdz
            a = -gcm
            c = gcv
            b = 1.0 + gcv - gcm
            d = phi[0, 0, 0] - gcv * (phi[0, 0, 1] - phi[0, 0, 0]) + gcm * (phi[0, 0, 0] - phi[0, 0, -1])
        with interval(0, 1):
            gcv = 0.25 * (w[0, 0, 1] + w[0, 0, 0]) * dtdz
            a = 0.0
            c = gcv
            b = 1.0 + gcv
            d = phi[0, 0, 0] - gcv * (phi[0, 0, 1] - phi[0, 0, 0])
        with interval(-1, None):
            gcm = 0.25 * (w[0, 0, 0] + w[0, 0, -1]) * dtdz
            a = -gcm
            c = 0.0
            b = 1.0 - gcm
            d = phi[0, 0, 0] + gcm * (phi[0, 0, 0] - phi[0, 0, -1])


def euler_defs(phi: Field[np.float64], adv: Field[np.float64], out: Field[np.float64], *, dt: np.float64):
    with computation(PARALLEL), interval(...):
        out = phi + dt * adv


H = 3
NI = NJ = 16
NK = 8
DOM = (NI, NJ, NK)
SHAPE = (NI + 2 * H, NJ + 2 * H, NK)
NT = 10
SCALARS = dict(
    dt=np.float64(0.1),
    dx=np.float64(1.0),
    dy=np.float64(1.0),
    dtdz=np.float64(0.1),
    alpha=np.float64(0.05),
)
FIELD_NAMES = ("phi", "u", "v", "w", "adv", "phi_star", "phi_h", "a", "b", "c", "d", "phi_new")


def _initial_arrays():
    rng = np.random.default_rng(0)
    xx, yy = np.meshgrid(np.linspace(-2, 2, SHAPE[0]), np.linspace(-2, 2, SHAPE[1]), indexing="ij")
    blob = np.exp(-(xx**2 + yy**2))[:, :, None] * np.ones((1, 1, NK))
    return {
        "phi": blob,
        "u": np.full(SHAPE, 0.8),
        "v": np.full(SHAPE, -0.4),
        "w": 0.2 * rng.random(SHAPE),
    }


def _stores(backend):
    init = _initial_arrays()
    out = {}
    for n in FIELD_NAMES:
        if n in init:
            out[n] = storage.from_array(np.array(init[n]), backend=backend, default_origin=(H, H, 0))
        else:
            out[n] = storage.zeros(SHAPE, backend=backend, default_origin=(H, H, 0))
    return out


def _build_all(backend):
    build = gtscript.stencil(backend=backend)
    return (
        build(advect_defs),
        build(euler_defs),
        build(diffuse_defs),
        build(wsystem_defs),
        build(vadv_defs),
    )


def _eager_steps(backend, nt):
    advect, euler, diffuse, wsys, vsolve = _build_all(backend)
    s = _stores(backend)
    for _ in range(nt):
        advect(s["phi"], s["u"], s["v"], s["adv"], dx=SCALARS["dx"], dy=SCALARS["dy"], domain=DOM)
        euler(s["phi"], s["adv"], s["phi_star"], dt=SCALARS["dt"], domain=DOM)
        diffuse(s["phi_star"], s["phi_h"], alpha=SCALARS["alpha"], domain=DOM)
        wsys(s["w"], s["phi_h"], s["a"], s["b"], s["c"], s["d"], dtdz=SCALARS["dtdz"], domain=DOM)
        vsolve(s["a"], s["b"], s["c"], s["d"], s["phi_new"], domain=DOM)
        s["phi"], s["phi_new"] = s["phi_new"], s["phi"]
    return np.asarray(s["phi"]).copy()


def _make_program(backend):
    advect, euler, diffuse, wsys, vsolve = _build_all(backend)

    @program(backend=backend, name=f"climate_step_{backend}")
    def climate_step(phi, u, v, w, adv, phi_star, phi_h, a, b, c, d, phi_new, *, dt, dx, dy, dtdz, alpha):
        advect(phi, u, v, adv, dx=dx, dy=dy, domain=DOM)
        euler(phi, adv, phi_star, dt=dt, domain=DOM)
        diffuse(phi_star, phi_h, alpha=alpha, domain=DOM)
        wsys(w, phi_h, a, b, c, d, dtdz=dtdz, domain=DOM)
        vsolve(a, b, c, d, phi_new, domain=DOM)
        return {"phi": phi_new, "phi_new": phi}

    return climate_step


def _program_steps(backend, nt, exec_info=None):
    step = _make_program(backend)
    p = _stores(backend)
    for t in range(nt):
        step(*[p[n] for n in FIELD_NAMES], **SCALARS, exec_info=exec_info if t == 0 else None)
    return np.asarray(p["phi"]).copy(), step, p


# ---------------------------------------------------------------------------
# bit-identity vs the eager per-stencil path
# ---------------------------------------------------------------------------


def test_program_bit_identical_to_eager_debug_oracle_10_steps():
    eager = _eager_steps("debug", NT)
    prog, _, _ = _program_steps("debug", NT)
    assert np.array_equal(prog, eager)  # bit-identical, float64


def test_program_bit_identical_to_eager_numpy_10_steps():
    eager = _eager_steps("numpy", NT)
    prog, _, _ = _program_steps("numpy", NT)
    assert np.array_equal(prog, eager)


def test_program_matches_eager_jax_10_steps():
    eager = _eager_steps("jax", NT)
    info = {}
    prog, _, _ = _program_steps("jax", NT, exec_info=info)
    # one fused jit vs five jits: XLA instruction selection may differ by
    # rounding (ulp-level); the debug-oracle comparison above is the bit gate
    assert np.abs(prog - eager).max() < 1e-12
    # and the jax program agrees bit-for-bit with the numpy program
    assert np.abs(prog - _program_steps("numpy", NT)[0]).max() < 1e-12


def test_program_fusion_and_eliminated_temporaries():
    info = {}
    _program_steps("numpy", 1, exec_info=info)
    rep = info["program_report"]
    assert rep["nodes"] == 5
    assert rep["groups"] == 1
    assert rep["fused_stencils"] >= 1
    # adv and the tridiagonal coefficients never materialize at program level
    assert set(rep["eliminated_temporaries"]) == {"adv", "a", "b", "c", "d"}
    assert rep["rotation"] == {"phi_new": "phi"}
    # PARALLEL stages all fused into one multi-stage; FORWARD/BACKWARD remain,
    # and interval_splitting peels the Thomas solver's carry-free boundary
    # interval(s) into PARALLEL multi-stages of their own
    assert rep["group_multi_stages"] == [4]
    assert [t["group"] for t in rep["node_timings"]] == [0]


def test_program_groups_ride_pass_config():
    """backend_opts thread into the fused groups' builds: fused programs
    split/tile exactly like standalone stencils, and disabling a pass at
    program scope disables it inside every merged group."""
    advect, euler, diffuse, wsys, vsolve = _build_all("numpy")

    def make(**opts):
        @program(backend="numpy", name=f"climate_step_cfg_{sorted(opts.items())!r}", **opts)
        def climate_step(phi, u, v, w, adv, phi_star, phi_h, a, b, c, d, phi_new, *, dt, dx, dy, dtdz, alpha):
            advect(phi, u, v, adv, dx=dx, dy=dy, domain=DOM)
            euler(phi, adv, phi_star, dt=dt, domain=DOM)
            diffuse(phi_star, phi_h, alpha=alpha, domain=DOM)
            wsys(w, phi_h, a, b, c, d, dtdz=dtdz, domain=DOM)
            vsolve(a, b, c, d, phi_new, domain=DOM)
            return {"phi": phi_new, "phi_new": phi}

        p = _stores("numpy")
        info = {}
        climate_step(*[p[n] for n in FIELD_NAMES], **SCALARS, exec_info=info)
        return info["program_report"], np.asarray(p["phi"]).copy()

    rep_default, phi_default = make()
    rep_nosplit, phi_nosplit = make(disable_passes=("interval_splitting",))
    # the peel happens inside the merged group (4 multi-stages), and turning
    # the pass off at program scope removes it (back to 3)
    assert rep_default["group_multi_stages"] == [4]
    assert rep_nosplit["group_multi_stages"] == [3]
    np.testing.assert_array_equal(phi_default, phi_nosplit)


def test_non_output_written_fields_persist_on_all_backends():
    """Writes to program fields the return binding does not name must still
    land in the caller's storages — matching the eager per-stencil path —
    on the functional backends too, not just the mutating ones."""
    for backend in ("numpy", "jax"):
        step = _make_program(backend)
        p = _stores(backend)
        step(*[p[n] for n in FIELD_NAMES], **SCALARS)
        s = _stores(backend)
        advect, euler, diffuse, wsys, vsolve = _build_all(backend)
        advect(s["phi"], s["u"], s["v"], s["adv"], dx=SCALARS["dx"], dy=SCALARS["dy"], domain=DOM)
        euler(s["phi"], s["adv"], s["phi_star"], dt=SCALARS["dt"], domain=DOM)
        diffuse(s["phi_star"], s["phi_h"], alpha=SCALARS["alpha"], domain=DOM)
        # phi_star / phi_h are written inside the program but not returned
        for name in ("phi_star", "phi_h"):
            assert np.abs(np.asarray(p[name]) - np.asarray(s[name])).max() < 1e-12, (backend, name)
            assert float(np.abs(np.asarray(p[name])).max()) > 0.0


def test_compiled_cache_is_keyword_order_insensitive():
    step = _make_program("numpy")
    p = _stores("numpy")
    step(**{n: p[n] for n in FIELD_NAMES}, **SCALARS)
    step(**{n: p[n] for n in reversed(FIELD_NAMES)}, **SCALARS)
    assert len(step._cache) == 1  # no spurious retrace/recompile


def test_stencil_apply_accepts_superset_fields_dict():
    diffuse = _build_all("numpy")[2]
    s = _stores("numpy")
    updates = diffuse.apply(
        {"phi": s["phi"], "out": s["phi_h"], "unrelated": s["w"]},
        {"alpha": SCALARS["alpha"]},
        domain=DOM,
    )
    assert set(updates) == {"out"}


def test_program_rotation_rebinds_storages():
    step = _make_program("numpy")
    p = _stores("numpy")
    before_phi, before_new = p["phi"].data, p["phi_new"].data
    step(*[p[n] for n in FIELD_NAMES], **SCALARS)
    # ping-pong: the arrays swapped owners, no copy was made
    assert p["phi"].data is before_new
    assert p["phi_new"].data is before_phi


# ---------------------------------------------------------------------------
# iterate: n steps in one dispatch
# ---------------------------------------------------------------------------


def test_iterate_matches_stepwise():
    stepwise, _, _ = _program_steps("jax", NT)
    step = _make_program("jax")
    p = _stores("jax")
    step.iterate(NT, *[p[n] for n in FIELD_NAMES], **SCALARS)
    assert np.abs(np.asarray(p["phi"]) - stepwise).max() < 1e-12


def test_iterate_requires_rotation_closed_outputs():
    sc = gtscript.stencil(backend="jax")(euler_defs)

    @program(backend="jax", name="t_noniter")
    def step(phi, adv, out, *, dt):
        sc(phi, adv, out, dt=dt, domain=DOM)
        return {"result": out}  # not a program field name

    p = _stores("jax")
    with pytest.raises(ProgramError, match="cannot iterate"):
        step.iterate(3, p["phi"], p["adv"], p["phi_new"], dt=SCALARS["dt"])


def test_iterate_rejected_on_numpy_backend():
    step = _make_program("numpy")
    p = _stores("numpy")
    with pytest.raises(ProgramError, match="iterate\\(\\) requires"):
        step.iterate(2, *[p[n] for n in FIELD_NAMES], **SCALARS)


# ---------------------------------------------------------------------------
# caching & the generated artifact
# ---------------------------------------------------------------------------


def test_compiled_program_cached_per_geometry():
    step = _make_program("numpy")
    p = _stores("numpy")
    step(*[p[n] for n in FIELD_NAMES], **SCALARS)
    assert len(step._cache) == 1
    cp = next(iter(step._cache.values()))
    step(*[p[n] for n in FIELD_NAMES], **SCALARS)
    assert next(iter(step._cache.values())) is cp  # no retrace, no rebuild
    assert len(cp.fingerprint) == 16


def test_generated_orchestrator_is_inspectable():
    step = _make_program("jax")
    p = _stores("jax")
    step(*[p[n] for n in FIELD_NAMES], **SCALARS)
    cp = next(iter(step._cache.values()))
    src = cp.generated_source
    assert "Auto-generated by repro.program" in src
    assert "group_runs[0]" in src
    # the rotation is a dict rewiring in the artifact, not a copy
    assert "'phi': vals['phi_new']" in src
    assert "'phi_new': vals['phi']" in src
    # group modules are real cached stencil modules
    assert cp.group_objects[0].generated_source.startswith('"""Auto-generated')


def test_program_runs_on_pallas_backend():
    eager = _eager_steps("numpy", 2)
    prog, _, _ = _program_steps("pallas", 2)
    assert np.abs(prog - eager).max() < 1e-12


def test_different_domains_split_groups_and_stay_exact():
    sc = gtscript.stencil(backend="numpy")(euler_defs)
    small = (NI // 2, NJ // 2, NK)

    @program(backend="numpy", name="t_twodoms")
    def step(phi, adv, phi_star, phi_new, *, dt):
        sc(phi, adv, phi_star, dt=dt, domain=DOM)
        sc(phi_star, adv, phi_new, dt=dt, domain=small)
        return {"phi_new": phi_new, "phi_star": phi_star}

    p = _stores("numpy")
    info = {}
    step(p["phi"], p["adv"], p["phi_star"], p["phi_new"], dt=SCALARS["dt"], exec_info=info)
    assert info["program_report"]["groups"] == 2

    s = _stores("numpy")
    sc(s["phi"], s["adv"], s["phi_star"], dt=SCALARS["dt"], domain=DOM)
    sc(s["phi_star"], s["adv"], s["phi_new"], dt=SCALARS["dt"], domain=small)
    assert np.array_equal(np.asarray(p["phi_new"]), np.asarray(s["phi_new"]))
    assert np.array_equal(np.asarray(p["phi_star"]), np.asarray(s["phi_star"]))
