"""Distributed program: fused sharded step vs the eager per-stencil chain.

jax fixes the device count at first init, so multi-device tests run in a
subprocess with ``--xla_force_host_platform_device_count=8`` (same harness
as ``test_distributed.py``).
"""

import json
import os
import subprocess
import sys
import tempfile
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run_subprocess(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, {src!r})
        import repro
        import jax, jax.numpy as jnp
        import numpy as np
        """
    ).format(src=SRC) + textwrap.dedent(body)
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    with tempfile.NamedTemporaryFile("w", suffix=".py", delete=False) as f:
        f.write(script)
        path = f.name
    try:
        res = subprocess.run([sys.executable, path], capture_output=True, text=True, timeout=600, env=env)
    finally:
        os.unlink(path)
    if res.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{res.stderr[-3000:]}")
    return json.loads(res.stdout.strip().splitlines()[-1])


_STEP_DEFS = """
from repro.core import gtscript
from repro.core.gtscript import Field, PARALLEL, computation, interval
from repro.program import program
from repro.stencils.library import laplacian
from repro.stencils.distributed import DistributedStencil

def diffuse_defs(phi: Field[np.float64], out: Field[np.float64], *, alpha: np.float64):
    with computation(PARALLEL), interval(...):
        out = phi + alpha * laplacian(phi)

def advect_defs(phi: Field[np.float64], u: Field[np.float64], v: Field[np.float64],
                adv: Field[np.float64], *, dx: np.float64, dy: np.float64):
    with computation(PARALLEL), interval(...):
        fx = (phi[0, 0, 0] - phi[-1, 0, 0]) / dx if u > 0.0 else (phi[1, 0, 0] - phi[0, 0, 0]) / dx
        fy = (phi[0, 0, 0] - phi[0, -1, 0]) / dy if v > 0.0 else (phi[0, 1, 0] - phi[0, 0, 0]) / dy
        adv = -(u * fx + v * fy)

def euler_defs(phi: Field[np.float64], adv: Field[np.float64], out: Field[np.float64],
               *, dt: np.float64):
    with computation(PARALLEL), interval(...):
        out = phi + dt * adv

be = "jax"
build = gtscript.stencil(backend=be)
advect, euler, diffuse = build(advect_defs), build(euler_defs), build(diffuse_defs)

mesh = jax.make_mesh((4, 2), ("data", "model"))
NI, NJ, NK, NT = 32, 16, 6, 10
rng = np.random.default_rng(0)
phi0 = rng.normal(size=(NI, NJ, NK))
u0 = np.full((NI, NJ, NK), 0.8)
v0 = np.full((NI, NJ, NK), -0.4)
sc = {"dx": np.float64(1.0), "dy": np.float64(1.0), "dt": np.float64(0.1),
      "alpha": np.float64(0.05)}

def fresh_fields():
    return {"phi": jnp.asarray(phi0), "u": jnp.asarray(u0), "v": jnp.asarray(v0),
            "adv": jnp.zeros((NI, NJ, NK)), "phi_star": jnp.zeros((NI, NJ, NK)),
            "phi_new": jnp.zeros((NI, NJ, NK))}

@program(backend=be, name="dist_climate")
def step(phi, u, v, adv, phi_star, phi_new, *, dx, dy, dt, alpha):
    advect(phi, u, v, adv, dx=dx, dy=dy)
    euler(phi, adv, phi_star, dt=dt)
    diffuse(phi_star, phi_new, alpha=alpha)
    return {"phi": phi_new, "phi_new": phi}
"""


def test_distributed_program_bit_identical_to_eager_chain():
    out = _run_subprocess(
        _STEP_DEFS
        + textwrap.dedent("""
        # ---- eager chain: one DistributedStencil call per stencil per step
        d_advect = DistributedStencil(advect, mesh)
        d_euler = DistributedStencil(euler, mesh)
        d_diffuse = DistributedStencil(diffuse, mesh)
        f = fresh_fields()
        for _ in range(NT):
            f["adv"] = d_advect({"phi": f["phi"], "u": f["u"], "v": f["v"],
                                 "adv": f["adv"]}, {"dx": sc["dx"], "dy": sc["dy"]})["adv"]
            f["phi_star"] = d_euler({"phi": f["phi"], "adv": f["adv"],
                                     "out": f["phi_star"]}, {"dt": sc["dt"]})["out"]
            new = d_diffuse({"phi": f["phi_star"], "out": f["phi_new"]},
                            {"alpha": sc["alpha"]})["out"]
            f["phi"], f["phi_new"] = new, f["phi"]

        # ---- fused program: one shard_map jit per step, minimal exchanges
        dp = step.distribute(mesh)
        g = fresh_fields()
        info = {}
        for t in range(NT):
            out = dp(g, sc, exec_info=info if t == 0 else None)
            g["phi"], g["phi_new"] = out["phi"], out["phi_new"]

        rep = info["program_report"]
        err = float(np.abs(np.asarray(g["phi"]) - np.asarray(f["phi"])).max())
        print(json.dumps({
            "err": err,
            "groups": rep["groups"],
            "fused": rep["fused_stencils"],
            "eliminated": rep["eliminated_temporaries"],
            "inserted": rep["halo_plan"]["inserted"],
            "baseline": rep["halo_plan"]["baseline_per_step"],
        }))
        """)
    )
    assert out["err"] == 0.0  # bit-identical across 10 sharded steps
    assert out["fused"] >= 1
    assert out["eliminated"] == ["adv"]
    # minimal plan: phi before the advect group, phi_star before diffuse —
    # vs six per step for the eager chain (every field of every call)
    assert out["inserted"] == 2
    assert out["baseline"] == 6
    assert out["inserted"] < out["baseline"]


def test_distributed_program_matches_single_device():
    out = _run_subprocess(
        _STEP_DEFS
        + textwrap.dedent("""
        # single-device numpy oracle with the same zero-halo boundary: embed
        # the global domain in a zero-padded buffer
        from repro.core import storage
        buildn = gtscript.stencil(backend="numpy")
        n_advect, n_euler, n_diffuse = (buildn(advect_defs), buildn(euler_defs),
                                        buildn(diffuse_defs))
        H = 1
        shape = (NI + 2 * H, NJ + 2 * H, NK)
        def pad(x):
            p = np.zeros(shape)
            p[H:-H, H:-H, :] = x
            return p
        s = {n: storage.from_array(pad(a), default_origin=(H, H, 0))
             for n, a in (("phi", phi0), ("u", u0), ("v", v0))}
        for n in ("adv", "phi_star", "phi_new"):
            s[n] = storage.zeros(shape, default_origin=(H, H, 0))
        dom = (NI, NJ, NK)
        for _ in range(NT):
            n_advect(s["phi"], s["u"], s["v"], s["adv"], dx=sc["dx"], dy=sc["dy"], domain=dom)
            n_euler(s["phi"], s["adv"], s["phi_star"], dt=sc["dt"], domain=dom)
            n_diffuse(s["phi_star"], s["phi_new"], alpha=sc["alpha"], domain=dom)
            s["phi"], s["phi_new"] = s["phi_new"], s["phi"]
        ref = s["phi"].to_numpy()[H:-H, H:-H, :]

        dp = step.distribute(mesh)
        g = fresh_fields()
        for _ in range(NT):
            out = dp(g, sc)
            g["phi"], g["phi_new"] = out["phi"], out["phi_new"]
        err = float(np.abs(np.asarray(g["phi"]) - ref).max())
        print(json.dumps({"err": err}))
        """)
    )
    # cross-backend (XLA vs numpy) agreement at rounding level over 10 steps
    assert out["err"] < 1e-12


def test_forced_exchange_marker_honoured():
    out = _run_subprocess(
        _STEP_DEFS
        + textwrap.dedent("""
        from repro.parallel.halo import request_exchange

        @program(backend=be, name="dist_forced")
        def fstep(phi, u, v, adv, phi_star, phi_new, *, dx, dy, dt, alpha):
            request_exchange(phi, 2)
            advect(phi, u, v, adv, dx=dx, dy=dy)
            euler(phi, adv, phi_star, dt=dt)
            diffuse(phi_star, phi_new, alpha=alpha)
            return {"phi": phi_new, "phi_new": phi}

        dp = fstep.distribute(mesh)
        g = fresh_fields()
        info = {}
        out = dp(g, sc, exec_info=info)
        ops = info["program_report"]["halo_plan"]["ops"]
        forced = [o for o in ops if o["forced"]]
        print(json.dumps({"n_ops": len(ops), "forced": forced}))
        """)
    )
    assert out["forced"] == [{"buffer": "phi", "halo": 2, "before_group": 0, "forced": True}]
    # the forced depth-2 exchange covers advect's depth-1 need: no extra op
    assert out["n_ops"] == 2


def test_distributed_iterate_bit_identical_to_eager_distributed_loop():
    """``DistributedProgram.iterate(n)``: n sharded steps in ONE fori_loop
    dispatch, the 2-exchange/step plan applied per iteration — bit-identical
    to n eager distributed calls."""
    out = _run_subprocess(
        _STEP_DEFS
        + textwrap.dedent("""
        dp = step.distribute(mesh)

        # eager: NT separate sharded dispatches with host-side rotation
        g = fresh_fields()
        for _ in range(NT):
            o = dp(g, sc)
            g["phi"], g["phi_new"] = o["phi"], o["phi_new"]

        # fused: one fori_loop dispatch
        info = {}
        final = dp.iterate(NT, fresh_fields(), sc, exec_info=info)
        rep = info["program_report"]
        err = float(np.abs(np.asarray(final["phi"]) - np.asarray(g["phi"])).max())
        print(json.dumps({
            "err": err,
            "iterated": rep["iterated_steps"],
            "inserted": rep["halo_plan"]["inserted"],
        }))
        """)
    )
    assert out["err"] == 0.0  # bit-identical across 10 fused sharded steps
    assert out["iterated"] == 10
    assert out["inserted"] == 2  # the minimal plan runs inside every iteration


def test_distributed_iterate_requires_rotation_closed_outputs():
    out = _run_subprocess(
        _STEP_DEFS
        + textwrap.dedent("""
        from repro.program import ProgramError

        @program(backend=be, name="dist_open")
        def open_step(phi, u, v, adv, *, dx, dy):
            advect(phi, u, v, adv, dx=dx, dy=dy)
            return {"tendency": adv}

        dp = open_step.distribute(mesh)
        f = {"phi": jnp.asarray(phi0), "u": jnp.asarray(u0), "v": jnp.asarray(v0),
             "adv": jnp.zeros((NI, NJ, NK))}
        try:
            dp.iterate(3, f, {"dx": sc["dx"], "dy": sc["dy"]})
            failed = False
        except ProgramError:
            failed = True
        print(json.dumps({"raised": failed}))
        """)
    )
    assert out["raised"] is True


def test_distributed_ensemble_members_times_domain_sharding():
    """Member x domain co-sharding: the member axis shards over its own mesh
    axis, domain tiles over (data, model), local members advance under vmap
    (batched halo exchanges) — and the result matches the single-device
    ensemble at rounding level."""
    out = _run_subprocess(
        _STEP_DEFS.replace(
            'mesh = jax.make_mesh((4, 2), ("data", "model"))',
            'mesh = jax.make_mesh((2, 2, 2), ("ens", "data", "model"))',
        )
        + textwrap.dedent("""
        from repro.core.storage import Storage
        from repro.ensemble import Ensemble, perturb
        from repro.ensemble import batch as B

        NMEM = 4
        ens = Ensemble(step, NMEM)

        # single-device oracle: python loop over per-member compiled programs
        # on padded (zero-halo-matching) storages
        Hh = 1
        shape = (NI + 2 * Hh, NJ + 2 * Hh, NK)
        def pad(x):
            p = np.zeros(shape)
            p[Hh:-Hh, Hh:-Hh, :] = x
            return p
        phi_b = perturb(
            Storage(pad(phi0), backend="jax", default_origin=(Hh, Hh, 0)),
            NMEM, seed=0, amplitude=1e-3)
        # zero the perturbation outside the interior so the zero-halo
        # boundary of the mesh decomposition is reproduced exactly
        noise_masked = np.zeros((NMEM,) + shape)
        noise_masked[:, Hh:-Hh, Hh:-Hh, :] = np.asarray(phi_b.data)[:, Hh:-Hh, Hh:-Hh, :]
        phi_b = Storage(noise_masked, backend="jax", default_origin=(0, Hh, Hh, 0),
                        axes=("N", "I", "J", "K"))

        refs = []
        for m in range(NMEM):
            mf = {
                "phi": Storage(np.asarray(phi_b.data)[m].copy(), backend="jax",
                               default_origin=(Hh, Hh, 0)),
                "u": Storage(pad(u0), backend="jax", default_origin=(Hh, Hh, 0)),
                "v": Storage(pad(v0), backend="jax", default_origin=(Hh, Hh, 0)),
            }
            for n in ("adv", "phi_star", "phi_new"):
                mf[n] = Storage(np.zeros(shape), backend="jax", default_origin=(Hh, Hh, 0))
            step(mf["phi"], mf["u"], mf["v"], mf["adv"], mf["phi_star"], mf["phi_new"], **sc)
            refs.append(np.asarray(mf["phi"].data)[Hh:-Hh, Hh:-Hh, :])
        ref = np.stack(refs)

        # distributed ensemble: GLOBAL interior-only arrays, members sharded
        # over the "ens" mesh axis, domain tiles over (data, model)
        dens = ens.distribute(mesh, member_axis="ens")
        g = {
            "phi": jnp.asarray(np.asarray(phi_b.data)[:, Hh:-Hh, Hh:-Hh, :]),
            "u": jnp.asarray(u0), "v": jnp.asarray(v0),
            "adv": jnp.zeros((NMEM, NI, NJ, NK)),
            "phi_star": jnp.zeros((NMEM, NI, NJ, NK)),
            "phi_new": jnp.zeros((NMEM, NI, NJ, NK)),
        }
        info = {}
        o = dens(g, sc, exec_info=info)
        rep = info["ensemble_report"]
        err = float(np.abs(np.asarray(o["phi"]) - ref).max())
        print(json.dumps({
            "err": err,
            "members": rep["members"],
            "per_shard": rep["members_per_shard"],
            "inserted": rep["program_report"]["halo_plan"]["inserted"],
            "out_shape": list(np.asarray(o["phi"]).shape),
        }))
        """)
    )
    assert out["err"] < 1e-12  # member x domain sharding matches the oracle
    assert out["members"] == 4 and out["per_shard"] == 2
    assert out["inserted"] == 2  # one exchange serves ALL local members
    assert out["out_shape"] == [4, 32, 16, 6]
